"""EXPERIMENTS.md §Dry-run + §Roofline table generator.

  PYTHONPATH=src python -m repro.roofline.report --dryrun-dir reports/dryrun

Prints markdown tables from the dry-run artifacts; EXPERIMENTS.md embeds
the output.
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(path: str) -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def _gb(x) -> str:
    return f"{x / 1e9:.2f}"


def _note(r: dict) -> str:
    dom = r["dominant"]
    arch, shape = r["arch"], r["shape"]
    if dom == "collective":
        kinds = r.get("coll_breakdown", {}).get("bytes", {})
        top = max(kinds, key=kinds.get) if kinds else "?"
        return (f"{top} dominates — overlap collectives with compute "
                f"(TPU latency-hiding scheduler) or reshard the source tensor")
    if dom == "memory":
        if r["kind"] == "decode":
            return ("KV/weight streaming — fuse reads (flash-decode kernel), "
                    "quantize weights/KV")
        return ("activation traffic — Pallas flash/SSD kernels keep the "
                "score/state chain in VMEM")
    return "compute-bound — at roofline; raise per-chip utilization via bigger tiles"


def dryrun_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | compile_s | args GB/chip | temp GB/chip | collectives (count by kind) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r.get("mesh", ""))):
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | — | — | — | "
                f"{r['reason'][:60]}… |"
            )
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **ERROR** | — | — | — | "
                f"{r.get('error', '')[:60]} |"
            )
            continue
        ms = r["memory_stats"]
        counts = r.get("coll_breakdown", {}).get("count", {})
        cstr = ", ".join(f"{k}:{int(v)}" for k, v in sorted(counts.items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {r['compile_s']} | "
            f"{_gb(ms.get('argument_bytes', 0))} | {_gb(ms.get('temp_bytes', 0))} | "
            f"{cstr} |"
        )
    return "\n".join(lines)


def roofline_table(recs: list[dict], mesh: str = "pod16x16") -> str:
    lines = [
        "| arch | shape | t_compute s | t_memory s | t_collective s | dominant | MODEL_FLOPS | useful ratio | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | "
                f"long_500k needs sub-quadratic mixing (full-attention arch) |"
            )
            continue
        if r.get("status") != "ok":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3e} | "
            f"{r['t_memory']:.3e} | {r['t_collective']:.3e} | "
            f"**{r['dominant']}** | {r['model_flops_total']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | {_note(r)} |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="reports/dryrun")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    recs = load(args.dryrun_dir)
    if args.section in ("all", "dryrun"):
        print("### Dry-run artifacts (both meshes)\n")
        print(dryrun_table(recs))
        print()
    if args.section in ("all", "roofline"):
        print("### Roofline terms (single-pod 16x16, 256 chips)\n")
        print(roofline_table(recs, "pod16x16"))
        print()
        print("### Roofline terms (multi-pod 2x16x16, 512 chips)\n")
        print(roofline_table(recs, "pod2x16x16"))


if __name__ == "__main__":
    main()
