"""Flash attention (forward) as a Pallas TPU kernel.

Blocked online-softmax attention: grid (batch, q_heads, q_blocks,
kv_blocks) with the kv dimension sequential ("arbitrary"), carrying the
running max / normalizer / output accumulator in VMEM scratch. Block
shapes are MXU-aligned (multiples of 128 on the contraction dims) and
sized so the working set — q block (Bq x D), kv blocks (Bk x D), the
(Bq x Bk) score tile, and the fp32 accumulator — fits VMEM.

GQA folds into the k/v BlockSpec index maps (head h reads kv head
h // group). Causal masking prunes fully-masked kv blocks with pl.when.

The training/dry-run path uses the XLA chunked implementation in
repro.models.layers (Pallas cannot lower on the CPU placeholder backend);
this kernel is the TPU serving/prefill hot path, validated in
interpret mode against ref.py on every shape/dtype in the test sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells CompilerParams TPUCompilerParams.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,            # VMEM blocks
    o_ref,                           # output block
    m_ref, l_ref, acc_ref,           # VMEM scratch (fp32)
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    num_kv_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # Skip kv blocks entirely above the causal diagonal.
    run = (k_start <= q_start + block_q - 1) if causal else True

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # (Bq, Bk)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, NEG_INF)

        m_prev = m_ref[...]                           # (Bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                        # (Bq, Bk)
        alpha = jnp.exp(m_prev - m_new)               # (Bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,      # (B, Hq, Sq, D)
    k: jax.Array,      # (B, Hkv, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0, (sq, block_q)
    assert sk % block_k == 0, (sk, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = d ** -0.5 if scale is None else scale

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        num_kv_blocks=nk,
    )

    grid = (b, hq, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, group=group: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, group=group: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running normalizer
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
