from repro.configs.base import (
    BlockDef,
    ModelConfig,
    ShapeConfig,
    SHAPES,
    all_configs,
    get_config,
    register,
    shapes_for,
)

__all__ = [
    "BlockDef",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "all_configs",
    "get_config",
    "register",
    "shapes_for",
]
