"""Multi-host input-pipeline simulation: N hosts stream disjoint shard
sets from one shared object store — with failures, stragglers, and a
host replacement mid-epoch — asserting the properties a thousand-node
job depends on."""

from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.data import DataCursor, LoaderConfig, PrefetchingDataLoader, synth_token_shard
from repro.store import LinkModel, MemTier, SimS3Store

N_HOSTS = 8
N_SHARDS = 32


@pytest.fixture()
def store():
    rng = np.random.default_rng(7)
    s = SimS3Store(link=LinkModel(latency_s=0.001, bandwidth_Bps=200e6))
    for i in range(N_SHARDS):
        s.backing.put(f"tok{i:03d}.bin", synth_token_shard(rng, 3000, vocab=1000))
    return s


def _loader(store, host, cursor=None, **kw):
    cfg = LoaderConfig(
        seq_len=64, batch_size=2, blocksize=4096,
        host_id=host, num_hosts=N_HOSTS, **kw,
    )
    return PrefetchingDataLoader(
        store, store.backing.list_objects(), [MemTier(1 << 20)], cfg,
        cursor=cursor,
    )


class TestMultiHost:
    def test_hosts_cover_disjoint_shards(self, store):
        files = store.backing.list_objects()
        assigned = []
        for h in range(N_HOSTS):
            loader = _loader(store, h)
            assigned.extend(m.key for m in loader.my_files)
            loader.close()
        assert sorted(assigned) == sorted(m.key for m in files)
        assert len(set(assigned)) == len(assigned)

    def test_concurrent_hosts_stream_correct_data(self, store):
        """All hosts pull batches concurrently through the SHARED link;
        every host's stream must equal its single-threaded reference."""
        results: dict[int, list] = {}
        errors: list = []

        def run(host):
            try:
                loader = _loader(store, host)
                results[host] = [b[0] for b in loader.batches(max_batches=3)]
                loader.close()
            except BaseException as e:  # noqa: BLE001
                errors.append((host, e))

        threads = [threading.Thread(target=run, args=(h,))
                   for h in range(N_HOSTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors

        for h in range(N_HOSTS):
            ref_loader = _loader(store, h)
            ref = [b[0] for b in ref_loader.batches(max_batches=3)]
            ref_loader.close()
            for a, b in zip(results[h], ref):
                np.testing.assert_array_equal(a, b)

    def test_host_replacement_resumes_deterministically(self, store):
        """Host 3 'dies' after 2 batches; its replacement restores the
        cursor and must produce exactly the batches the original would
        have produced next."""
        loader = _loader(store, 3)
        consumed = [b for b in loader.batches(max_batches=2)]
        cursor = DataCursor(**loader.cursor.to_dict())
        loader.close()  # host dies

        # Uninterrupted reference.
        ref_loader = _loader(store, 3)
        ref = [b for b in ref_loader.batches(max_batches=5)]
        ref_loader.close()

        # Replacement host resumes from the checkpointed cursor.
        repl = _loader(store, 3, cursor=cursor)
        resumed = [b for b in repl.batches(max_batches=3)]
        repl.close()
        for (a, _), (b, _) in zip(resumed, ref[2:]):
            np.testing.assert_array_equal(a, b)

    def test_transient_store_failures_do_not_corrupt_streams(self, store):
        store.link.fail_prob = 0.02
        store.link._rng.seed(123)
        loader = _loader(store, 0, mode="rolling")
        batches = [b for b in loader.batches(max_batches=4)]
        loader.close()
        store.link.fail_prob = 0.0
        ref_loader = _loader(store, 0)
        ref = [b for b in ref_loader.batches(max_batches=4)]
        ref_loader.close()
        for (a, _), (b, _) in zip(batches, ref):
            np.testing.assert_array_equal(a, b)

    def test_straggler_hedging_under_jitter(self, store):
        store.link.jitter = 2.0  # heavy-tailed latencies
        loader = _loader(store, 1, hedge_timeout_s=0.01)
        batches = [b for b in loader.batches(max_batches=3)]
        stats = loader.stats
        loader.close()
        assert len(batches) == 3
        assert stats is not None  # hedges counter exists (may or may not fire)
