from repro.data.loader import (
    DataCursor,
    DeviceFeeder,
    LoaderConfig,
    PrefetchingDataLoader,
)
from repro.data.tokens import (
    TokenStreamReader,
    synth_token_shard,
    write_token_shard,
)
from repro.data.trk import (
    LazyTrkReader,
    Streamline,
    TrkHeader,
    iter_streamlines_multi,
    synth_trk,
    write_trk,
)

__all__ = [
    "DataCursor",
    "DeviceFeeder",
    "LoaderConfig",
    "PrefetchingDataLoader",
    "TokenStreamReader",
    "synth_token_shard",
    "write_token_shard",
    "LazyTrkReader",
    "Streamline",
    "TrkHeader",
    "iter_streamlines_multi",
    "synth_trk",
    "write_trk",
]
