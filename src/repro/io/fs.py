"""PrefetchFS: one filesystem-style facade for every reader engine.

Following the S3Fs idiom the paper extends, applications hold a filesystem
object and open file-like readers from it::

    fs = PrefetchFS(store, policy=IOPolicy(engine="rolling", blocksize=8 << 20))
    with fs:
        f = fs.open("bucket/key")              # one object
        g = fs.open_many(metas, depth=4)       # multi-object logical stream,
                                               # per-open policy override
        ...
        print(fs.stats().snapshot())           # aggregated across all opens

The facade owns cache-tier lifecycle (builds a bounded MemTier on demand
when an engine needs one and none was supplied), dispatches
``IOPolicy.engine`` through the reader registry, and aggregates per-reader
statistics into one `FSStats` view. Training data loading, checkpoint
restore, serving cold-start, and every A/B benchmark construct readers
exclusively through this API.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Iterable, Sequence

from repro.io.policy import IOPolicy
from repro.io.registry import available_engines, engine_spec
from repro.store.base import ObjectMeta, ObjectStore
from repro.store.tiers import CacheTier, MemTier

# Importing the engines module populates the registry with the built-ins.
import repro.io.engines  # noqa: F401  (side-effect import)


@dataclass
class FSStats:
    """Aggregated I/O statistics across every reader a PrefetchFS opened.

    ``totals`` sums every numeric counter that any engine reports
    (bytes_read, bytes_fetched, retries, hedges, direct_reads, ...);
    ``per_engine`` keeps the same sums split by engine name.
    """

    opens: int = 0
    totals: dict = field(default_factory=dict)
    per_engine: dict = field(default_factory=dict)

    def snapshot(self) -> dict:
        return {
            "opens": self.opens,
            "totals": dict(self.totals),
            "per_engine": {k: dict(v) for k, v in self.per_engine.items()},
        }


class PrefetchFS:
    """Filesystem facade over an `ObjectStore` with pluggable prefetching."""

    def __init__(
        self,
        store: ObjectStore,
        policy: IOPolicy | None = None,
        tiers: Sequence[CacheTier] | None = None,
    ) -> None:
        self.store = store
        self.policy = policy if policy is not None else IOPolicy()
        self._tiers: list[CacheTier] | None = (
            list(tiers) if tiers is not None else None
        )
        self._lock = threading.RLock()
        self._readers: list[tuple[str, object]] = []
        # Stats of already-closed readers, folded per engine so a loader
        # that reopens a stream every epoch doesn't accumulate dead reader
        # objects (see _prune_closed).
        self._folded: dict[str, dict] = {}
        self._closed = False

    # ------------------------------------------------------------------ #
    # opening readers
    # ------------------------------------------------------------------ #
    def open(self, key, *, policy: IOPolicy | None = None,
             tiers: Sequence[CacheTier] | None = None, **overrides):
        """Open one object (or a list of them) as a `Reader`.

        ``key`` is an object key string, an `ObjectMeta`, or a list of
        either (lists delegate to :meth:`open_many`). Keyword overrides
        (``engine=``, ``blocksize=``, ``depth=``, ...) apply on top of the
        filesystem policy for this open only.
        """
        if isinstance(key, (list, tuple)):
            return self.open_many(key, policy=policy, tiers=tiers, **overrides)
        return self.open_many([key], policy=policy, tiers=tiers, **overrides)

    def open_many(self, keys: Iterable, *, policy: IOPolicy | None = None,
                  tiers: Sequence[CacheTier] | None = None, **overrides):
        """Open a list of objects as ONE logical sequential stream — the
        paper's multi-file case ("treating a list of files as a single
        file"). Returns a `Reader`."""
        if self._closed:   # early check: skip store metadata round-trips
            raise ValueError("open on closed PrefetchFS")
        pol = policy if policy is not None else self.policy
        if overrides:
            pol = pol.replace(**overrides)
        spec = engine_spec(pol.engine)
        files = [self._resolve(k) for k in keys]
        # The closed check, factory call, and registration happen under one
        # lock so an open racing with close() either lands in close()'s
        # sweep or observes the closed flag — never an orphaned reader.
        with self._lock:
            if self._closed:
                raise ValueError("open on closed PrefetchFS")
            if tiers is not None:
                use_tiers = list(tiers)
            elif spec.needs_tiers:
                use_tiers = self._ensure_tiers(pol)
            else:
                use_tiers = []
            reader = spec.factory(self.store, files, use_tiers, pol)
            self._prune_closed()
            self._readers.append((pol.engine, reader))
        return reader

    def _resolve(self, key) -> ObjectMeta:
        if isinstance(key, ObjectMeta):
            return key
        key = str(key)
        return ObjectMeta(key, self.store.size(key))

    def _ensure_tiers(self, policy: IOPolicy) -> list[CacheTier]:
        with self._lock:
            if self._tiers is None:
                self._tiers = [
                    MemTier(policy.default_tier_capacity(), name="prefetchfs.mem")
                ]
            return self._tiers

    # ------------------------------------------------------------------ #
    # introspection
    # ------------------------------------------------------------------ #
    def ls(self, prefix: str = "") -> list[ObjectMeta]:
        """List objects under a prefix (one store metadata request)."""
        return self.store.list_objects(prefix)

    def engines(self) -> tuple[str, ...]:
        return available_engines()

    @property
    def tiers(self) -> list[CacheTier]:
        """The cache tiers this filesystem manages (empty until an engine
        that needs them is opened, unless tiers were supplied)."""
        with self._lock:
            return list(self._tiers or [])

    @staticmethod
    def _fold_snapshot(bucket: dict, reader) -> None:
        bucket["opens"] = bucket.get("opens", 0) + 1
        stats_obj = getattr(reader, "stats", None)
        snap = stats_obj.snapshot() if stats_obj is not None else {}
        for k, v in snap.items():
            if isinstance(v, (int, float)):
                bucket[k] = bucket.get(k, 0) + v

    def _prune_closed(self) -> None:
        """Fold the stats of closed readers into `_folded` and drop the
        reader objects, so per-epoch reopen loops stay O(1) memory.
        Caller holds `_lock`."""
        live = []
        for engine, reader in self._readers:
            if getattr(reader, "closed", False):
                self._fold_snapshot(self._folded.setdefault(engine, {}), reader)
            else:
                live.append((engine, reader))
        self._readers = live

    def stats(self) -> FSStats:
        """Aggregate statistics across every reader opened so far (open or
        closed); closed readers' stats persist in the folded totals."""
        with self._lock:
            per_engine = {k: dict(v) for k, v in self._folded.items()}
            readers = list(self._readers)
        for engine, reader in readers:
            self._fold_snapshot(per_engine.setdefault(engine, {}), reader)
        out = FSStats(per_engine=per_engine)
        for bucket in per_engine.values():
            out.opens += bucket.get("opens", 0)
            for k, v in bucket.items():
                if k != "opens":
                    out.totals[k] = out.totals.get(k, 0) + v
        return out

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def close(self) -> None:
        """Close every reader this filesystem opened (engines run their
        final eviction sweep, so owned tiers end empty)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            readers = list(self._readers)
        # Closing outside the lock: rolling close joins worker threads.
        for _, reader in readers:
            reader.close()

    def __enter__(self) -> "PrefetchFS":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
