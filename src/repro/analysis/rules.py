"""The rule set. Each rule encodes one bug class this repo has already
shipped and fixed (rationale strings cite the history); see README's
"Static analysis" section for the catalogue.

Rules are deliberately heuristic: they under-approximate (unresolvable
receivers and calls are skipped) so a finding is worth reading, and the
suppression comment exists for the cases where the code is right and
the rule cannot see why.
"""

from __future__ import annotations

import ast

from repro.analysis.core import (
    Finding,
    FuncInfo,
    Module,
    Project,
    held_walk,
    iter_calls_shallow,
)
from repro.analysis.registry import register_rule


def _module_funcs(module: Module, project: Project) -> list[FuncInfo]:
    return [fi for fi in project.funcs.values() if fi.module is module]


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return "<call>"


def _recv_name(expr: ast.AST) -> str:
    """Terminal name of a call receiver: `self.store` -> "store"."""
    if isinstance(expr, ast.Attribute):
        return expr.attr
    if isinstance(expr, ast.Name):
        return expr.id
    return ""


def _shallow(node: ast.AST):
    """Walk a subtree without descending into nested scopes."""
    yield node
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda, ast.ClassDef)):
            continue
        yield from _shallow(child)


# ---------------------------------------------------------------------------
# RP001: bare lock.acquire()
# ---------------------------------------------------------------------------

@register_rule(
    "RP001",
    "bare lock.acquire() without a with-block or try/finally release",
    rationale="PR 4 fixed locks leaked on early-exit paths in the rolling "
              "scheduler; an acquire whose release is not on every exit "
              "path wedges all readers behind a dead flight.",
)
def rule_bare_acquire(module: Module, project: Project) -> list[Finding]:
    out: list[Finding] = []
    for fi in _module_funcs(module, project):
        # Locks released inside ANY finally block of this function.
        safe: set[str] = set()
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Try):
                continue
            for stmt in node.finalbody:
                for call in iter_calls_shallow(stmt):
                    f = call.func
                    if isinstance(f, ast.Attribute) and f.attr == "release":
                        lock = project.resolve_lock_expr(fi, f.value)
                        if lock:
                            safe.add(lock)
        for call in iter_calls_shallow(fi.node):
            f = call.func
            if not (isinstance(f, ast.Attribute) and f.attr == "acquire"):
                continue
            lock = project.resolve_lock_expr(fi, f.value)
            if lock is None or lock in safe:
                continue
            out.append(module.finding(
                "RP001", call,
                f"`{lock}.acquire()` with no matching release in a "
                f"finally block — use `with {_recv_name(f.value)}:` or "
                f"try/finally so every exit path releases it",
            ))
    return out


# ---------------------------------------------------------------------------
# RP002: blocking I/O while holding a lock
# ---------------------------------------------------------------------------

_SOCKET_BLOCKING = {"recv", "recv_into", "recvfrom", "sendall", "accept",
                    "connect", "create_connection"}
_STORE_BLOCKING = {"get_range", "get_ranges", "get_range_verified",
                   "get_ranges_verified", "digest_range", "start_multipart"}
_STORE_NAMED = {"get", "put", "delete"}          # only on store-ish receivers
_TIER_BLOCKING = {"read", "write", "delete"}     # only on tier receivers
_BLOCKING_FUNCS = {"recv_msg", "send_msg"}       # peer frame I/O


def _blocking_desc(fi: FuncInfo, project: Project,
                   call: ast.Call) -> str | None:
    f = call.func
    if isinstance(f, ast.Name):
        if f.id in _BLOCKING_FUNCS:
            return f"socket I/O {f.id}()"
        return None
    if not isinstance(f, ast.Attribute):
        return None
    attr, recv = f.attr, f.value
    rname = _recv_name(recv)
    if attr == "sleep" and rname == "time":
        return "time.sleep()"
    if attr in _SOCKET_BLOCKING and attr != "connect" or (
            attr == "connect" and ("sock" in rname or "conn" in rname)):
        if rname == "time":
            return None
        return f"socket I/O .{attr}()"
    if attr in _STORE_BLOCKING:
        return f"store I/O .{attr}()"
    if attr in _STORE_NAMED and (rname in ("inner",) or rname.endswith("store")):
        return f"store I/O {rname}.{attr}()"
    if attr in _TIER_BLOCKING:
        rtype = project.receiver_type(fi, recv)
        tierish = (rtype is not None
                   and project.is_subclass_of(rtype, "CacheTier"))
        if tierish or rname == "tier" or rname.endswith("_tier"):
            return f"tier I/O .{attr}()"
    if attr == "fetch" and (rname.endswith("client")
                            or project.receiver_type(fi, recv) == "PeerClient"):
        return "peer RPC .fetch()"
    return None


def _blocking_closures(project: Project) -> dict:
    """function key -> {description: via-qualname-or-None}, the fixpoint
    of "may this function block?" over the resolved call graph."""
    direct: dict = {}
    callees: dict = {}
    for key, fi in project.funcs.items():
        found: dict = {}
        outs = set()
        for call in iter_calls_shallow(fi.node):
            desc = _blocking_desc(fi, project, call)
            if desc:
                found.setdefault(desc, None)
            target = project.resolve_call(fi, call)
            if target is not None and target.key != key:
                outs.add(target.key)
        direct[key] = found
        callees[key] = outs
    closure = {k: dict(v) for k, v in direct.items()}
    changed = True
    while changed:
        changed = False
        for key, outs in callees.items():
            mine = closure[key]
            qual = {k2: project.funcs[k2].qualname for k2 in outs}
            for callee in outs:
                for desc, via in closure.get(callee, {}).items():
                    if desc not in mine:
                        mine[desc] = via or qual[callee]
                        changed = True
    return closure


@register_rule(
    "RP002",
    "blocking call (store/tier/socket I/O, time.sleep) while holding a lock",
    rationale="PR 4's scheduler rewrite moved store GETs out from under "
              "the index lock after profiling showed every reader "
              "serialized behind one fetch; I/O under a lock turns "
              "concurrency into a queue.",
)
def rule_blocking_under_lock(module: Module,
                             project: Project) -> list[Finding]:
    out: list[Finding] = []
    closures = getattr(project, "_rp002_closures", None)
    if closures is None:
        closures = _blocking_closures(project)
        project._rp002_closures = closures  # type: ignore[attr-defined]
    for fi in _module_funcs(module, project):
        for ev in held_walk(fi, project):
            if ev[0] != "call":
                continue
            _, call, held = ev
            if not held:
                continue
            lock = held[-1]
            desc = _blocking_desc(fi, project, call)
            if desc is not None:
                out.append(module.finding(
                    "RP002", call,
                    f"{desc} inside `with {lock}:` — move the I/O out of "
                    f"the critical section (tombstone/copy-then-release)",
                ))
                continue
            target = project.resolve_call(fi, call)
            if target is None or target.key == fi.key:
                continue
            blocked = closures.get(target.key, {})
            if blocked:
                desc, via = next(iter(sorted(blocked.items())))
                chain = f" via {via}()" if via else ""
                out.append(module.finding(
                    "RP002", call,
                    f"call to {target.qualname}() may block ({desc}"
                    f"{chain}) while holding `{lock}`",
                ))
    return out


# ---------------------------------------------------------------------------
# RP003: Condition.wait() without a while-loop predicate
# ---------------------------------------------------------------------------

def _condition_receiver(fi: FuncInfo, project: Project,
                        expr: ast.AST) -> str | None:
    """Lock name if `expr` denotes a threading.Condition."""
    lock = project.resolve_lock_expr(fi, expr)
    if lock is None:
        return None
    if "<local " in lock:
        # Local: find the constructing assignment to read its kind.
        name = _recv_name(expr)
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and node.targets[0].id == name \
                    and isinstance(node.value, ast.Call):
                f = node.value.func
                cname = f.attr if isinstance(f, ast.Attribute) else \
                    f.id if isinstance(f, ast.Name) else ""
                return lock if cname == "Condition" else None
        return None
    return lock if project.lock_kind(lock) == "Condition" else None


@register_rule(
    "RP003",
    "Condition.wait() not guarded by a while-loop predicate",
    rationale="Spurious wakeups and stolen notifications are real: the "
              "cache index's single-flight join loops on its predicate "
              "for exactly this reason. An if-guarded wait() returns "
              "once with the predicate still false.",
)
def rule_unguarded_wait(module: Module, project: Project) -> list[Finding]:
    out: list[Finding] = []
    for fi in _module_funcs(module, project):
        for call in iter_calls_shallow(fi.node):
            f = call.func
            if not (isinstance(f, ast.Attribute) and f.attr == "wait"):
                continue
            lock = _condition_receiver(fi, project, f.value)
            if lock is None:
                continue
            in_while = any(isinstance(p, ast.While)
                           for p in module.parents(call))
            if not in_while:
                out.append(module.finding(
                    "RP003", call,
                    f"`{lock}.wait()` outside any while loop — re-check "
                    f"the predicate in a loop, or use wait_for()",
                ))
    return out


# ---------------------------------------------------------------------------
# RP004: hand-rolled backoff outside repro.io.retry
# ---------------------------------------------------------------------------

@register_rule(
    "RP004",
    "hand-rolled retry backoff (time.sleep / 2**attempt in an except "
    "handler) outside repro.io.retry",
    rationale="PR 5 unified three divergent retry implementations after "
              "an unjittered 2**attempt loop synchronized clients into "
              "retry storms; backoff now lives in repro.io.retry "
              "(full jitter, budget, Retry-After) and nowhere else.",
    skip_paths=("io/retry.py",),
)
def rule_handrolled_backoff(module: Module,
                            project: Project) -> list[Finding]:
    out: list[Finding] = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        for stmt in node.body:
            for call in iter_calls_shallow(stmt):
                f = call.func
                if isinstance(f, ast.Attribute) and f.attr == "sleep" \
                        and _recv_name(f.value) == "time":
                    out.append(module.finding(
                        "RP004", call,
                        "time.sleep() in an except handler — hand-rolled "
                        "backoff; use repro.io.retry (Retrier/RetryPolicy: "
                        "full jitter + budget)",
                    ))
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Pow) \
                        and isinstance(sub.left, ast.Constant) \
                        and sub.left.value == 2:
                    out.append(module.finding(
                        "RP004", sub,
                        "`2 ** n` backoff in an except handler — "
                        "unjittered exponential backoff synchronizes "
                        "clients into retry storms; use repro.io.retry",
                    ))
    return out


# ---------------------------------------------------------------------------
# RP005: broad except that swallows
# ---------------------------------------------------------------------------

_BROAD = {"Exception", "BaseException"}


def _is_broad(type_node: ast.AST | None) -> bool:
    if type_node is None:
        return True
    if isinstance(type_node, ast.Name):
        return type_node.id in _BROAD
    if isinstance(type_node, ast.Attribute):
        return type_node.attr in _BROAD
    if isinstance(type_node, ast.Tuple):
        return any(_is_broad(e) for e in type_node.elts)
    return False


@register_rule(
    "RP005",
    "broad `except Exception` that neither re-raises nor carries an "
    "annotated suppression",
    rationale="Swallowed StoreError/IntegrityError turns data loss into "
              "silence — the HSM mover and write-behind pool both route "
              "broad catches through telemetry + annotation instead. A "
              "broad handler must re-raise, narrow, or say why not "
              "(`# repro: allow[RP005] — reason`).",
)
def rule_broad_except(module: Module, project: Project) -> list[Finding]:
    out: list[Finding] = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.ExceptHandler) or not _is_broad(node.type):
            continue
        reraises = any(isinstance(sub, ast.Raise)
                       for stmt in node.body
                       for sub in _shallow(stmt))
        if reraises:
            continue
        what = "bare except" if node.type is None else "broad except"
        out.append(module.finding(
            "RP005", node,
            f"{what} swallows all errors (incl. StoreError/IntegrityError)"
            " — re-raise, narrow the type, or annotate "
            "`# repro: allow[RP005] — reason`",
        ))
    return out


# ---------------------------------------------------------------------------
# RP006: fire-and-forget threads
# ---------------------------------------------------------------------------

def _is_thread_ctor(call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr == "Thread" and _recv_name(f.value) == "threading"
    return isinstance(f, ast.Name) and f.id == "Thread"


def _class_joins_attr(cls_node: ast.ClassDef, attr: str) -> bool:
    """Does any method of the class both reference self.<attr> and call
    .join() in the same function? Covers `self._t.join()` and
    `for t in self._threads: t.join()`."""
    for item in cls_node.body:
        if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        refs_attr = any(
            isinstance(n, ast.Attribute) and n.attr == attr
            and isinstance(n.value, ast.Name) and n.value.id == "self"
            for n in ast.walk(item)
        )
        joins = any(
            isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
            and n.func.attr == "join"
            for n in ast.walk(item)
        )
        if refs_attr and joins:
            return True
    return False


def _collection_local(call: ast.Call) -> str | None:
    """Thread ctor feeding a local collection: ``ts = [Thread(...) for ...]``,
    ``ts += [...]``, ``ts.append(Thread(...))``. Returns the local name."""
    node: ast.AST = call
    while True:
        parent = getattr(node, "_repro_parent", None)
        if parent is None or isinstance(
            parent, (ast.FunctionDef, ast.AsyncFunctionDef,
                     ast.Lambda, ast.ClassDef),
        ):
            return None
        if isinstance(parent, ast.Assign) and len(parent.targets) == 1 \
                and isinstance(parent.targets[0], ast.Name):
            return parent.targets[0].id
        if isinstance(parent, ast.AugAssign) \
                and isinstance(parent.target, ast.Name):
            return parent.target.id
        if isinstance(parent, ast.Call) and parent is not call \
                and isinstance(parent.func, ast.Attribute) \
                and parent.func.attr == "append" \
                and isinstance(parent.func.value, ast.Name):
            return parent.func.value.id
        node = parent


def _local_joined(fn: ast.AST, name: str) -> bool:
    """Is `<name>.join()` called, or `.join()` on the loop variable of a
    ``for t in <name>:`` loop, anywhere in the function?"""
    for n in ast.walk(fn):
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                and n.func.attr == "join" \
                and isinstance(n.func.value, ast.Name) \
                and n.func.value.id == name:
            return True
        if isinstance(n, ast.For) and isinstance(n.iter, ast.Name) \
                and n.iter.id == name and isinstance(n.target, ast.Name):
            var = n.target.id
            for m in ast.walk(n):
                if isinstance(m, ast.Call) \
                        and isinstance(m.func, ast.Attribute) \
                        and m.func.attr == "join" \
                        and isinstance(m.func.value, ast.Name) \
                        and m.func.value.id == var:
                    return True
    return False


@register_rule(
    "RP006",
    "threading.Thread spawned with no join()/close() path referencing it",
    rationale="Leaked hedge threads outlived their Hedger until PR 5 "
              "pinned their lifecycle; a thread nobody joins holds "
              "sockets and store handles past close() and turns "
              "shutdown into a race.",
)
def rule_unjoined_thread(module: Module, project: Project) -> list[Finding]:
    out: list[Finding] = []
    for fi in _module_funcs(module, project):
        fn = fi.node
        for call in iter_calls_shallow(fn):
            if not _is_thread_ctor(call):
                continue
            parent = getattr(call, "_repro_parent", None)
            stored_attr: str | None = None
            local: str | None = None
            if isinstance(parent, ast.Assign) and len(parent.targets) == 1:
                t = parent.targets[0]
                if isinstance(t, ast.Attribute) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id == "self":
                    stored_attr = t.attr
                elif isinstance(t, ast.Name):
                    local = t.id
            if local is None and stored_attr is None:
                local = _collection_local(call)
            if local is not None:
                if _local_joined(fn, local):
                    continue
                # t = Thread(); self.X.append(t) → stored under X.
                for n in ast.walk(fn):
                    if isinstance(n, ast.Call) \
                            and isinstance(n.func, ast.Attribute) \
                            and n.func.attr == "append" \
                            and isinstance(n.func.value, ast.Attribute) \
                            and n.args \
                            and isinstance(n.args[0], ast.Name) \
                            and n.args[0].id == local:
                        stored_attr = n.func.value.attr
                        break
            if stored_attr is not None and fi.cls is not None:
                for info in project.mro(fi.cls.name):
                    if _class_joins_attr(info.node, stored_attr):
                        break
                else:
                    out.append(module.finding(
                        "RP006", call,
                        f"thread stored in self.{stored_attr} is never "
                        f"join()ed by any method — add a close()/join path "
                        f"or annotate why the thread may be orphaned",
                    ))
                continue
            if stored_attr is None and local is None:
                out.append(module.finding(
                    "RP006", call,
                    "fire-and-forget thread (not stored, never joined) — "
                    "its lifetime outlives every owner; join it or "
                    "annotate why detaching is safe",
                ))
            elif local is not None:
                out.append(module.finding(
                    "RP006", call,
                    f"thread `{local}` is started but never join()ed in "
                    f"this function or stored on self — shutdown cannot "
                    f"wait for it",
                ))
    return out


# ---------------------------------------------------------------------------
# RP007: unverified range-get bytes published to a cache tier
# ---------------------------------------------------------------------------

_RANGE_GETTERS = {"get_range", "get_ranges"}
_PUBLISH_SINKS = {"write", "publish"}
_GUARDS = {"check_block", "check_ranges", "block_digest", "len"}


@register_rule(
    "RP007",
    "range-get bytes written to a tier/published without a length check "
    "or digest verification",
    rationale="An un-length-checked range response was once cached and "
              "served as truth (the short-push bug PR 7 fixed at the "
              "protocol edge, PR 8 at every path): verify length or "
              "digest between fetch and publish, or fetch via the "
              "*_verified variants.",
)
def rule_unverified_publish(module: Module,
                            project: Project) -> list[Finding]:
    out: list[Finding] = []
    for fi in _module_funcs(module, project):
        fn = fi.node
        tracked: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.value, ast.Call) \
                    and isinstance(node.value.func, ast.Attribute) \
                    and node.value.func.attr in _RANGE_GETTERS:
                t = node.targets[0]
                if isinstance(t, ast.Name):
                    tracked.add(t.id)
            # Iterating a tracked list taints the loop variable.
            if isinstance(node, ast.For) and isinstance(node.iter, ast.Name) \
                    and node.iter.id in tracked \
                    and isinstance(node.target, ast.Name):
                tracked.add(node.target.id)
            if isinstance(node, ast.For) and isinstance(node.iter, ast.Call) \
                    and isinstance(node.iter.func, ast.Name) \
                    and node.iter.func.id == "zip" \
                    and isinstance(node.target, ast.Tuple):
                srcs = {a.id for a in node.iter.args
                        if isinstance(a, ast.Name)}
                if srcs & tracked:
                    tracked.update(e.id for e in node.target.elts
                                   if isinstance(e, ast.Name))
        if not tracked:
            continue
        guarded: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                    and node.func.id in _GUARDS:
                for a in node.args:
                    if isinstance(a, ast.Name) and a.id in tracked:
                        guarded.add(a.id)
        for call in iter_calls_shallow(fn):
            f = call.func
            if not (isinstance(f, ast.Attribute) and f.attr in _PUBLISH_SINKS):
                continue
            for a in call.args:
                if isinstance(a, ast.Name) and a.id in tracked \
                        and a.id not in guarded:
                    out.append(module.finding(
                        "RP007", call,
                        f"`{a.id}` came from an unverified range get and "
                        f"reaches .{f.attr}() with no len()/digest check — "
                        f"a short or corrupt response would be cached as "
                        f"truth; check it or use get_range(s)_verified",
                    ))
    return out


# ---------------------------------------------------------------------------
# RP008: unseeded randomness / wall-clock assertions in tests
# ---------------------------------------------------------------------------

_RANDOM_FNS = {"random", "randint", "choice", "shuffle", "uniform",
               "randrange", "sample", "getrandbits", "randbytes"}
_TIME_FNS = {"time", "perf_counter", "monotonic"}


@register_rule(
    "RP008",
    "unseeded random.* call or wall-clock time in an assert, in tests",
    rationale="Flaky tests erode the tier-1 gate: the hypothesis "
              "fallback seeds every example stream per-test for exactly "
              "this reason. Seed the module RNG (or use random.Random(n)"
              "/jax.random keys); never assert on wall-clock reads.",
    only_paths=("tests",),
)
def rule_test_nondeterminism(module: Module,
                             project: Project) -> list[Finding]:
    out: list[Finding] = []
    seeded = any(
        isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
        and n.func.attr == "seed" and _recv_name(n.func.value) == "random"
        for n in ast.walk(module.tree)
    )
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            f = node.func
            if f.attr in _RANDOM_FNS and isinstance(f.value, ast.Name) \
                    and f.value.id == "random" and not seeded:
                out.append(module.finding(
                    "RP008", node,
                    f"unseeded random.{f.attr}() in a test — seed the "
                    f"module RNG or use random.Random(<seed>)",
                ))
        if isinstance(node, ast.Assert):
            for call in iter_calls_shallow(node.test):
                f = call.func
                if isinstance(f, ast.Attribute) and f.attr in _TIME_FNS \
                        and _recv_name(f.value) == "time":
                    out.append(module.finding(
                        "RP008", node,
                        f"assert reads the wall clock (time.{f.attr}()) — "
                        f"timing assertions flake under load; assert on "
                        f"counters or injected clocks instead",
                    ))
    return out
