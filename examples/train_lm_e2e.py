"""End-to-end LM training with the full production substrate, including a
mid-run simulated crash and automatic resume:

  object store -> Rolling Prefetch loader -> device feed -> jit train step
  -> async checkpoints -> (crash) -> restore + data-cursor resume -> finish

  PYTHONPATH=src python examples/train_lm_e2e.py [--steps 200]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import LoaderConfig, PrefetchingDataLoader, synth_token_shard
from repro.ft import RestartManager, run_with_restarts
from repro.io import IOPolicy, open_store
from repro.models import make_model
from repro.store import MemTier
from repro.train import AdamWConfig, StepConfig, build_train_step, init_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--arch", default="smollm-135m")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = make_model(cfg)
    print(f"training {cfg.name}: {model.param_count() / 1e3:.0f}k params, "
          f"{args.steps} steps, crash injected at step {args.steps // 2}")

    rng = np.random.default_rng(0)
    data_store = open_store("sims3://data?latency_ms=5&bw_mbps=60", fresh=True)
    for i in range(6):
        data_store.backing.put(
            f"tok{i}.bin", synth_token_shard(rng, 400_000, cfg.vocab_size)
        )
    ckpt_store = open_store("sims3://ckpt?latency_ms=5&bw_mbps=60", fresh=True)

    opt = AdamWConfig(lr=1e-3, total_steps=args.steps,
                      warmup_steps=args.steps // 10)
    base_step = build_train_step(
        model, opt, StepConfig(q_chunk=min(512, args.seq_len),
                               loss_chunk=min(512, args.seq_len))
    )
    jit_step = jax.jit(base_step)

    def train_step(state, inputs, labels):
        return jit_step(state, {"inputs": jnp.asarray(inputs),
                                "labels": jnp.asarray(labels)})

    def make_loader(cursor):
        return PrefetchingDataLoader(
            data_store, data_store.backing.list_objects(),
            [MemTier(8 << 20)],
            LoaderConfig(seq_len=args.seq_len, batch_size=args.batch,
                         policy=IOPolicy(engine="rolling",
                                         blocksize=256 << 10,
                                         eviction_interval_s=0.2)),
            cursor=cursor,
        )

    mgr = RestartManager(ckpt_store, "e2e", ckpt_interval=20,
                         write_policy=IOPolicy(write_depth=4,
                                               blocksize=256 << 10))
    result = run_with_restarts(
        total_steps=args.steps,
        make_initial_state=lambda: init_train_state(model, jax.random.key(0)),
        make_loader=make_loader,
        train_step=train_step,
        restart_mgr=mgr,
        crash_at={args.steps // 2},
    )
    first, last = result.losses[0], result.losses[-1]
    print(f"finished: {result.final_step} steps, {result.restarts} restart(s)")
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'no improvement'})")
    assert result.restarts == 1 and result.final_step == args.steps
    assert last < first, "loss should decrease over training"
    print("OK: crash survived, training converged through the restart")


if __name__ == "__main__":
    main()
