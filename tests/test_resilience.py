"""Resilience-layer tests: RetryPolicy/Retrier/Hedger units, the
LinkModel throttle/failure-cost model, the FaultyStore chaos harness,
and end-to-end chaos runs across read (both engines), write-behind, and
checkpoint save/restore."""

from __future__ import annotations

import threading
import time

import pytest

from repro.core.autotune import AimdDepthController
from repro.core.rolling import RollingPrefetcher, RollingPrefetchFile
from repro.core.sequential import SequentialFile
from repro.io import IOPolicy, PrefetchFS, open_store
from repro.io.retry import Hedger, Retrier, RetryPolicy
from repro.store import (
    FaultSchedule,
    FaultyStore,
    LinkModel,
    MemStore,
    MemTier,
    SimS3Store,
)
from repro.store.base import (
    ObjectMeta,
    StoreError,
    ThrottleError,
    TransientStoreError,
)


def payload(n: int, seed: int = 0) -> bytes:
    return bytes((i * 31 + seed * 7) % 256 for i in range(n))


def make_store(objects: dict[str, bytes], latency=0.0,
               bandwidth=float("inf"), **kw) -> SimS3Store:
    store = SimS3Store(link=LinkModel(latency_s=latency,
                                      bandwidth_Bps=bandwidth, **kw))
    for k, v in objects.items():
        store.backing.put(k, v)
    return store


def metas(store) -> list[ObjectMeta]:
    backing = getattr(store, "backing", None)
    if backing is None:                      # FaultyStore wrapper
        backing = store.inner.backing
    return backing.list_objects()


# --------------------------------------------------------------------------- #
# RetryPolicy / Retrier
# --------------------------------------------------------------------------- #
class TestRetryPolicy:
    def test_full_jitter_bounds(self):
        import random

        pol = RetryPolicy(backoff_s=0.1, backoff_cap_s=10.0)
        rng = random.Random(42)
        for attempt in range(6):
            for _ in range(50):
                d = pol.backoff(attempt, rng)
                assert 0.0 <= d <= 0.1 * (2 ** attempt)

    def test_no_jitter_is_exact_exponential(self):
        import random

        pol = RetryPolicy(backoff_s=0.1, backoff_cap_s=10.0, jitter="none")
        rng = random.Random(0)
        assert [pol.backoff(a, rng) for a in range(4)] == [
            0.1, 0.2, 0.4, 0.8]

    def test_backoff_cap(self):
        import random

        pol = RetryPolicy(backoff_s=1.0, backoff_cap_s=2.0, jitter="none")
        assert pol.backoff(10, random.Random(0)) == 2.0

    def test_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_retries=-1)
        with pytest.raises(ValueError):
            RetryPolicy(jitter="bogus")
        with pytest.raises(ValueError):
            RetryPolicy(deadline_s=0)

    def test_retries_then_succeeds(self):
        calls = []

        def fn():
            calls.append(1)
            if len(calls) < 3:
                raise TransientStoreError("flaky")
            return "ok"

        r = Retrier(RetryPolicy(max_retries=5, backoff_s=0.0))
        assert r.call(fn) == "ok"
        assert len(calls) == 3
        assert r.retries == 2

    def test_exhaustion_raises_storeerror_chained(self):
        def fn():
            raise TransientStoreError("always")

        r = Retrier(RetryPolicy(max_retries=2, backoff_s=0.0))
        with pytest.raises(StoreError, match="exhausted 3 attempts") as ei:
            r.call(fn, label="op")
        assert isinstance(ei.value.__cause__, TransientStoreError)

    def test_permanent_errors_not_retried(self):
        calls = []

        def fn():
            calls.append(1)
            raise StoreError("permanent")

        r = Retrier(RetryPolicy(max_retries=5, backoff_s=0.0))
        with pytest.raises(StoreError, match="permanent"):
            r.call(fn)
        assert len(calls) == 1

    def test_budget_spans_calls(self):
        r = Retrier(RetryPolicy(max_retries=10, backoff_s=0.0, budget=3))

        def fail():
            raise TransientStoreError("x")

        with pytest.raises(StoreError, match="budget"):
            r.call(fail)           # spends the whole budget
        assert r.budget_left == 0
        calls = []

        def fail_once():
            calls.append(1)
            raise TransientStoreError("x")

        # No budget left: a later call gets zero retries.
        with pytest.raises(StoreError, match="budget"):
            r.call(fail_once)
        assert len(calls) == 1

    def test_deadline_stops_early(self):
        fake_now = [0.0]
        sleeps = []
        r = Retrier(
            RetryPolicy(max_retries=100, backoff_s=1.0, backoff_cap_s=1.0,
                        jitter="none", deadline_s=2.5),
            sleep=lambda s: (sleeps.append(s),
                             fake_now.__setitem__(0, fake_now[0] + s)),
            clock=lambda: fake_now[0],
        )

        def fail():
            raise TransientStoreError("x")

        with pytest.raises(StoreError, match="deadline"):
            r.call(fail)
        # Backoffs of 1s each: two fit inside the 2.5s deadline.
        assert len(sleeps) == 2

    def test_on_throttle_fires_even_when_retry_succeeds(self):
        seen = []
        calls = []

        def fn():
            calls.append(1)
            if len(calls) == 1:
                raise ThrottleError("503")
            return "ok"

        r = Retrier(RetryPolicy(max_retries=3, backoff_s=0.0),
                    on_throttle=lambda: seen.append(1))
        assert r.call(fn) == "ok"
        assert seen == [1]
        assert r.throttles == 1

    def test_desynchronized_backoff_regression(self):
        """Satellite: N concurrent streams tripped by the same transient
        fault must not re-collide within one backoff window. The old
        unjittered ``2 ** attempt`` loops put every stream's retry at
        exactly the same instant; full jitter spreads them."""
        n = 8

        def collect(policy: RetryPolicy, seed_base: int) -> list[float]:
            times = []
            for i in range(n):
                sleeps = []
                r = Retrier(policy, seed=seed_base + i,
                            sleep=sleeps.append)
                calls = []

                def fn():
                    calls.append(1)
                    if len(calls) == 1:
                        raise TransientStoreError("shared fault at t=0")
                    return "ok"

                r.call(fn)
                times.append(sleeps[0])   # the stream's first retry time
            return times

        window = 0.1
        sync = collect(RetryPolicy(backoff_s=window, jitter="none"), 0)
        # The storm: all N retries at the identical instant.
        assert len(set(sync)) == 1
        jittered = collect(RetryPolicy(backoff_s=window), 100)
        assert all(0.0 <= t <= window for t in jittered)
        # Spread check: no re-collision — minimum pairwise separation is
        # nonzero and the retries span a real fraction of the window.
        ordered = sorted(jittered)
        gaps = [b - a for a, b in zip(ordered, ordered[1:])]
        assert min(gaps) > 0.0
        assert max(ordered) - min(ordered) > window / 4


# --------------------------------------------------------------------------- #
# Hedger
# --------------------------------------------------------------------------- #
class TestHedger:
    def test_disabled_runs_inline_and_times(self):
        h = Hedger(None)
        result, secs = h.call(lambda: "x")
        assert result == "x" and secs is not None and secs >= 0.0
        assert h.hedges == 0

    def test_hedge_fires_on_straggler_and_withholds_timing(self):
        slow_first = [True]

        def fn():
            if slow_first[0]:
                slow_first[0] = False
                time.sleep(0.2)
            return "x"

        h = Hedger(0.01)
        result, secs = h.call(fn)
        assert result == "x"
        assert secs is None          # hedged sample: timing contaminated
        assert h.hedges == 1

    def test_in_flight_cap(self):
        release = threading.Event()

        def stuck():
            release.wait(5.0)
            return "x"

        h = Hedger(0.01, max_in_flight=1)
        results = []
        threads = [threading.Thread(target=lambda: results.append(h.call(stuck)))
                   for _ in range(4)]
        for t in threads:
            t.start()
        time.sleep(0.3)              # all four primaries straggle
        release.set()
        for t in threads:
            t.join(5.0)
        assert len(results) == 4
        # Only ONE hedge could ever be in flight despite 4 stragglers.
        assert h.hedges <= 1
        assert h.peak_in_flight <= 1

    def test_failure_waits_for_inflight_hedge(self):
        """A failed primary must not raise while the hedged duplicate can
        still rescue the call."""
        calls = []

        def fn():
            calls.append(threading.get_ident())
            if len(calls) == 1:
                time.sleep(0.05)
                raise TransientStoreError("primary fails late")
            return "rescued"

        h = Hedger(0.01)
        result, secs = h.call(fn)
        assert result == "rescued"

    def test_all_attempts_fail_raises(self):
        def fn():
            time.sleep(0.02)
            raise TransientStoreError("down")

        h = Hedger(0.005)
        with pytest.raises(TransientStoreError):
            h.call(fn)


# --------------------------------------------------------------------------- #
# LinkModel: throttle model + honest failure costs
# --------------------------------------------------------------------------- #
class TestLinkModel:
    def test_failed_request_pays_latency(self):
        link = LinkModel(latency_s=0.05)
        link.fail_next(1)
        t0 = time.perf_counter()
        with pytest.raises(TransientStoreError):
            link.transfer(1000)
        assert time.perf_counter() - t0 >= 0.05   # repro: allow[RP008] — lower bound; load only increases elapsed
        assert link.failed_requests == 1
        assert link.requests == 1
        assert link.latency_paid_s >= 0.05
        assert link.bytes_moved == 0

    def test_rps_limit_throttles_burst(self):
        link = LinkModel(rps_limit=5.0, rps_burst=2.0)
        ok, throttled = 0, 0
        for _ in range(10):
            try:
                link.transfer(0)
                ok += 1
            except ThrottleError:
                throttled += 1
        assert ok >= 2               # the burst allowance
        assert throttled >= 1
        assert link.throttled == throttled
        assert link.failed_requests >= throttled

    def test_rps_recovers_after_backoff(self):
        link = LinkModel(rps_limit=50.0, rps_burst=1.0)
        link.transfer(0)
        with pytest.raises(ThrottleError):
            link.transfer(0)
        time.sleep(0.05)             # > 1/rps: a token has refilled
        link.transfer(0)

    def test_sims3_uri_rps_params(self):
        s = open_store(
            "sims3://throttled?rps_limit=100&rps_burst=3&rps_penalty=0.5",
            fresh=True)
        assert s.link.rps_limit == 100.0
        assert s.link.rps_burst == 3.0
        assert s.link.rps_penalty == 0.5

    def test_rps_penalty_escalates_throttling(self):
        # SlowDown escalation: hammering a penalized link drains the
        # bucket below zero, so recovery needs a longer quiet period
        # than the plain token refill — backing off early is cheaper
        # than retrying at pressure.
        def hammer(link, n=6):
            for _ in range(n):
                with pytest.raises(ThrottleError):
                    link.transfer(0)

        plain = LinkModel(rps_limit=20.0, rps_burst=1.0)
        plain.transfer(0)            # spend the burst
        hammer(plain)
        time.sleep(0.06)             # > 1/rps: a token refilled
        plain.transfer(0)            # no penalty: instant recovery

        hot = LinkModel(rps_limit=20.0, rps_burst=1.0, rps_penalty=1.0)
        hot.transfer(0)
        hammer(hot)                  # drains to the -burst floor
        time.sleep(0.06)
        with pytest.raises(ThrottleError):
            hot.transfer(0)          # still in the penalty hole
        time.sleep(0.12)             # (1 + burst)/rps: hole repaid
        hot.transfer(0)

    def test_throttle_is_transient(self):
        assert issubclass(ThrottleError, TransientStoreError)


# --------------------------------------------------------------------------- #
# FaultSchedule / FaultyStore
# --------------------------------------------------------------------------- #
class TestFaultSchedule:
    def test_deterministic_given_seed(self):
        def run(seed):
            sched = FaultSchedule(seed=seed).transient(
                prob=0.5, ops=("get_range",))
            fired = []
            for i in range(50):
                fired.append(bool(sched.decide("get_range", f"k{i}")))
            return fired

        assert run(7) == run(7)
        assert run(7) != run(8)

    def test_every_and_times_and_after(self):
        sched = FaultSchedule().transient(ops=("get_range",), every=3,
                                          times=2, after=1)
        fired = [bool(sched.decide("get_range", "k")) for _ in range(12)]
        # Skip 1, then every 3rd matching request, at most twice.
        assert sum(fired) == 2
        assert fired.index(True) == 3   # requests 2,3,4 -> 3rd match fires

    def test_key_filter(self):
        sched = FaultSchedule().transient(key="shard_3", ops=("get_range",))
        assert not sched.decide("get_range", "shard_1")
        assert sched.decide("get_range", "prefix/shard_3.trk")

    def test_throttle_and_transient_raise(self):
        inner = MemStore()
        inner.put("k", b"abcdef")
        st = FaultyStore(inner, FaultSchedule()
                         .throttle(ops=("get_range",), times=1)
                         .transient(ops=("get_range",), times=1, after=1))
        with pytest.raises(ThrottleError):
            st.get_range("k", 0, 6)
        with pytest.raises(TransientStoreError):
            st.get_range("k", 0, 6)
        assert st.get_range("k", 0, 6) == b"abcdef"
        assert st.snapshot()["throttle"] == 1
        assert st.snapshot()["transient"] == 1

    def test_truncate_and_corrupt_shapes(self):
        inner = MemStore()
        inner.put("k", payload(64))
        st = FaultyStore(inner, FaultSchedule()
                         .truncate(nbytes=16, ops=("get_range",), times=1))
        assert st.get_range("k", 0, 64) == payload(64)[:-16]
        assert st.get_range("k", 0, 64) == payload(64)

        st2 = FaultyStore(inner, FaultSchedule(seed=3)
                          .corrupt(ops=("get_range",), times=1))
        bad = st2.get_range("k", 0, 64)
        assert bad != payload(64) and len(bad) == 64
        # Exactly one byte differs.
        assert sum(a != b for a, b in zip(bad, payload(64))) == 1

    def test_stall_delays(self):
        inner = MemStore()
        inner.put("k", b"x")
        st = FaultyStore(inner, FaultSchedule().stall(0.05, times=1))
        t0 = time.perf_counter()
        st.get_range("k", 0, 1)
        assert time.perf_counter() - t0 >= 0.05   # repro: allow[RP008] — lower bound; load only increases elapsed

    def test_cut_pays_partial_bandwidth(self):
        store = make_store({"k": payload(4096)})
        st = FaultyStore(store, FaultSchedule()
                         .cut(after_bytes=1000, ops=("get_range",), times=1))
        with pytest.raises(TransientStoreError, match="cut"):
            st.get_range("k", 0, 4096)
        # The partial transfer crossed the simulated link for real.
        assert store.link.bytes_moved == 1000
        assert st.get_range("k", 0, 4096) == payload(4096)

    def test_get_ranges_payload_fault_on_last_span(self):
        inner = MemStore()
        inner.put("k", payload(100))
        st = FaultyStore(inner, FaultSchedule()
                         .truncate(nbytes=5, ops=("get_ranges",), times=1))
        out = st.get_ranges("k", [(0, 10), (10, 30)])
        assert out[0] == payload(100)[0:10]
        assert out[1] == payload(100)[10:25]   # tail truncated

    def test_multipart_faults(self):
        inner = MemStore()
        st = FaultyStore(inner, FaultSchedule()
                         .transient(ops=("put_part",), times=1))
        mp = st.start_multipart("k")
        with pytest.raises(TransientStoreError):
            mp.put_part(0, b"aa")
        mp.put_part(0, b"aa")
        mp.put_part(1, b"bb")
        mp.complete()
        assert inner.get("k") == b"aabb"


# --------------------------------------------------------------------------- #
# AIMD throttle feedback
# --------------------------------------------------------------------------- #
class TestThrottleAimd:
    def test_on_throttle_halves_target(self):
        c = AimdDepthController(8, 16, throttle_cooldown_s=0.0)
        assert c.on_throttle() == 4
        assert c.on_throttle() == 2
        assert c.on_throttle() == 1
        assert c.on_throttle() == 1

    def test_throttle_cooldown_coalesces_bursts(self):
        # One halving per cooldown window (TCP's one-cut-per-RTT rule):
        # 8 streams throttled by the same pressure burst must count as
        # ONE signal, not 8 halvings straight to the floor.
        c = AimdDepthController(8, 16, throttle_cooldown_s=1.0)
        assert c.on_throttle(now=10.0) == 4
        assert c.on_throttle(now=10.1) == 4   # within cooldown: coalesced
        assert c.on_throttle(now=10.9) == 4
        assert c.throttle_cuts == 1
        assert c.on_throttle(now=11.1) == 2   # new window: cuts again
        assert c.throttle_cuts == 2

    def test_rolling_engine_shrinks_depth_on_throttle(self):
        objects = {"a": payload(64 << 10)}
        store = make_store(objects)
        sched = FaultSchedule().throttle(ops=("get_range", "get_ranges"),
                                         every=4)
        pf = RollingPrefetcher(
            FaultyStore(store, sched), metas(store), [MemTier(1 << 20)],
            blocksize=2048, depth=8, max_depth=8,
            retry=RetryPolicy(max_retries=8, backoff_s=0.001),
            eviction_interval_s=0.01,
        )
        f = RollingPrefetchFile(pf)
        assert f.read() == objects["a"]
        f.close()
        assert pf.stats.throttles > 0
        # Backend pushback reached the depth controller.
        assert pf._aimd.target < 8

    def test_throttle_oblivious_mode_keeps_depth(self):
        objects = {"a": payload(32 << 10)}
        store = make_store(objects)
        sched = FaultSchedule().throttle(ops=("get_range", "get_ranges"),
                                         every=5)
        throttle_cuts = []
        pf = RollingPrefetcher(
            FaultyStore(store, sched), metas(store), [MemTier(1 << 20)],
            blocksize=2048, depth=4, max_depth=4, throttle_aimd=False,
            retry=RetryPolicy(max_retries=8, backoff_s=0.001),
            eviction_interval_s=0.01,
        )
        pf._aimd.on_throttle = lambda: throttle_cuts.append(1)  # spy
        f = RollingPrefetchFile(pf)
        assert f.read() == objects["a"]
        f.close()
        assert pf.stats.throttles > 0
        # Oblivious: throttles retried, but none reached the controller
        # (the throughput-window AIMD still runs — that is the point of
        # the A/B: backoff alone, no pushback-driven cut).
        assert not throttle_cuts


# --------------------------------------------------------------------------- #
# End-to-end chaos
# --------------------------------------------------------------------------- #
def chaos_schedule(seed: int = 11) -> FaultSchedule:
    """The standard mixed read-fault script: throttles, transients,
    stalls, truncations, and mid-transfer cuts. Corruption faults live
    in `tests/test_integrity.py`: with verify-on-read (the
    ``IOPolicy.verify`` digest layer) they are detected and healed like
    any other transient."""
    return (FaultSchedule(seed=seed)
            .throttle(ops=("get_range", "get_ranges"), prob=0.08)
            .transient(ops=("get_range", "get_ranges", "get"), prob=0.08)
            .stall(0.002, ops=("get_range", "get_ranges"), prob=0.1)
            .truncate(nbytes=7, ops=("get_range", "get_ranges"), prob=0.05)
            .cut(after_bytes=512, ops=("get_range", "get_ranges"), prob=0.05))


class TestChaosEndToEnd:
    RETRY = RetryPolicy(max_retries=10, backoff_s=0.001, backoff_cap_s=0.01)

    def _dataset(self):
        return {f"f{i}": payload(20_000, seed=i) for i in range(3)}

    def test_rolling_survives_chaos_byte_identical(self):
        objects = self._dataset()
        store = FaultyStore(make_store(objects), chaos_schedule())
        want = b"".join(objects[m.key] for m in metas(store))
        fs = PrefetchFS(store, policy=IOPolicy(
            engine="rolling", blocksize=4096, depth=2,
            retry=self.RETRY, eviction_interval_s=0.01))
        with fs:
            f = fs.open_many(metas(store))
            assert f.read() == want
            f.close()
            snap = fs.stats().snapshot()
        assert snap["totals"]["retries"] > 0
        assert store.schedule.total_fired() > 0

    def test_sequential_survives_chaos_byte_identical(self):
        """Satellite regression: pre-resilience-layer the sequential
        engine propagated the FIRST transient fault."""
        objects = self._dataset()
        store = FaultyStore(make_store(objects), chaos_schedule(seed=13))
        want = b"".join(objects[m.key] for m in metas(store))
        f = SequentialFile(store, metas(store), blocksize=4096,
                           retry=self.RETRY)
        assert f.read() == want
        assert f.stats.retries > 0
        f.close()

    def test_sequential_single_fault_regression(self):
        objects = {"a": payload(4096)}
        store = make_store(objects)
        store.link.fail_next(1)
        f = SequentialFile(store, metas(store), blocksize=1024)
        # Old behaviour: TransientStoreError propagated to the caller.
        assert f.read() == objects["a"]
        assert f.stats.retries == 1

    def test_both_engines_same_schedule_same_bytes(self):
        objects = self._dataset()
        want = b"".join(v for _, v in sorted(objects.items()))
        for engine in ("rolling", "sequential"):
            store = FaultyStore(make_store(objects), chaos_schedule(seed=29))
            fs = PrefetchFS(store, policy=IOPolicy(
                engine=engine, blocksize=2048, retry=self.RETRY,
                eviction_interval_s=0.01))
            with fs:
                f = fs.open_many(metas(store))
                assert f.read() == want, engine
                f.close()

    def test_write_behind_survives_chaos(self):
        store = FaultyStore(
            make_store({}),
            FaultSchedule(seed=5)
            .throttle(ops=("put_part",), prob=0.15)
            .transient(ops=("put_part", "complete", "put"), prob=0.15)
            .stall(0.002, ops=("put_part",), prob=0.2))
        data = payload(100_000, seed=9)
        fs = PrefetchFS(store, policy=IOPolicy(
            blocksize=8192, write_depth=4, retry=self.RETRY))
        with fs:
            w = fs.open_write("out/key")
            for off in range(0, len(data), 3000):
                w.write(data[off:off + 3000])
            w.close()
            assert w.stats.snapshot()["retries"] > 0
        assert store.inner.backing.get("out/key") == data

    def test_ckpt_save_restore_under_chaos(self):
        import numpy as np

        from repro.ckpt.manager import restore_checkpoint, save_checkpoint

        sched = (FaultSchedule(seed=23)
                 .transient(ops=("put", "put_part", "complete"), prob=0.1)
                 .throttle(ops=("size", "list_objects"), prob=0.1)
                 .transient(ops=("get_range", "get_ranges", "get"), prob=0.1))
        store = FaultyStore(make_store({}), sched)
        state = {"w": np.arange(4096, dtype=np.float32).reshape(64, 64),
                 "b": np.ones((257,), dtype=np.float32)}
        pol = IOPolicy(blocksize=4096, retry=self.RETRY,
                       eviction_interval_s=0.01)
        save_checkpoint(store, "ckpt", 3, state, policy=pol)
        restored, manifest = restore_checkpoint(store, "ckpt", state,
                                                policy=pol)
        assert manifest["step"] == 3
        np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])
        np.testing.assert_array_equal(np.asarray(restored["b"]), state["b"])
        assert sched.total_fired() > 0

    def test_no_leaked_threads_after_close(self):
        objects = self._dataset()
        store = FaultyStore(
            make_store(objects, latency=0.002),
            FaultSchedule(seed=31)
            .stall(0.02, ops=("get_range", "get_ranges"), prob=0.3)
            .transient(ops=("get_range", "get_ranges"), prob=0.1))
        fs = PrefetchFS(store, policy=IOPolicy(
            engine="rolling", blocksize=4096, depth=3,
            hedge_timeout_s=0.005, max_hedges=2, retry=self.RETRY,
            eviction_interval_s=0.01))
        with fs:
            f = fs.open_many(metas(store))
            want = b"".join(objects[m.key] for m in metas(store))
            assert f.read() == want
            f.close()
        # Hedge attempts are daemon threads bounded by the in-flight
        # cap; after close everything drains (store calls complete).
        deadline = time.time() + 5.0
        while time.time() < deadline:
            leaked = [t for t in threading.enumerate()
                      if t.name.startswith(("rp-", "hedge-"))
                      and t.is_alive()]
            if not leaked:
                break
            time.sleep(0.02)
        assert not leaked, leaked

    def test_hedges_bounded_under_systemic_slowdown(self):
        objects = {"a": payload(64 << 10)}
        store = FaultyStore(
            make_store(objects, latency=0.005),
            FaultSchedule(seed=37).stall(0.03, ops=("get_range",
                                                    "get_ranges"), prob=1.0))
        pf = RollingPrefetcher(
            store, metas(store), [MemTier(1 << 20)], blocksize=4096,
            depth=4, hedge_timeout_s=0.002, max_hedges=2,
            retry=self.RETRY, eviction_interval_s=0.01,
        )
        f = RollingPrefetchFile(pf)
        assert f.read() == objects["a"]
        f.close()
        # EVERY request straggled, but duplicates stayed capped.
        assert pf._hedger.peak_in_flight <= 2
        assert pf.stats.hedges == pf._hedger.hedges

    def test_writer_upload_pool_drains_after_chaos_close(self):
        store = FaultyStore(
            make_store({}),
            FaultSchedule(seed=41).transient(ops=("put_part",), prob=0.2))
        fs = PrefetchFS(store, policy=IOPolicy(blocksize=2048,
                                               write_depth=3,
                                               retry=self.RETRY))
        with fs:
            for i in range(4):
                w = fs.open_write(f"k{i}")
                w.write(payload(10_000, seed=i))
                w.close_async()
                w.join()
        deadline = time.time() + 5.0
        while time.time() < deadline:
            leaked = [t for t in threading.enumerate()
                      if t.name.startswith("fs-upload") and t.is_alive()]
            if not leaked:
                break
            time.sleep(0.02)
        assert not leaked, leaked
        for i in range(4):
            assert store.inner.backing.get(f"k{i}") == payload(10_000, seed=i)

    def test_truncated_response_detected_and_retried(self):
        objects = {"a": payload(8192)}
        store = FaultyStore(
            make_store(objects),
            FaultSchedule().truncate(nbytes=3, ops=("get_range",), times=1))
        pf = RollingPrefetcher(store, metas(store), [MemTier(1 << 20)],
                               blocksize=2048, retry=self.RETRY,
                               eviction_interval_s=0.01)
        f = RollingPrefetchFile(pf)
        assert f.read() == objects["a"]   # NOT silently short
        f.close()
        assert pf.stats.retries >= 1


# --------------------------------------------------------------------------- #
# Chaos through the peer transport
# --------------------------------------------------------------------------- #
class TestPeerChaos:
    """`FaultSchedule` rules routed through the ``peer_*`` ops hit the
    `PeerClient` transport (see `repro.peer.protocol.PEER_OPS`): peer
    stalls, transient refusals, mid-transfer cuts, and dead heartbeats
    must all degrade to direct store GETs — byte-identical reads, zero
    errors surfaced to readers."""

    N_HOSTS = 3
    BLOCKSIZE = 4096

    def _dataset(self):
        return {f"p{i}": payload(16_384, seed=i) for i in range(3)}

    def _read_all_hosts(self, cluster, objects):
        want = b"".join(objects[k] for k in sorted(objects))
        outs, errors = {}, []

        def run(h):
            try:
                host = cluster.host(h)
                fs = host.open_fs(IOPolicy(
                    engine="rolling", blocksize=self.BLOCKSIZE, depth=2,
                    keep_cached=True, eviction_interval_s=0.05))
                files = sorted(host.store.list_objects(),
                               key=lambda m: m.key)
                f = fs.open_many(files)
                try:
                    outs[h] = f.read()
                finally:
                    f.close()
            except BaseException as e:  # repro: allow[RP005] — stashed; asserted after join
                errors.append((h, e))

        threads = [threading.Thread(target=run, args=(h,))
                   for h in range(self.N_HOSTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors
        for h in range(self.N_HOSTS):
            assert outs[h] == want, f"host {h} bytes diverged under chaos"
        return outs

    def _cluster(self, objects, faults, **kw):
        from repro.peer.sim import SimCluster

        backing = MemStore()
        for k, v in objects.items():
            backing.put(k, v)
        return SimCluster(self.N_HOSTS, backing, faults=faults, **kw)

    def test_peer_transients_degrade_byte_identical(self):
        objects = self._dataset()
        sched = FaultSchedule(seed=17).transient(ops=("peer_fetch",),
                                                 prob=0.3)
        cluster = self._cluster(objects, sched)
        try:
            self._read_all_hosts(cluster, objects)
            failures = sum(
                cluster.host(h).store.peer_snapshot()["group"]["rpc_failures"]
                for h in range(self.N_HOSTS))
            assert failures > 0          # the chaos actually landed
            assert sched.total_fired() > 0
        finally:
            cluster.close()

    def test_peer_stalls_within_rpc_timeout(self):
        objects = self._dataset()
        sched = FaultSchedule(seed=19).stall(0.005, ops=("peer_fetch",),
                                             prob=0.3)
        cluster = self._cluster(objects, sched)
        try:
            self._read_all_hosts(cluster, objects)
            assert sched.total_fired() > 0
        finally:
            cluster.close()

    def test_peer_cut_mid_transfer_rereads_identically(self):
        """A cut declares the connection dead AFTER the bytes crossed the
        wire: the retry (or the store fallback) must observe the same
        bytes — no torn or duplicated block may reach a reader."""
        objects = self._dataset()
        sched = FaultSchedule(seed=23).cut(after_bytes=512,
                                           ops=("peer_fetch",), prob=0.25)
        cluster = self._cluster(objects, sched)
        try:
            self._read_all_hosts(cluster, objects)
            assert sched.total_fired() > 0
        finally:
            cluster.close()

    def test_dead_heartbeats_fail_everything_over_to_the_store(self):
        """Every heartbeat ping fails: all siblings get marked dead, all
        remote-owned blocks degrade to direct backing GETs, and the reads
        stay exact."""
        objects = self._dataset()
        sched = FaultSchedule(seed=29).transient(ops=("peer_ping",),
                                                 prob=1.0)
        cluster = self._cluster(objects, sched,
                                heartbeat_interval_s=0.02, miss_limit=2)
        try:
            deadline = time.time() + 10.0
            while time.time() < deadline:
                if all(cluster.host(h).group.alive_ids() == [h]
                       for h in range(self.N_HOSTS)):
                    break
                time.sleep(0.02)
            self._read_all_hosts(cluster, objects)
            for h in range(self.N_HOSTS):
                snap = cluster.host(h).store.peer_snapshot()
                # Nothing remote-owned was served by a peer...
                assert snap["peer_hits"] == 0
                # ...every read degraded to the backing store.
                assert (snap["dead_peer_fallbacks"] > 0
                        or snap["local_fetches"] > 0)
        finally:
            cluster.close()

    def test_mixed_peer_chaos_with_store_chaos(self):
        """Peer faults AND backing-store faults at once: the peer layer
        degrades to the store, the store's own retry machinery absorbs
        its faults, and the bytes stay exact."""
        objects = self._dataset()
        sched = (FaultSchedule(seed=31)
                 .transient(ops=("peer_fetch",), prob=0.2)
                 .stall(0.002, ops=("peer_fetch",), prob=0.2)
                 .cut(after_bytes=256, ops=("peer_fetch",), prob=0.1))
        backing = FaultyStore(
            make_store(objects),
            FaultSchedule(seed=37).transient(
                ops=("get_range", "get_ranges"), prob=0.1))
        from repro.peer.sim import SimCluster

        cluster = SimCluster(self.N_HOSTS, backing, faults=sched)
        try:
            self._read_all_hosts(cluster, objects)
            assert sched.total_fired() > 0
        finally:
            cluster.close()
