"""Unit + property tests for the Rolling Prefetch core (paper §II-A)."""

from __future__ import annotations

import threading
import time

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BlockPlan,
    BlockState,
    RollingPrefetcher,
    RollingPrefetchFile,
    SequentialFile,
)
from repro.store import LinkModel, MemTier, SimS3Store
from repro.store.base import ObjectMeta, StoreError


def make_store(objects: dict[str, bytes], latency=0.0, bandwidth=float("inf"), **kw):
    store = SimS3Store(link=LinkModel(latency_s=latency, bandwidth_Bps=bandwidth, **kw))
    for k, v in objects.items():
        store.backing.put(k, v)
    return store


def payload(n: int, seed: int = 0) -> bytes:
    # Deterministic, position-dependent bytes so offset bugs surface.
    return bytes((i * 31 + seed * 7) % 256 for i in range(n))


def metas(store) -> list[ObjectMeta]:
    return store.backing.list_objects()


# --------------------------------------------------------------------------- #
# BlockPlan
# --------------------------------------------------------------------------- #
class TestBlockPlan:
    def test_blocks_cover_stream_exactly(self):
        files = [ObjectMeta("a", 100), ObjectMeta("b", 64), ObjectMeta("c", 1)]
        plan = BlockPlan(files, blocksize=64)
        assert plan.total_bytes == 165
        # Coverage: concatenation of all block ranges == the whole stream.
        pos = 0
        for b in plan.blocks:
            assert b.global_start == pos
            pos = b.global_end
        assert pos == plan.total_bytes
        # Blocks never span files.
        for b in plan.blocks:
            assert b.end <= files[b.file_index].size

    def test_block_at(self):
        files = [ObjectMeta("a", 100), ObjectMeta("b", 50)]
        plan = BlockPlan(files, blocksize=30)
        for off in [0, 29, 30, 99, 100, 149]:
            b = plan.block_at(off)
            assert b.global_start <= off < b.global_end
        with pytest.raises(IndexError):
            plan.block_at(150)

    @given(
        sizes=st.lists(st.integers(1, 500), min_size=1, max_size=8),
        blocksize=st.integers(1, 200),
    )
    @settings(max_examples=50, deadline=None)
    def test_plan_properties(self, sizes, blocksize):
        files = [ObjectMeta(f"f{i}", s) for i, s in enumerate(sizes)]
        plan = BlockPlan(files, blocksize)
        assert plan.total_bytes == sum(sizes)
        assert all(1 <= b.size <= blocksize for b in plan.blocks)
        ids = [b.block_id for b in plan.blocks]
        assert len(set(ids)) == len(ids)  # block ids unique


# --------------------------------------------------------------------------- #
# Rolling Prefetch engine
# --------------------------------------------------------------------------- #
class TestRollingPrefetch:
    def test_reads_are_byte_identical(self):
        objects = {f"f{i}": payload(1000 + i * 37, seed=i) for i in range(4)}
        store = make_store(objects)
        tiers = [MemTier(capacity=4096)]
        with RollingPrefetchFile.open(
            store, metas(store), tiers, blocksize=256, eviction_interval_s=0.01
        ) as f:
            got = f.read()
        want = b"".join(objects[m.key] for m in metas(store))
        assert got == want

    def test_chunked_reads_match_full_read(self):
        objects = {"a": payload(5000), "b": payload(3000, seed=1)}
        store = make_store(objects)
        with RollingPrefetchFile.open(
            store, metas(store), [MemTier(8192)], blocksize=512,
            eviction_interval_s=0.01,
        ) as f:
            chunks = []
            while True:
                chunk = f.read(777)
                if not chunk:
                    break
                chunks.append(chunk)
        assert b"".join(chunks) == payload(5000) + payload(3000, seed=1)

    def test_cache_budget_never_exceeded(self):
        """The paper's core guarantee: bounded local footprint even when the
        dataset is much larger than the cache."""
        objects = {f"f{i}": payload(2048, seed=i) for i in range(8)}  # 16 KiB
        store = make_store(objects)
        tier = MemTier(capacity=1024)  # 4 blocks of 256
        peak = [0]
        stop = threading.Event()

        def monitor():
            while not stop.is_set():
                peak[0] = max(peak[0], tier.used)
                time.sleep(0.0005)

        t = threading.Thread(target=monitor, daemon=True)
        t.start()
        with RollingPrefetchFile.open(
            store, metas(store), [tier], blocksize=256, eviction_interval_s=0.001
        ) as f:
            data = f.read()
        stop.set()
        t.join()
        assert len(data) == 8 * 2048
        assert peak[0] <= 1024
        assert tier.used == 0  # final sweep cleaned everything

    def test_dataset_larger_than_cache_streams_through(self):
        objects = {f"f{i}": payload(4096, seed=i) for i in range(4)}
        store = make_store(objects)
        tier = MemTier(capacity=512)  # far smaller than 16 KiB dataset
        with RollingPrefetchFile.open(
            store, metas(store), [tier], blocksize=256, eviction_interval_s=0.001
        ) as f:
            want = b"".join(objects[m.key] for m in metas(store))
            assert f.read() == want

    def test_multi_tier_spill(self):
        """When tier 0 fills, blocks go to tier 1 (priority order)."""
        objects = {"a": payload(4096)}
        store = make_store(objects)
        t0, t1 = MemTier(capacity=256, name="t0"), MemTier(capacity=4096, name="t1")
        pf = RollingPrefetcher(
            store, metas(store), [t0, t1], blocksize=256,
            eviction_interval_s=10.0,  # effectively no eviction during test
        )
        with pf:
            # Wait until prefetching stalls or finishes.
            deadline = time.time() + 5.0
            while time.time() < deadline:
                states = [i.state for i in pf._info]
                if sum(s == BlockState.CACHED for s in states) >= 8:
                    break
                time.sleep(0.005)
            cached_tiers = {i.tier.name for i in pf._info if i.tier is not None}
            assert "t1" in cached_tiers  # spilled beyond tier 0
            data = pf.read_range(0, 4096)
            assert data == payload(4096)

    def test_eviction_marks_and_frees(self):
        objects = {"a": payload(1024)}
        store = make_store(objects)
        tier = MemTier(capacity=2048)
        pf = RollingPrefetcher(
            store, metas(store), [tier], blocksize=256, eviction_interval_s=0.001
        )
        with pf:
            pf.read_range(0, 1024)
            deadline = time.time() + 5.0
            while time.time() < deadline and pf.stats.blocks_evicted < 4:
                time.sleep(0.005)
            assert pf.stats.blocks_evicted == 4

    def test_seek_forward_and_tell(self):
        objects = {"a": payload(1000), "b": payload(1000, seed=2)}
        store = make_store(objects)
        with RollingPrefetchFile.open(
            store, metas(store), [MemTier(4096)], blocksize=128,
            eviction_interval_s=0.01,
        ) as f:
            f.seek(500)
            assert f.tell() == 500
            got = f.read(700)
            want = (payload(1000) + payload(1000, seed=2))[500:1200]
            assert got == want

    def test_backward_seek_after_eviction_falls_back_to_direct_read(self):
        objects = {"a": payload(1024)}
        store = make_store(objects)
        with RollingPrefetchFile.open(
            store, metas(store), [MemTier(4096)], blocksize=128,
            eviction_interval_s=0.001,
        ) as f:
            first = f.read(512)
            time.sleep(0.1)  # let eviction claim consumed blocks
            f.seek(0)
            again = f.read(512)
            assert first == again
        assert f.stats.direct_reads >= 1

    def test_transient_failures_are_retried(self):
        objects = {"a": payload(2048)}
        store = make_store(objects)
        store.link.fail_next(2)
        with RollingPrefetchFile.open(
            store, metas(store), [MemTier(4096)], blocksize=512,
            eviction_interval_s=0.01, max_retries=5, retry_backoff_s=0.001,
        ) as f:
            assert f.read() == payload(2048)
        assert f.stats.retries >= 2

    def test_permanent_failure_raises(self):
        objects = {"a": payload(2048)}
        store = make_store(objects)
        store.link.fail_next(100)
        with RollingPrefetchFile.open(
            store, metas(store), [MemTier(4096)], blocksize=512,
            eviction_interval_s=0.01, max_retries=1, retry_backoff_s=0.001,
        ) as f:
            with pytest.raises(StoreError):
                f.read()

    def test_depth_gt_one_still_correct(self):
        objects = {f"f{i}": payload(3000, seed=i) for i in range(3)}
        store = make_store(objects, latency=0.002)
        with RollingPrefetchFile.open(
            store, metas(store), [MemTier(16384)], blocksize=512,
            depth=4, eviction_interval_s=0.01,
        ) as f:
            want = b"".join(objects[m.key] for m in metas(store))
            assert f.read() == want

    def test_hedged_fetch_fires_on_straggler(self):
        objects = {"a": payload(4096)}
        store = make_store(objects, latency=0.05)
        with RollingPrefetchFile.open(
            store, metas(store), [MemTier(8192)], blocksize=1024,
            hedge_timeout_s=0.01, eviction_interval_s=0.01,
        ) as f:
            assert f.read() == payload(4096)
        assert f.stats.hedges >= 1

    @given(
        nfiles=st.integers(1, 4),
        size=st.integers(1, 2000),
        blocksize=st.integers(1, 512),
        readsize=st.integers(1, 999),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_stream_integrity(self, nfiles, size, blocksize, readsize):
        """Any (files, blocksize, read-chunking) combination returns exactly
        the concatenated object bytes."""
        objects = {f"f{i}": payload(size, seed=i) for i in range(nfiles)}
        store = make_store(objects)
        with RollingPrefetchFile.open(
            store, metas(store), [MemTier(max(blocksize * 4, 2048))],
            blocksize=blocksize, eviction_interval_s=0.001,
        ) as f:
            got = bytearray()
            while True:
                chunk = f.read(readsize)
                if not chunk:
                    break
                got.extend(chunk)
        assert bytes(got) == b"".join(objects[m.key] for m in metas(store))

    def test_reserve_failure_fails_group_without_leaking_flights(self):
        # _reserve runs eviction I/O; if it raises with the group's
        # flights registered, those flights must be aborted — a leaked
        # flight parks every waiter (this reader included) until the
        # reclaim TTL.
        objects = {"a": payload(1024)}
        store = make_store(objects)
        pf = RollingPrefetcher(
            store, metas(store), [MemTier(4096)], blocksize=256,
            eviction_interval_s=10.0,
        )

        def broken_reserve(nbytes):
            raise RuntimeError("eviction I/O exploded")

        pf._reserve = broken_reserve
        with pf:
            with pytest.raises(StoreError):
                pf.read_range(0, 256)
            # Every flight the failed group registered was aborted.
            assert not pf.index._flights
            failed = [i for i in pf._info if i.state == BlockState.FAILED]
            assert failed and all(i.error is not None for i in failed)


# --------------------------------------------------------------------------- #
# Sequential baseline equivalence
# --------------------------------------------------------------------------- #
class TestSequentialFile:
    def test_matches_rolling_output(self):
        objects = {f"f{i}": payload(1500, seed=i) for i in range(3)}
        store = make_store(objects)
        seq = SequentialFile(store, metas(store), blocksize=400)
        data_seq = seq.read()
        with RollingPrefetchFile.open(
            store, metas(store), [MemTier(8192)], blocksize=400,
            eviction_interval_s=0.01,
        ) as f:
            data_pf = f.read()
        assert data_seq == data_pf

    def test_no_overlap_costs_are_serial(self):
        """With latency only on the store link, the sequential file pays one
        latency per block fetched."""
        objects = {"a": payload(4096)}
        store = make_store(objects, latency=0.01)
        seq = SequentialFile(store, metas(store), blocksize=1024)
        t0 = time.perf_counter()
        seq.read()
        elapsed = time.perf_counter() - t0
        assert seq.stats.blocks_fetched == 4
        assert elapsed >= 4 * 0.01


# --------------------------------------------------------------------------- #
# Overlap actually happens (the paper's central claim, miniaturized)
# --------------------------------------------------------------------------- #
class TestOverlap:
    def test_prefetch_overlaps_compute(self):
        """With per-block cloud time ~= per-block compute time, rolling
        prefetch should approach 2x over sequential (Eq. 3)."""
        nbytes, nblocks = 64 * 1024, 16
        blocksize = nbytes // nblocks
        per_block_cloud = 0.02
        objects = {"a": payload(nbytes)}

        def run_sequential():
            store = make_store(objects, latency=per_block_cloud)
            f = SequentialFile(store, metas(store), blocksize=blocksize)
            t0 = time.perf_counter()
            while True:
                chunk = f.read(blocksize)
                if not chunk:
                    break
                time.sleep(per_block_cloud)  # "compute"
            return time.perf_counter() - t0

        def run_rolling():
            store = make_store(objects, latency=per_block_cloud)
            with RollingPrefetchFile.open(
                store, metas(store), [MemTier(nbytes)], blocksize=blocksize,
                eviction_interval_s=0.005,
            ) as f:
                t0 = time.perf_counter()
                while True:
                    chunk = f.read(blocksize)
                    if not chunk:
                        break
                    time.sleep(per_block_cloud)  # "compute"
                return time.perf_counter() - t0

        t_seq = run_sequential()
        t_pf = run_rolling()
        speedup = t_seq / t_pf
        # Theory bound is <2; require clear overlap, not an exact value.
        assert speedup > 1.3, f"expected overlap speedup, got {speedup:.2f}"
        assert speedup < 2.2
