"""Elastic scaling: re-shard a live (or restored) state onto a new mesh.

Checkpoints store logical arrays (full shapes); restore targets carry the
NEW topology's shardings, so growing 256 -> 512 chips (or shrinking after
losing a pod) is a restore with a different rules/mesh pair — no format
change. This module also reshards in-memory trees for mid-job elasticity.
"""

from __future__ import annotations

import jax

from repro.models.spec import param_shardings
from repro.sharding.rules import ShardingRules


def reshard_tree(tree, shardings):
    """device_put every leaf onto the paired sharding (None = replicate)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings
    )


def reshard_params(params, spec_tree, rules: ShardingRules):
    """Re-shard a parameter tree onto `rules.mesh` per the declarative spec."""
    return reshard_tree(params, param_shardings(spec_tree, rules))
