"""Pallas TPU kernels for the framework's compute hot spots:

* flash_attention — blocked online-softmax attention (serving/prefill path)
* ssd_scan — fused Mamba-2 SSD chunked scan (mamba2/jamba cells)

Each kernel ships with a jit wrapper (ops.py) and a pure-jnp oracle
(ref.py); tests sweep shapes/dtypes in interpret mode on CPU.
"""

from repro.kernels.ops import flash_attention, ssd_scan

__all__ = ["flash_attention", "ssd_scan"]
