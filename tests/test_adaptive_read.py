"""Tests for the adaptive prefetch scheduler: vectorized `get_ranges`,
coalesced fetches, readahead-horizon bounds, AIMD depth control, and the
closed autotune loop (PR: adaptive prefetch scheduling)."""

from __future__ import annotations

import time

import pytest

from repro.core import cost_model
from repro.core.autotune import AimdDepthController, BlockSizeTuner
from repro.core.rolling import BlockState, RollingPrefetcher
from repro.core.sequential import SequentialFile
from repro.io import IOPolicy, PrefetchFS
from repro.store import DirStore, LinkModel, MemStore, MemTier, SimS3Store
from repro.store.base import ObjectMeta, StoreError, adjacent_runs


def payload(n: int, seed: int = 0) -> bytes:
    return bytes((i * 31 + seed * 7) % 256 for i in range(n))


def make_store(objects, latency=0.0, bandwidth=float("inf"), **kw):
    store = SimS3Store(
        link=LinkModel(latency_s=latency, bandwidth_Bps=bandwidth, **kw)
    )
    for k, v in objects.items():
        store.backing.put(k, v)
    return store


def metas(store) -> list[ObjectMeta]:
    return store.backing.list_objects()


# --------------------------------------------------------------------------- #
# vectorized store API
# --------------------------------------------------------------------------- #
SPAN_SETS = [
    [(0, 100)],
    [(0, 64), (64, 128), (128, 200)],          # one adjacent run
    [(0, 50), (100, 150), (150, 160), (400, 401)],  # mixed runs
    [(10, 10), (10, 40)],                      # empty span
    [(500, 600), (0, 100)],                    # out of order
]


class TestGetRanges:
    @pytest.fixture(params=["mem", "dir", "sims3"])
    def store(self, request, tmp_path):
        data = payload(1000)
        if request.param == "mem":
            s = MemStore()
        elif request.param == "dir":
            s = DirStore(str(tmp_path / "store"))
        else:
            s = SimS3Store(link=LinkModel())
        s.put("obj", data)
        return s

    @pytest.mark.parametrize("spans", SPAN_SETS)
    def test_parity_with_per_span_get_range(self, store, spans):
        want = [store.get_range("obj", a, b) for a, b in spans]
        assert store.get_ranges("obj", spans) == want

    def test_missing_key_raises(self, store):
        with pytest.raises(StoreError):
            store.get_ranges("nope", [(0, 1)])

    def test_whole_get_parity(self, store):
        assert store.get("obj") == payload(1000)

    def test_whole_get_missing_raises(self, store):
        with pytest.raises(StoreError):
            store.get("nope")

    def test_adjacent_runs_grouping(self):
        runs = adjacent_runs([(0, 4), (4, 8), (9, 12), (12, 13), (0, 2)])
        assert runs == [[(0, 4), (4, 8)], [(9, 12), (12, 13)], [(0, 2)]]

    def test_sims3_coalesces_adjacent_spans_into_one_request(self):
        store = make_store({"obj": payload(4096)})
        r0 = store.link.requests
        store.get_ranges("obj", [(0, 512), (512, 1024), (1024, 1536)])
        assert store.link.requests - r0 == 1
        assert store.link.coalesced_requests == 1
        assert store.link.spans_served >= 3

    def test_sims3_nonadjacent_spans_pay_per_run(self):
        store = make_store({"obj": payload(4096)})
        r0 = store.link.requests
        store.get_ranges("obj", [(0, 512), (1024, 1536), (1536, 2048)])
        assert store.link.requests - r0 == 2  # two adjacent runs

    def test_sims3_whole_get_is_one_request(self):
        """The old default paid a HEAD (size) plus a ranged GET — two
        latencies per object; whole-object gets are now one request."""
        store = make_store({"obj": payload(4096)})
        r0 = store.link.requests
        assert store.get("obj") == payload(4096)
        assert store.link.requests - r0 == 1


# --------------------------------------------------------------------------- #
# coalesced prefetch correctness
# --------------------------------------------------------------------------- #
class TestCoalescedPrefetch:
    def test_coalesced_run_bytes_identical_and_fewer_requests(self):
        objects = {f"f{i}": payload(4096, seed=i) for i in range(3)}
        store = make_store(objects)
        with RollingPrefetcher(
            store, metas(store), [MemTier(64 << 10)], 512,
            coalesce=8, eviction_interval_s=0.01,
        ) as pf:
            got = pf.read_range(0, pf.plan.total_bytes)
        assert got == b"".join(objects[m.key] for m in metas(store))
        s = pf.stats.snapshot()
        assert s["store_requests"] < s["blocks_fetched"]
        assert s["coalesced_requests"] >= 1
        assert s["coalesced_blocks"] > s["coalesced_requests"]

    def test_runs_never_span_files(self):
        """A coalesced request covers one key only: per-file byte content
        must survive coalescing with many small files."""
        objects = {f"f{i}": payload(700 + i * 13, seed=i) for i in range(6)}
        store = make_store(objects)
        with RollingPrefetcher(
            store, metas(store), [MemTier(64 << 10)], 256,
            coalesce=16, eviction_interval_s=0.01,
        ) as pf:
            got = pf.read_range(0, pf.plan.total_bytes)
        assert got == b"".join(objects[m.key] for m in metas(store))

    def test_coalesced_fetch_retries_transient_failures(self):
        objects = {"a": payload(8192)}
        store = make_store(objects)
        store.link.fail_next(3)
        with RollingPrefetcher(
            store, metas(store), [MemTier(32 << 10)], 512,
            coalesce=4, max_retries=6, retry_backoff_s=0.001,
            eviction_interval_s=0.01,
        ) as pf:
            assert pf.read_range(0, 8192) == payload(8192)
        assert pf.stats.retries >= 3

    def test_coalesced_fetch_under_hedging(self):
        objects = {"a": payload(16384)}
        store = make_store(objects, latency=0.05)
        with RollingPrefetcher(
            store, metas(store), [MemTier(64 << 10)], 2048,
            coalesce=4, hedge_timeout_s=0.01, eviction_interval_s=0.01,
        ) as pf:
            assert pf.read_range(0, 16384) == payload(16384)
        assert pf.stats.hedges >= 1

    def test_permanent_failure_fails_whole_run(self):
        objects = {"a": payload(4096)}
        store = make_store(objects)
        store.link.fail_next(100)
        with RollingPrefetcher(
            store, metas(store), [MemTier(32 << 10)], 512,
            coalesce=4, max_retries=1, retry_backoff_s=0.001,
            eviction_interval_s=0.01,
        ) as pf:
            with pytest.raises(StoreError):
                pf.read_range(0, 4096)

    def test_run_shrinks_when_tier_cannot_hold_it(self):
        """coalesce=8 with a tier that fits only 2 blocks: the scheduler
        must degrade to narrower runs, not deadlock."""
        objects = {"a": payload(8192)}
        store = make_store(objects)
        with RollingPrefetcher(
            store, metas(store), [MemTier(1024)], 512,  # 2-block budget
            coalesce=8, eviction_interval_s=0.005,
        ) as pf:
            assert pf.read_range(0, 8192) == payload(8192)


# --------------------------------------------------------------------------- #
# readahead horizon
# --------------------------------------------------------------------------- #
class TestReadaheadHorizon:
    def test_slow_reader_bounds_fetch_window(self):
        objects = {"a": payload(16384)}
        store = make_store(objects)
        pf = RollingPrefetcher(
            store, metas(store), [MemTier(64 << 10)], 512,   # 32 blocks
            readahead_blocks=4, eviction_interval_s=10.0,
        )
        with pf:
            time.sleep(0.25)   # reader never reads: horizon stays [0, 4)
            in_flight = sum(
                i.state in (BlockState.FETCHING, BlockState.CACHED)
                for i in pf._info
            )
            assert in_flight <= 4
            # Reader progress slides the horizon and the stream finishes.
            assert pf.read_range(0, 16384) == payload(16384)
            assert pf.stats.blocks_fetched >= 32 - pf.stats.direct_reads

    def test_horizon_bounds_coalesced_runs(self):
        objects = {"a": payload(16384)}
        store = make_store(objects)
        pf = RollingPrefetcher(
            store, metas(store), [MemTier(64 << 10)], 512,
            coalesce=16, readahead_blocks=6, eviction_interval_s=10.0,
        )
        with pf:
            time.sleep(0.25)
            in_flight = sum(
                i.state in (BlockState.FETCHING, BlockState.CACHED)
                for i in pf._info
            )
            assert in_flight <= 6
            assert pf.read_range(0, 16384) == payload(16384)

    def test_validation(self):
        objects = {"a": payload(1024)}
        store = make_store(objects)
        with pytest.raises(ValueError):
            RollingPrefetcher(store, metas(store), [MemTier(4096)], 256,
                              readahead_blocks=0)
        with pytest.raises(ValueError):
            RollingPrefetcher(store, metas(store), [MemTier(4096)], 256,
                              coalesce=0)
        with pytest.raises(ValueError):
            RollingPrefetcher(store, metas(store), [MemTier(4096)], 256,
                              depth=4, max_depth=2)


# --------------------------------------------------------------------------- #
# AIMD depth control
# --------------------------------------------------------------------------- #
class TestAimdDepth:
    def test_additive_increase_while_throughput_holds(self):
        ctl = AimdDepthController(1, 8, window=2)
        now = [0.0]
        for _ in range(40):
            now[0] += 0.01
            ctl.on_fetch(1 << 20, now[0])   # steady throughput
        assert ctl.target == 8              # ramped to the ceiling
        assert ctl.peak == 8

    def test_multiplicative_decrease_on_regression(self):
        ctl = AimdDepthController(1, 8, window=2)
        now = 0.0
        for _ in range(40):
            now += 0.01
            ctl.on_fetch(1 << 20, now)
        assert ctl.target == 8
        # Throughput collapses 10x: the next windows must halve the target.
        for _ in range(4):
            now += 0.1
            ctl.on_fetch(1 << 20, now)
        assert ctl.target <= 4
        assert 1 <= ctl.target <= ctl.max_depth

    def test_never_leaves_bounds(self):
        ctl = AimdDepthController(3, 4, window=1)
        now = 0.0
        for i in range(100):
            now += 0.001 if i % 7 else 1.0   # wildly noisy throughput
            ctl.on_fetch(1024, now)
            assert 1 <= ctl.target <= 4

    def test_engine_grows_streams_on_latency_bound_link(self):
        objects = {f"f{i}": payload(2048, seed=i) for i in range(8)}
        store = make_store(objects, latency=0.005)
        with RollingPrefetcher(
            store, metas(store), [MemTier(64 << 10)], 512,
            depth=1, max_depth=6, eviction_interval_s=0.01,
        ) as pf:
            got = pf.read_range(0, pf.plan.total_bytes)
        assert got == b"".join(objects[m.key] for m in metas(store))
        assert pf.stats.depth_peak > 1
        assert pf.stats.depth_peak <= 6


# --------------------------------------------------------------------------- #
# event-driven eviction (the 5-second-cliff fix)
# --------------------------------------------------------------------------- #
class TestEvictionNotify:
    def test_full_tier_does_not_wait_out_the_eviction_interval(self):
        """Tier fits 2 of 16 blocks and the eviction interval is 30 s: the
        consume/demand notifications must keep the pipeline rolling — the
        old timed poll would stall for up to eviction_interval_s per
        eviction round."""
        objects = {"a": payload(8192)}
        store = make_store(objects)
        tier = MemTier(1024)   # 2 blocks of 512
        t0 = time.perf_counter()
        with RollingPrefetcher(
            store, metas(store), [tier], 512, eviction_interval_s=30.0,
        ) as pf:
            assert pf.read_range(0, 8192) == payload(8192)
        elapsed = time.perf_counter() - t0
        assert elapsed < 5.0, f"eviction stalled the pipeline: {elapsed:.1f}s"
        assert pf.stats.blocks_evicted >= 1


# --------------------------------------------------------------------------- #
# copy reduction
# --------------------------------------------------------------------------- #
class TestZeroCopyReads:
    def test_read_range_view_returns_memoryview_within_block(self):
        objects = {"a": payload(4096)}
        store = make_store(objects)
        with RollingPrefetcher(
            store, metas(store), [MemTier(16 << 10)], 1024,
            eviction_interval_s=0.05,
        ) as pf:
            first = pf.read_range(0, 512, view=True)
            assert isinstance(first, memoryview)
            assert bytes(first) == payload(4096)[:512]
            # Multi-block requests still return bytes.
            rest = pf.read_range(512, 4096, view=True)
            assert isinstance(rest, bytes)
            assert rest == payload(4096)[512:]

    def test_readview_file_api(self):
        objects = {"a": payload(2048)}
        store = make_store(objects)
        fs = PrefetchFS(store, policy=IOPolicy(
            engine="rolling", blocksize=1024, eviction_interval_s=0.05))
        with fs:
            f = fs.open_many(metas(store))
            got = bytearray()
            while True:
                chunk = f.readview(256)
                if not chunk:
                    break
                got += chunk
            assert bytes(got) == payload(2048)

    def test_put_part_keeps_immutable_bytes_without_copy(self):
        store = MemStore()
        mp = store.start_multipart("k")
        part = payload(512)
        mp.put_part(0, part)
        assert mp._parts[0] is part        # no defensive re-copy
        mp.put_part(1, bytearray(payload(16, seed=1)))  # mutable: copied
        mp.complete()
        assert store.get("k") == part + payload(16, seed=1)


# --------------------------------------------------------------------------- #
# sequential engine read-ahead
# --------------------------------------------------------------------------- #
class TestSequentialReadahead:
    def test_multiblock_cache_fills_with_one_request(self):
        objects = {"a": payload(8192)}
        store = make_store(objects)
        f = SequentialFile(store, metas(store), blocksize=512, cache_blocks=4)
        assert f.read() == payload(8192)
        assert f.stats.blocks_fetched == 16
        assert f.stats.store_requests == 4      # 4-block runs, one GET each
        assert store.link.coalesced_requests >= 1

    def test_single_block_cache_keeps_baseline_request_shape(self):
        objects = {"a": payload(4096)}
        store = make_store(objects)
        f = SequentialFile(store, metas(store), blocksize=512)
        assert f.read() == payload(4096)
        assert f.stats.store_requests == f.stats.blocks_fetched == 8


# --------------------------------------------------------------------------- #
# the closed autotune loop
# --------------------------------------------------------------------------- #
class TestClosedLoopAutotune:
    def test_request_fit_separates_latency_and_bandwidth(self):
        tuner = BlockSizeTuner(min_blocksize=1024)
        lat, bw = 0.02, 100e6
        for w in [1, 2, 4, 8, 1, 3, 6, 2, 5, 7]:
            nbytes = w * 65536
            tuner.observe_request(nbytes, lat + nbytes / bw)
        assert tuner.latency_s == pytest.approx(lat, rel=0.05)
        assert tuner.bandwidth_Bps == pytest.approx(bw, rel=0.05)

    def test_uniform_sizes_stay_underdetermined(self):
        tuner = BlockSizeTuner()
        for _ in range(20):
            tuner.observe_request(65536, 0.02)
        assert tuner.latency_s is None       # no variance, no fit
        assert tuner.suggest_coalesce(65536, 16) == 1

    def test_suggest_coalesce_matches_cost_model(self):
        tuner = BlockSizeTuner()
        tuner.observe_latency(0.02)
        tuner.observe_bandwidth(200e6)
        want = cost_model.coalesce_width(0.02, 200e6, 32 << 10, 16)
        assert tuner.suggest_coalesce(32 << 10, 16) == want
        assert want > 1                      # latency-bound: coalescing on
        assert cost_model.coalesce_width(0.001, 45e6, 256 << 10, 16) == 1

    def test_fsstats_surfaces_tuner_estimates(self):
        objects = {f"f{i}": payload(4096, seed=i) for i in range(4)}
        store = make_store(objects, latency=0.003)
        fs = PrefetchFS(store, policy=IOPolicy(
            engine="rolling", blocksize=512, autotune=True,
            eviction_interval_s=0.02))
        with fs:
            f = fs.open_many(metas(store))
            f.read()
            f.close()
            snap = fs.stats().snapshot()
        assert snap["tuner"] is not None
        assert snap["tuner"]["requests_observed"] > 0
        assert snap["tuner"]["latency_s"] is not None
        assert snap["totals"]["store_requests"] < snap["totals"]["blocks_fetched"]

    def test_autotuned_blocksize_converges_to_eq4_optimum(self):
        """Acceptance: with autotune=True the blocksize chosen for the
        second open lands within 20% of Eq. 4's optimum for the simulated
        link's known l_c / b_cr and the reader's compute rate."""
        l_c, b_cr = 0.03, 200e6
        c = 2e-7                       # compute seconds per byte (sleept)
        objects = {f"f{i}": payload((768 << 10) + 1000 * i, seed=i)
                   for i in range(4)}
        store = make_store(objects, latency=l_c, bandwidth=b_cr)
        total = sum(len(v) for v in objects.values())
        fs = PrefetchFS(store, policy=IOPolicy(
            engine="rolling", blocksize=32 << 10, autotune=True,
            eviction_interval_s=0.02))
        with fs:
            f = fs.open_many(metas(store))
            chunk = 128 << 10
            while True:
                data = f.read(chunk)
                if not data:
                    break
                time.sleep(c * len(data))   # the application's compute
            f.close()
            g = fs.open_many(metas(store))   # retuned from observations
            chosen = g._pf.plan.blocksize
            g.close()
        want = cost_model.optimal_blocksize(total, c, l_c)
        assert 0.8 * want <= chosen <= 1.2 * want, (
            f"chosen {chosen} vs Eq.4 optimum {want:.0f} "
            f"(tuner: {fs.tuner.estimates()})"
        )

    def test_loader_exposes_fs_tuner(self):
        from repro.data.loader import LoaderConfig, PrefetchingDataLoader
        from repro.data.tokens import synth_token_shard
        import numpy as np

        rng = np.random.default_rng(0)
        store = make_store(
            {f"s{i}": synth_token_shard(rng, 3000) for i in range(2)}
        )
        cfg = LoaderConfig(seq_len=64, batch_size=2,
                           policy=IOPolicy(engine="rolling", blocksize=8192,
                                           autotune=True,
                                           eviction_interval_s=0.02))
        loader = PrefetchingDataLoader(store, metas(store), [MemTier(1 << 20)],
                                       cfg)
        for _ in loader.batches(max_batches=2):
            pass
        assert loader.tuner is not None
        assert loader.tuner.n_requests_observed > 0
        loader.close()

    def test_retune_respects_explicit_coalesce_cap(self):
        """An explicit IOPolicy.coalesce — including 1, i.e. coalescing
        off — bounds the payload one request may carry; autotune only
        opens the ceiling when coalesce was left unset (None)."""
        objects = {"f0": payload(64 << 10)}
        for coalesce, want in [(2, lambda w: w == 2),
                               (1, lambda w: w == 1),
                               (None, lambda w: w > 1)]:
            store = make_store(objects, latency=0.005)
            fs = PrefetchFS(store, policy=IOPolicy(
                engine="rolling", blocksize=4096, autotune=True,
                coalesce=coalesce, eviction_interval_s=0.02))
            with fs:
                f = fs.open("f0")
                assert want(f._pf.coalesce), (coalesce, f._pf.coalesce)
                f.read()
                f.close()

    def test_depth_peak_folds_as_max_not_sum(self):
        """depth_peak is a high-water mark: folding reopened readers (and
        cross-engine totals) must keep the peak, not sum peaks."""
        class FakeStats:
            def __init__(self, snap):
                self._snap = snap

            def snapshot(self):
                return dict(self._snap)

        class FakeReader:
            def __init__(self, snap):
                self.stats = FakeStats(snap)

        bucket: dict = {}
        PrefetchFS._fold_snapshot(
            bucket, FakeReader({"depth_peak": 8, "blocks_fetched": 10}))
        PrefetchFS._fold_snapshot(
            bucket, FakeReader({"depth_peak": 5, "blocks_fetched": 7}))
        assert bucket["depth_peak"] == 8     # max, not 13
        assert bucket["blocks_fetched"] == 17  # counters still sum

    def test_sequential_engine_feeds_tuner(self):
        """autotune=True is not a rolling-only loop: the sequential
        engine's synchronous fetches are observed too."""
        objects = {f"f{i}": payload(20000 + 1000 * i, seed=i)
                   for i in range(3)}
        store = make_store(objects, latency=0.002)
        fs = PrefetchFS(store, policy=IOPolicy(
            engine="sequential", blocksize=4096, autotune=True))
        with fs:
            f = fs.open_many(metas(store))
            f.read()
            f.close()
            snap = fs.stats().snapshot()
        assert snap["tuner"] is not None
        assert snap["tuner"]["requests_observed"] > 0


# --------------------------------------------------------------------------- #
# coalesced-fetch failure cleanup + lazy stream spawning (review fixes)
# --------------------------------------------------------------------------- #
class TestCoalescedWriteFailureCleanup:
    def test_mid_run_tier_write_failure_leaves_no_orphans(self):
        """A tier.write failure mid-way through a coalesced run must not
        strand the blocks already written: FAILED blocks are invisible to
        eviction, so orphans would stay resident past the cancelled
        reservation forever."""
        class FlakyWriteTier(MemTier):
            def __init__(self, capacity: int, fail_at: int) -> None:
                super().__init__(capacity)
                self.writes = 0
                self.fail_at = fail_at

            def _write(self, block_id: str, data: bytes) -> None:
                self.writes += 1
                if self.writes == self.fail_at:
                    raise StoreError("tier write blew up")
                super()._write(block_id, data)

        objects = {"a": payload(4096)}
        store = make_store(objects)
        tier = FlakyWriteTier(32 << 10, fail_at=3)
        with RollingPrefetcher(
            store, metas(store), [tier], 512,
            coalesce=8, eviction_interval_s=0.01,
        ) as pf:
            with pytest.raises(StoreError):
                pf.read_range(0, 4096)
            assert tier._resident_bytes() == 0   # writes 1-2 cleaned up
            tier.verify_used()
            assert tier.available() == tier.capacity


class TestLazyStreamSpawn:
    def test_streams_spawn_lazily_up_to_aimd_target(self):
        objects = {"a": payload(32 << 10)}
        store = make_store(objects, latency=0.05)
        pf = RollingPrefetcher(
            store, metas(store), [MemTier(1 << 20)], 2048,
            depth=2, max_depth=32, eviction_interval_s=0.01,
        )
        pf.start()
        assert pf._spawned == 2              # not the 32-thread ceiling
        assert pf.read_range(0, 32 << 10) == payload(32 << 10)
        assert pf._spawned <= max(2, pf.stats.depth_peak)
        assert pf._spawned < 32
        pf.close()

    def test_non_store_error_write_failure_fails_run_not_deadlocks(self):
        """ENOSPC-style failures (not StoreError) must also cancel the
        reservation and FAIL the run — otherwise the blocks stay FETCHING
        and the reader waits forever."""
        class Enospc(MemTier):
            def _write(self, block_id: str, data: bytes) -> None:
                raise OSError(28, "No space left on device")

        objects = {"a": payload(2048)}
        store = make_store(objects)
        tier = Enospc(32 << 10)
        with RollingPrefetcher(
            store, metas(store), [tier], 512,
            coalesce=4, eviction_interval_s=0.01,
        ) as pf:
            with pytest.raises(StoreError):
                pf.read_range(0, 2048)
            tier.verify_used()
            assert tier.available() == tier.capacity
