"""Tests for the deterministic interleaving explorer and its scheduler.

Calibration contract: the known-racy single-flight fixture MUST be
caught (by fuzzing and by bounded exhaustive search), its fixed twin
MUST pass, and the real concurrency-core models (CacheIndex single
flight, UploadPool close-vs-submit, PeerGroup failover) MUST pass
within the preemption bound. Determinism is the other half: identical
seed, identical trace and verdict, bit for bit.
"""

from __future__ import annotations

import threading
import time

import pytest

from repro.analysis.explore import (
    PeerFailoverModel,
    RacySingleFlightModel,
    SafeSingleFlightModel,
    SingleFlightModel,
    UploadPoolCloseModel,
    explore,
    fuzz,
    replay,
)
from repro.sched import (
    CoopScheduler,
    DeadlockError,
    RandomPicker,
    ReplayPicker,
    TaskFailed,
)


# --------------------------------------------------------------------------- #
# Determinism: same seed, same everything.
# --------------------------------------------------------------------------- #

def test_fuzz_is_deterministic_on_racy_model():
    a = fuzz(RacySingleFlightModel, seed=7, runs=25)
    b = fuzz(RacySingleFlightModel, seed=7, runs=25)
    assert not a.ok
    assert a.schedules == b.schedules
    assert a.trace == b.trace
    assert a.decisions == b.decisions
    assert a.violations == b.violations
    assert a.error == b.error


def test_fuzz_is_deterministic_on_safe_model():
    a = fuzz(SafeSingleFlightModel, seed=7, runs=10)
    b = fuzz(SafeSingleFlightModel, seed=7, runs=10)
    assert a.ok and b.ok
    assert a.trace == b.trace
    assert a.decisions == b.decisions


def test_different_seeds_may_visit_different_schedules():
    a = fuzz(SafeSingleFlightModel, seed=1, runs=1)
    b = fuzz(SafeSingleFlightModel, seed=2, runs=1)
    # Both clean, but the point of seeding is varied coverage; the
    # decision logs exist either way.
    assert a.ok and b.ok
    assert a.decisions and b.decisions


def test_trace_has_no_wall_clock_entries():
    v = fuzz(SafeSingleFlightModel, seed=3, runs=2)
    # Virtual-clock entries are "clock <t>"; everything else is
    # "<task> <reason>". No timestamps from the host clock.
    for line in v.trace:
        head = line.split()[0]
        assert head == "clock" or not head.replace(".", "").isdigit()


# --------------------------------------------------------------------------- #
# Replay: a recorded decision sequence reproduces the verdict.
# --------------------------------------------------------------------------- #

def test_replay_reproduces_fuzzed_violation():
    v = fuzz(RacySingleFlightModel, seed=7, runs=25)
    assert not v.ok
    r = replay(RacySingleFlightModel, v.decisions)
    assert not r.ok
    assert r.trace == v.trace
    assert r.violations == v.violations and r.error == v.error


def test_replay_empty_prefix_is_nonpreemptive_baseline():
    r = replay(RacySingleFlightModel, ())
    # The nonpreemptive schedule runs each reader to completion — the
    # race needs a preemption, so the baseline is clean.
    assert r.ok, r.describe()


# --------------------------------------------------------------------------- #
# Bounded exhaustive exploration.
# --------------------------------------------------------------------------- #

def test_explore_catches_racy_fixture_at_bound_one():
    v = explore(RacySingleFlightModel, preemption_bound=1,
                max_schedules=100)
    assert not v.ok, v.describe()
    # The duplicate fetch is the observable symptom at one preemption.
    assert v.error and "fetches" in v.error


def test_explore_catches_monitor_violation_at_bound_two():
    v = explore(RacySingleFlightModel, preemption_bound=2,
                max_schedules=400)
    assert not v.ok


def test_explore_verdict_replays():
    v = explore(RacySingleFlightModel, preemption_bound=1,
                max_schedules=100)
    assert not v.ok
    r = replay(RacySingleFlightModel, v.decisions)
    assert not r.ok
    assert r.error == v.error and r.violations == v.violations


def test_explore_passes_safe_fixture():
    v = explore(SafeSingleFlightModel, preemption_bound=2,
                max_schedules=400)
    assert v.ok, v.describe()
    assert v.schedules > 1          # it actually branched


def test_explore_is_deterministic():
    a = explore(RacySingleFlightModel, preemption_bound=1,
                max_schedules=100)
    b = explore(RacySingleFlightModel, preemption_bound=1,
                max_schedules=100)
    assert a.schedules == b.schedules
    assert a.decisions == b.decisions
    assert a.trace == b.trace


# --------------------------------------------------------------------------- #
# The real concurrency core, under the monitor.
# --------------------------------------------------------------------------- #

def test_real_single_flight_passes_bounded_exploration():
    v = explore(SingleFlightModel, preemption_bound=1, max_schedules=200)
    assert v.ok, v.describe()


def test_real_single_flight_passes_fuzz():
    v = fuzz(SingleFlightModel, seed=11, runs=20)
    assert v.ok, v.describe()


def test_upload_pool_close_vs_submit_passes():
    v = explore(UploadPoolCloseModel, preemption_bound=1,
                max_schedules=200)
    assert v.ok, v.describe()


def test_peer_failover_passes():
    v = explore(PeerFailoverModel, preemption_bound=2, max_schedules=200)
    assert v.ok, v.describe()


# --------------------------------------------------------------------------- #
# Scheduler mechanics.
# --------------------------------------------------------------------------- #

class _ABBADeadlockModel:
    """Classic lock-order inversion: one preemption away from deadlock."""

    def setup(self, monitor):
        a = threading.Lock()
        b = threading.Lock()

        def t1():
            with a:
                with b:
                    pass

        def t2():
            with b:
                with a:
                    pass

        return [("t1", t1), ("t2", t2)]

    def check(self) -> None:
        pass


def test_explore_finds_abba_deadlock():
    v = explore(_ABBADeadlockModel, preemption_bound=1, max_schedules=50)
    assert not v.ok
    assert v.error and v.error.startswith("deadlock")
    # And the deadlock replays from its decision log.
    r = replay(_ABBADeadlockModel, v.decisions)
    assert r.error == v.error


def test_virtual_clock_runs_sleeps_instantly():
    sched = CoopScheduler(ReplayPicker(()))
    with sched.activate():
        def sleeper():
            time.sleep(300.0)

        sched.spawn(sleeper, name="sleeper")
        sched.run()
    # The 300 virtual seconds elapsed on the scheduler's clock; the test
    # itself returns in milliseconds of real time.
    assert sched.now >= 300.0
    assert any(line.startswith("clock") for line in sched.trace)


def test_condition_timeout_uses_virtual_clock():
    sched = CoopScheduler(ReplayPicker(()))
    with sched.activate():
        out = {}

        def waiter():
            cond = threading.Condition()
            with cond:
                out["signalled"] = cond.wait(timeout=60.0)

        sched.spawn(waiter, name="waiter")
        sched.run()
    assert out["signalled"] is False
    assert sched.now >= 60.0


def test_task_exception_surfaces_as_task_failed():
    sched = CoopScheduler(ReplayPicker(()))
    with sched.activate():
        def boom():
            raise ValueError("kaboom")

        sched.spawn(boom, name="boom")
        with pytest.raises(TaskFailed, match="kaboom"):
            sched.run()


def test_self_deadlock_detected():
    sched = CoopScheduler(ReplayPicker(()))
    with sched.activate():
        def stuck():
            lock = threading.Lock()
            lock.acquire()
            lock.acquire()          # non-reentrant: blocks forever

        sched.spawn(stuck, name="stuck")
        with pytest.raises(DeadlockError):
            sched.run()


def test_queue_handoff_is_cooperative():
    import queue

    sched = CoopScheduler(RandomPicker("q"))
    got = []
    with sched.activate():
        # A Queue built during the window resolves the patched ctors, so
        # its mutex/conditions are cooperative.
        q = queue.Queue(maxsize=1)
        assert type(q.mutex).__name__ == "SchedLock"

        def producer():
            for i in range(3):
                q.put(i)

        def consumer():
            for _ in range(3):
                got.append(q.get())

        sched.spawn(producer, name="producer")
        sched.spawn(consumer, name="consumer")
        sched.run()
    assert got == [0, 1, 2]


def test_condition_notify_wakes_distinct_waiters():
    sched = CoopScheduler(ReplayPicker(()))
    woken = []
    with sched.activate():
        cond = threading.Condition()
        ready = []

        def waiter(tag):
            with cond:
                ready.append(tag)
                cond.wait()
                woken.append(tag)

        def notifier():
            # Two successive single notifies must wake two DIFFERENT
            # waiters.
            while True:
                with cond:
                    if len(ready) == 2:
                        cond.notify()
                        cond.notify()
                        return
                time.sleep(0.01)

        sched.spawn(lambda: waiter("a"), name="waiter-a")
        sched.spawn(lambda: waiter("b"), name="waiter-b")
        sched.spawn(notifier, name="notifier")
        sched.run()
    assert sorted(woken) == ["a", "b"]


def test_daemon_task_does_not_block_shutdown():
    sched = CoopScheduler(ReplayPicker(()))
    with sched.activate():
        def forever():
            lock = threading.Lock()
            lock.acquire()
            lock.acquire()          # parks forever

        def work():
            pass

        sched.spawn(forever, name="bg", daemon=True)
        sched.spawn(work, name="work")
        sched.run()                 # returns once `work` is done
    assert True


def test_thread_start_join_inside_schedule():
    sched = CoopScheduler(RandomPicker("t"))
    seen = []
    with sched.activate():
        def child():
            seen.append("child")

        def parent():
            t = threading.Thread(target=child, name="child")
            t.start()
            t.join()
            seen.append("parent")

        sched.spawn(parent, name="parent")
        sched.run()
    assert seen == ["child", "parent"]
