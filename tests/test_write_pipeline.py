"""Write-path tests: URI store registry, write-behind Writer (flush
barrier, retry, byte/stat parity with sync put), multipart stores,
pipelined checkpoint save, and the PR's satellite fixes (exists()
transient propagation, rolling restart-after-close, PrefetchFS
concurrency)."""

from __future__ import annotations

import json
import threading

import numpy as np
import pytest

from repro.core.rolling import RollingPrefetcher
from repro.io import (
    IOPolicy,
    PrefetchFS,
    Writer,
    available_stores,
    clear_store_cache,
    open_store,
    parse_store_uri,
    register_store,
)
from repro.io import stores as io_stores
from repro.store import DirStore, MemStore, MemTier, SimS3Store
from repro.store.base import ObjectMeta, StoreError, TransientStoreError


def payload(n: int, seed: int = 0) -> bytes:
    return bytes((i * 31 + seed * 7) % 256 for i in range(n))


@pytest.fixture(autouse=True)
def _fresh_store_cache():
    clear_store_cache()
    yield
    clear_store_cache()


# --------------------------------------------------------------------------- #
# store registry
# --------------------------------------------------------------------------- #
class TestStoreRegistry:
    def test_builtin_schemes(self):
        assert {"mem", "local", "sims3"} <= set(available_stores())

    def test_uri_parsing(self):
        u = parse_store_uri("sims3://bucket/pfx?latency_ms=40&bw_mbps=200")
        assert u.scheme == "sims3"
        assert u.location == "bucket/pfx"
        assert u.params == {"latency_ms": "40", "bw_mbps": "200"}

    def test_mem_local_sims3_dispatch(self, tmp_path):
        assert isinstance(open_store("mem://scratch"), MemStore)
        assert isinstance(open_store(f"local://{tmp_path}/d"), DirStore)
        s = open_store("sims3://b?latency_ms=40&bw_mbps=200")
        assert isinstance(s, SimS3Store)
        assert s.link.latency_s == pytest.approx(0.04)
        assert s.link.bandwidth_Bps == pytest.approx(200e6)

    def test_asymmetric_put_link(self):
        s = open_store("sims3://b?latency_ms=10&put_latency_ms=30&put_bw_mbps=50")
        assert s.put_link is not s.link
        assert s.put_link.latency_s == pytest.approx(0.03)
        assert s.put_link.bandwidth_Bps == pytest.approx(50e6)

    def test_same_uri_shares_instance_fresh_bypasses(self):
        a = open_store("mem://shared")
        b = open_store("mem://shared")
        c = open_store("mem://shared", fresh=True)
        d = open_store("mem://other")
        assert a is b
        assert c is not a
        assert d is not a
        a.put("k", b"x")
        assert b.get("k") == b"x"

    def test_store_instance_passthrough(self):
        s = MemStore()
        assert open_store(s) is s

    def test_param_order_shares_one_instance(self):
        """canonical() sorts params: two spellings of the same bucket must
        map to ONE cached instance (one LinkModel, one state)."""
        a = open_store("sims3://b?latency_ms=40&bw_mbps=200")
        b = open_store("sims3://b?bw_mbps=200&latency_ms=40")
        assert a is b

    def test_percent_encoded_params_do_not_collide(self):
        """Regression: parse_qsl decodes escapes, so a canonical form that
        re-joined raw values collapsed ``?a=1&b=2`` with ``?a=1%26b%3D2``
        (ONE param whose value is "1&b=2") — two different stores shared
        one cached instance."""
        u1 = parse_store_uri("x://b?a=1&b=2")
        u2 = parse_store_uri("x://b?a=1%26b%3D2")
        assert u1.params != u2.params
        assert u1.canonical() != u2.canonical()
        # And through the cache: distinct params -> distinct instances.
        made = []

        @register_store("canon-test")
        def _factory(uri):
            made.append(dict(uri.params))
            return MemStore()

        try:
            a = open_store("canon-test://b?a=1&b=2")
            b = open_store("canon-test://b?a=1%26b%3D2")
            assert a is not b
            assert made == [{"a": "1", "b": "2"}, {"a": "1&b=2"}]
        finally:
            io_stores._REGISTRY.pop("canon-test")

    def test_unknown_scheme_and_params_raise(self):
        with pytest.raises(ValueError, match="unknown store scheme"):
            open_store("bogus://x")
        with pytest.raises(ValueError, match="unknown store URI params"):
            open_store("sims3://b?latency=oops")
        with pytest.raises(ValueError, match="not a store URI"):
            open_store("no-scheme-here")

    def test_new_scheme_plugs_in(self):
        calls = []

        @register_store("test-scheme")
        def _factory(uri):
            calls.append(uri.location)
            return MemStore()

        try:
            fs = PrefetchFS("test-scheme://bucket")
            assert isinstance(fs.store, MemStore)
            assert calls == ["bucket"]
        finally:
            io_stores._REGISTRY.pop("test-scheme")

    def test_duplicate_scheme_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_store("mem")(lambda uri: MemStore())


# --------------------------------------------------------------------------- #
# write-behind Writer
# --------------------------------------------------------------------------- #
def make_fs(uri="sims3://wtest?latency_ms=1", **policy_kw) -> PrefetchFS:
    policy_kw.setdefault("blocksize", 1024)
    policy_kw.setdefault("write_depth", 3)
    policy_kw.setdefault("retry_backoff_s", 0.001)
    policy_kw.setdefault("eviction_interval_s", 0.01)
    return PrefetchFS(open_store(uri, fresh=True),
                      policy=IOPolicy(**policy_kw))


class TestWriter:
    def test_multi_part_byte_identity_and_readback(self):
        data = payload(10_000)   # 10 parts of 1024 v 1 remainder
        fs = make_fs()
        with fs.open_write("obj") as w:
            # odd-sized writes crossing part boundaries
            for lo in range(0, len(data), 777):
                w.write(data[lo:lo + 777])
        assert fs.store.backing.get("obj") == data
        assert fs.open("obj", engine="direct").read() == data
        fs.close()

    def test_single_part_uses_plain_put(self):
        fs = make_fs(blocksize=1 << 20)
        with fs.open_write("small") as w:
            w.write(b"tiny")
        assert w._mp is None          # single background put, no multipart
        assert fs.store.backing.get("small") == b"tiny"
        fs.close()

    def test_object_invisible_until_close(self):
        fs = make_fs()
        w = fs.open_write("late")
        w.write(payload(4096))
        w.flush()                      # parts durable, object NOT published
        assert not fs.store.backing.exists("late")
        w.close()
        assert fs.store.backing.exists("late")
        fs.close()

    def test_flush_is_durability_barrier(self):
        fs = make_fs()
        w = fs.open_write("flushy")
        w.write(payload(3000))
        w.flush()
        snap = w.stats.snapshot()
        # every sealed part (2 full + 1 partial) uploaded before flush returned
        assert snap["parts_uploaded"] == 3
        assert snap["bytes_uploaded"] == 3000
        w.write(payload(500, seed=1))
        w.close()
        assert fs.store.backing.get("flushy") == payload(3000) + payload(500, seed=1)
        fs.close()

    def test_partial_upload_retry(self):
        fs = make_fs()
        fs.store.put_link.fail_next(2)   # two part uploads throttle once each
        data = payload(5000)
        with fs.open_write("retry") as w:
            w.write(data)
        assert fs.store.backing.get("retry") == data
        assert w.stats.snapshot()["retries"] >= 2

    def test_permanent_failure_raises_and_never_publishes(self):
        fs = make_fs(max_retries=1)
        fs.store.put_link.fail_next(1000)
        w = fs.open_write("doomed")
        w.write(payload(5000))
        with pytest.raises(StoreError):
            w.close()
        assert w.closed
        assert not fs.store.backing.exists("doomed")

    def test_stats_parity_with_sync_put(self):
        data = payload(8192)
        sync_store = open_store("sims3://sync?latency_ms=1", fresh=True)
        sync_store.put("obj", data)
        fs = make_fs()
        with fs.open_write("obj") as w:
            w.write(data)
        assert fs.store.backing.get("obj") == sync_store.backing.get("obj")
        snap = w.stats.snapshot()
        assert snap["bytes_written"] == snap["bytes_uploaded"] == len(data)

    def test_hedged_put(self):
        fs = make_fs(uri="sims3://hedge?latency_ms=30", hedge_timeout_s=0.003)
        data = payload(2048)
        with fs.open_write("h") as w:
            w.write(data)
        assert fs.store.backing.get("h") == data
        assert w.stats.snapshot()["hedges"] >= 1

    def test_pool_refusing_job_unwinds_seal_barrier(self):
        # If the pool is closed underneath the writer, the seal's barrier
        # count must be unwound — otherwise flush()/close() wait forever
        # for an upload job that was never queued.
        fs = make_fs()
        w = fs.open_write("refused")

        class _ClosedPool:
            def submit(self, job):
                raise ValueError("submit on closed UploadPool")

        orig_pool = w._pool
        w._pool = _ClosedPool()
        with pytest.raises(ValueError):
            w.write(payload(2048))      # seals part 0, pool refuses
        # Barrier accounting balanced: flush() returns instead of hanging
        # forever on an upload job that was never queued.
        assert w._sealed == w._done
        w._pool = orig_pool
        w.flush()
        w.abort()
        fs.close()

    def test_staging_tier_write_failure_returns_budget_and_raises(self):
        fs = make_fs()
        w = fs.open_write("torn")
        tier = w.tiers[0]
        free_before = tier.available()

        def torn_write(*a, **kw):
            raise OSError("disk gone")

        orig = tier.write
        tier.write = torn_write
        try:
            with pytest.raises(OSError):
                w.write(payload(2048))
        finally:
            tier.write = orig
        # The failed reservation was cancelled, not leaked.
        assert tier.available() == free_before
        assert w._sealed == w._done
        w.abort()
        fs.close()

    def test_write_after_close_and_join_without_close_async(self):
        fs = make_fs()
        w = fs.open_write("x")
        w.write(b"abc")
        with pytest.raises(ValueError, match="join"):
            w.join()
        w.close()
        with pytest.raises(ValueError, match="closed"):
            w.write(b"more")
        fs.close()

    def test_backpressure_bounded_staging(self):
        # Tiny staging tier: the writer must block rather than buffer
        # unboundedly, and everything still lands.
        fs = PrefetchFS(open_store("sims3://bp?latency_ms=2", fresh=True),
                        policy=IOPolicy(blocksize=512, write_depth=1),
                        tiers=[MemTier(1024)])
        data = payload(8192)
        with fs.open_write("big") as w:
            w.write(data)
        assert fs.store.backing.get("big") == data
        assert fs.tiers[0].used == 0   # staging space fully released
        fs.close()

    def test_writer_stats_fold_into_fs_stats(self):
        fs = make_fs()
        with fs.open_write("a") as w:
            w.write(payload(2048))
        fs.open("a", engine="direct").read()
        snap = fs.stats().snapshot()
        assert "write-behind" in snap["per_engine"]
        assert snap["per_engine"]["write-behind"]["bytes_uploaded"] == 2048
        assert snap["totals"]["bytes_uploaded"] == 2048
        assert snap["totals"]["bytes_read"] == 2048
        fs.close()

    def test_open_write_on_closed_fs(self):
        fs = make_fs()
        fs.close()
        with pytest.raises(ValueError, match="closed PrefetchFS"):
            fs.open_write("k")


# --------------------------------------------------------------------------- #
# multipart store support
# --------------------------------------------------------------------------- #
class TestMultipart:
    def test_memstore_default_multipart(self):
        s = MemStore()
        mp = s.start_multipart("k")
        mp.put_part(1, b"world")
        mp.put_part(0, b"hello ")
        mp.complete()
        assert s.get("k") == b"hello world"

    def test_non_contiguous_parts_rejected(self):
        s = MemStore()
        mp = s.start_multipart("k")
        mp.put_part(0, b"a")
        mp.put_part(2, b"c")
        with pytest.raises(StoreError, match="non-contiguous"):
            mp.complete()

    def test_abort_never_publishes(self):
        s = MemStore()
        mp = s.start_multipart("k")
        mp.put_part(0, b"a")
        mp.abort()
        with pytest.raises(StoreError):
            mp.put_part(1, b"b")
        assert not s.exists("k")

    def test_dirstore_multipart_cleans_part_files(self, tmp_path):
        s = DirStore(str(tmp_path))
        mp = s.start_multipart("sub/obj")
        mp.put_part(0, b"aa")
        mp.put_part(1, b"bb")
        mp.complete()
        assert s.get("sub/obj") == b"aabb"
        leftovers = [m.key for m in s.list_objects() if ".mpart" in m.key]
        assert leftovers == []

    def test_sims3_multipart_charges_put_link(self):
        s = open_store("sims3://mp?latency_ms=0", fresh=True)
        mp = s.start_multipart("k")
        mp.put_part(0, payload(100))
        assert s.put_link.bytes_moved == 100   # paid at part time, not complete
        mp.complete()
        assert s.backing.get("k") == payload(100)


# --------------------------------------------------------------------------- #
# checkpoint save through the pipeline
# --------------------------------------------------------------------------- #
class TestCheckpointWritePath:
    def _state(self):
        return {
            "w": np.arange(4096, dtype=np.float32).reshape(64, 64),
            "b": np.ones(17, dtype=np.float64),
            "step": np.int32(3),
        }

    def test_byte_identical_to_legacy_sync_path(self):
        from repro.ckpt.manager import save_checkpoint

        state = self._state()
        wb_store = open_store("mem://wb-ckpt", fresh=True)
        save_checkpoint(wb_store, "ckpt", 7, state,
                        policy=IOPolicy(blocksize=4096, write_depth=3))

        legacy = open_store("mem://legacy-ckpt", fresh=True)
        import jax

        leaves = jax.device_get(jax.tree_util.tree_flatten(state)[0])
        for idx, leaf in enumerate(leaves):
            legacy.put(f"ckpt/step_{7:08d}/{idx:06d}.raw",
                       np.asarray(leaf).tobytes())
        for idx in range(len(leaves)):
            key = f"ckpt/step_{7:08d}/{idx:06d}.raw"
            assert wb_store.get(key) == legacy.get(key)
        manifest = json.loads(wb_store.get(f"ckpt/step_{7:08d}/MANIFEST.json"))
        assert manifest["step"] == 7
        assert len(manifest["leaves"]) == len(leaves)

    def test_roundtrip_through_uri_store(self):
        from repro.ckpt.manager import restore_checkpoint, save_checkpoint

        state = self._state()
        uri = "sims3://ckpt-uri?latency_ms=1"
        save_checkpoint(uri, "ckpt", 1, state,
                        policy=IOPolicy(blocksize=2048, write_depth=4))
        restored, manifest = restore_checkpoint(uri, "ckpt", state)
        assert manifest["step"] == 1
        for a, b in zip(np.asarray(restored["w"]), state["w"]):
            np.testing.assert_array_equal(a, b)

    def test_failed_save_leaves_no_manifest(self):
        from repro.ckpt.manager import latest_step, save_checkpoint

        store = open_store("sims3://ckpt-fail?latency_ms=0", fresh=True)
        store.put_link.fail_next(1000)
        with pytest.raises(StoreError):
            save_checkpoint(store, "ckpt", 5, self._state(),
                            policy=IOPolicy(max_retries=0, blocksize=1024))
        # inspect the substrate directly: the failed step must be invisible
        assert latest_step(store.backing, "ckpt") is None


# --------------------------------------------------------------------------- #
# satellite fixes
# --------------------------------------------------------------------------- #
class TestSatellites:
    def test_exists_propagates_transient_errors(self):
        s = open_store("sims3://ex?latency_ms=0", fresh=True)
        s.backing.put("k", b"x")
        s.link.fail_next(1)
        with pytest.raises(TransientStoreError):
            s.exists("k")            # throttled != missing
        assert s.exists("k") is True
        assert s.exists("nope") is False

    def test_rolling_prefetcher_refuses_restart_after_close(self):
        store = open_store("mem://rp", fresh=True)
        store.put("a", payload(256))
        pf = RollingPrefetcher(store, [ObjectMeta("a", 256)], [MemTier(4096)],
                               blocksize=64, eviction_interval_s=0.01)
        with pf:
            assert pf.read_range(0, 256) == payload(256)
        pf.close()   # double close is a no-op
        assert pf._threads == []
        with pytest.raises(RuntimeError, match="cannot restart"):
            pf.start()

    def test_open_many_on_closed_fs_issues_no_store_requests(self):
        class CountingStore(MemStore):
            def __init__(self):
                super().__init__()
                self.size_calls = 0

            def size(self, key):
                self.size_calls += 1
                return super().size(key)

        store = CountingStore()
        store.put("k", b"x")
        fs = PrefetchFS(store)
        fs.close()
        with pytest.raises(ValueError, match="closed PrefetchFS"):
            fs.open_many(["k"])      # string key would need a size() lookup
        assert store.size_calls == 0

    def test_concurrent_open_close_stats(self):
        """Stats folding must stay consistent under concurrent
        open/read/close/stats from many threads."""
        objects = {f"f{i}": payload(2048, seed=i) for i in range(4)}
        store = open_store("mem://conc", fresh=True)
        for k, v in objects.items():
            store.put(k, v)
        fs = PrefetchFS(store, policy=IOPolicy(engine="sequential",
                                               blocksize=512))
        n_threads, n_iters = 6, 10
        errors = []

        def reader_worker(tid):
            try:
                for _ in range(n_iters):
                    f = fs.open(f"f{tid % 4}")
                    f.read()
                    f.close()
                    fs.stats()
            except Exception as e:  # repro: allow[RP005] — stashed; asserted after join
                errors.append(e)

        def writer_worker(tid):
            try:
                for i in range(n_iters):
                    with fs.open_write(f"out/{tid}/{i}", blocksize=4096) as w:
                        w.write(payload(1000, seed=tid))
                    fs.stats()
            except Exception as e:  # repro: allow[RP005] — stashed; asserted after join
                errors.append(e)

        threads = [threading.Thread(target=reader_worker, args=(t,))
                   for t in range(n_threads)]
        threads += [threading.Thread(target=writer_worker, args=(t,))
                    for t in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == []
        snap = fs.stats().snapshot()
        want_read = sum(len(objects[f"f{t % 4}"]) * n_iters
                        for t in range(n_threads))
        assert snap["per_engine"]["sequential"]["bytes_read"] == want_read
        assert snap["per_engine"]["sequential"]["opens"] == n_threads * n_iters
        assert snap["per_engine"]["write-behind"]["bytes_uploaded"] == \
            2 * n_iters * 1000
        fs.close()

    def test_writer_protocol_surface(self):
        fs = make_fs()
        w = fs.open_write("k")
        assert isinstance(w, Writer)
        assert w.tell() == 0
        w.write(b"abcd")
        assert w.tell() == 4
        assert not w.closed
        w.close()
        assert w.closed
        fs.close()
