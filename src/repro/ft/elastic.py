"""Elastic scaling: re-shard a live (or restored) state onto a new mesh.

Checkpoints store logical arrays (full shapes); restore targets carry the
NEW topology's shardings, so growing 256 -> 512 chips (or shrinking after
losing a pod) is a restore with a different rules/mesh pair — no format
change. This module also reshards in-memory trees for mid-job elasticity
and persists post-reshard snapshots through the write-behind checkpoint
path so the (expensive) resize is immediately crash-safe.
"""

from __future__ import annotations

import jax

from repro.io import IOPolicy
from repro.models.spec import param_shardings
from repro.sharding.rules import ShardingRules


def reshard_tree(tree, shardings):
    """device_put every leaf onto the paired sharding (None = replicate)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings
    )


def reshard_params(params, spec_tree, rules: ShardingRules):
    """Re-shard a parameter tree onto `rules.mesh` per the declarative spec."""
    return reshard_tree(params, param_shardings(spec_tree, rules))


def snapshot_resharded(
    store,
    prefix: str,
    step: int,
    tree,
    shardings,
    *,
    extra: dict | None = None,
    policy: IOPolicy | None = None,
) -> dict:
    """Reshard `tree` onto `shardings` and persist it as a checkpoint.

    After an elastic resize the first post-reshard snapshot is the new
    recovery point — losing it replays the whole resize. Uploads go
    through the pipelined `save_checkpoint` (write-behind; manifest-last
    commit), so the snapshot costs max(T_reshard, T_upload) instead of
    their sum. `store` may be an `ObjectStore`, `PrefetchFS`, or URI.
    """
    from repro.ckpt.manager import save_checkpoint

    resharded = reshard_tree(tree, shardings)
    return save_checkpoint(store, prefix, step, resharded,
                           extra=extra, policy=policy)


def restore_resharded(
    store,
    prefix: str,
    template,
    *,
    host_id: int,
    num_hosts: int,
    step: int | None = None,
    policy: IOPolicy | None = None,
    **kw,
):
    """Mesh-sharded restore for an elastic topology change: delegates to
    ``restore_checkpoint(shard=(host_id, num_hosts))``, so each host of
    the NEW mesh warms only its rendezvous-owned slice of the checkpoint
    stream and fills the rest from siblings when `store` routes through a
    ``peer://`` group.

    This is how a replacement host after a failure warms cheaply: the
    survivors still hold (and serve) their shards from the previous
    restore, so the newcomer's full-stream read costs ~its own shard in
    backing-store traffic — everything else arrives over the LAN. The
    ``shard`` ids must be the mesh's ``(process_index, process_count)``
    (see ``repro.launch.mesh.mesh_host_shard``) so the warmed blocks line
    up with where the peer group routes requests for them.
    """
    from repro.ckpt.manager import restore_checkpoint

    return restore_checkpoint(store, prefix, template, step=step,
                              policy=policy, shard=(host_id, num_hosts),
                              **kw)
