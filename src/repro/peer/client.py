"""PeerClient: one host's RPC endpoint to one sibling's BlockServer.

Pools persistent connections (a socket per concurrent RPC, reused across
requests), retries through the shared `repro.io.retry` machinery
(`PeerError` subclasses `TransientStoreError`, so a flaky LAN hop gets
the same full-jitter backoff as a flaky store), bills every payload to
the peer `LinkModel` — the ONLY place peer bytes are billed, so the LAN
hop is charged exactly once per block — and routes a `FaultSchedule`'s
``peer_*`` ops through the transport for chaos tests (stalls, transient
refusals, mid-transfer cuts).
"""

from __future__ import annotations

import socket
import threading
import time

from repro.io.integrity import IntegrityError, block_digest, check_block
from repro.io.retry import Retrier, RetryPolicy
from repro.peer.protocol import PeerError, recv_msg, send_msg, span_block_id
from repro.store.link import LinkModel

#: Peer RPCs fail fast: the fallback (a direct backing-store GET) is
#: always available, so burning seconds retrying a sick sibling is worse
#: than degrading. One retry absorbs a blip; anything longer marks the
#: peer suspect.
PEER_RETRY = RetryPolicy(max_retries=1, backoff_s=0.01, backoff_cap_s=0.05)


class PeerClient:
    def __init__(
        self,
        address: tuple[str, int],
        *,
        link: LinkModel | None = None,
        retry: RetryPolicy | None = None,
        timeout_s: float = 10.0,
        faults=None,
        peer_id: int = -1,
    ) -> None:
        self.address = (address[0], int(address[1]))
        self.link = link
        self.timeout_s = timeout_s
        self.faults = faults   # FaultSchedule | None (duck-typed: .decide)
        self.peer_id = peer_id
        self._retrier = Retrier(retry if retry is not None else PEER_RETRY)
        self._idle: list[socket.socket] = []
        self._lock = threading.Lock()
        self._closed = False
        # Telemetry.
        self.rpcs = 0
        self.failures = 0
        self.integrity_failures = 0
        self.bytes_received = 0
        self.bytes_sent = 0

    # -- connection pool ----------------------------------------------------
    def _checkout(self) -> socket.socket:
        with self._lock:
            if self._closed:
                raise PeerError(f"peer client {self.peer_id}: closed")
            if self._idle:
                return self._idle.pop()
        try:
            sock = socket.create_connection(self.address,
                                            timeout=self.timeout_s)
        except OSError as e:
            raise PeerError(
                f"peer {self.peer_id} unreachable at "
                f"{self.address[0]}:{self.address[1]}: {e}"
            ) from e
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    def _checkin(self, sock: socket.socket) -> None:
        with self._lock:
            if not self._closed:
                self._idle.append(sock)
                return
        try:
            sock.close()
        except OSError:
            pass

    def close(self) -> None:
        with self._lock:
            self._closed = True
            idle, self._idle = self._idle, []
        for s in idle:
            try:
                s.close()
            except OSError:
                pass

    # -- fault injection ----------------------------------------------------
    def _inject(self, op: str, key: str) -> set[str]:
        """Apply scheduled transport faults for one attempt. Returns the
        set of *deferred* fault kinds — ``"cut"`` (the attempt completes
        and THEN loses its connection: the bytes crossed the wire, the
        socket did not survive to tell us) and ``"corrupt"`` (a byte of
        the received BLOCK frame payload is flipped in transit — the
        digest carried in the frame header no longer matches)."""
        if self.faults is None:
            return set()
        deferred: set[str] = set()
        for f in self.faults.decide(op, key):
            kind = getattr(f, "kind", None)
            if kind == "stall":
                time.sleep(getattr(f, "stall_s", 0.0))
            elif kind in ("transient", "throttle"):
                with self._lock:
                    self.failures += 1
                raise PeerError(f"{op} {key}: injected peer fault ({kind})")
            elif kind in ("cut", "corrupt"):
                deferred.add(kind)
        return deferred

    # -- RPC core -----------------------------------------------------------
    def _request_once(self, op: str, header: dict,
                      payload: bytes, key: str) -> tuple[dict, bytes]:
        deferred = self._inject(op, key)
        sock = self._checkout()
        try:
            send_msg(sock, header, payload)
            resp, data = recv_msg(sock)
        except (OSError, PeerError) as e:
            with self._lock:
                self.failures += 1
            try:
                sock.close()
            except OSError:
                pass
            if isinstance(e, PeerError):
                raise
            raise PeerError(
                f"peer {self.peer_id}: {op} failed: {e}"
            ) from e
        if "cut" in deferred:
            # The response arrived but the connection is declared dead
            # mid-transfer: drop it and fail the attempt — the retry (or
            # the caller's store fallback) must re-request, and the
            # re-request must observe byte-identical data.
            with self._lock:
                self.failures += 1
            try:
                sock.close()
            except OSError:
                pass
            raise PeerError(f"peer {self.peer_id}: {op} {key}: "
                            "connection cut mid-transfer")
        self._checkin(sock)
        if not resp.get("ok"):
            raise PeerError(
                f"peer {self.peer_id}: {op} {key}: remote error: "
                f"{resp.get('error')}"
            )
        if "corrupt" in deferred and data:
            # In-transit frame corruption: flip one byte of the payload
            # AFTER the frame was received intact — the header (and its
            # digest) survive, the block bytes do not. Detection is the
            # digest check below, exactly as it would be in production.
            buf = bytearray(data)
            buf[self.faults.rand_index(len(buf))] ^= 0xFF
            data = bytes(buf)
        digest = resp.get("digest")
        if digest is not None and data:
            # Verify the payload against the digest the sibling attested
            # in the frame header. A mismatch — bit-flipped in transit or
            # a byzantine peer serving wrong bytes under a correct-length
            # frame — degrades to a failed attempt, never to wrong data.
            try:
                check_block(data, digest, what=f"peer {self.peer_id} {op} {key}")
            except IntegrityError as e:
                with self._lock:
                    self.failures += 1
                    self.integrity_failures += 1
                raise PeerError(str(e)) from e
        with self._lock:
            self.rpcs += 1
            self.bytes_received += len(data)
            self.bytes_sent += len(payload)
        return resp, data

    def _rpc(self, op: str, header: dict, payload: bytes = b"",
             key: str = "") -> tuple[dict, bytes]:
        resp, data = self._retrier.call(
            lambda: self._request_once(op, header, payload, key),
            label=f"peer {self.peer_id} {op} {key}",
        )
        if self.link is not None and (data or payload):
            # Bill the LAN hop exactly once, for the dominant direction.
            self.link.transfer(max(len(data), len(payload)))
        return resp, data

    # -- public ops ---------------------------------------------------------
    def ping(self) -> bool:
        """Single-attempt liveness probe (the heartbeat IS the retry
        loop; wrapping it in another one would just slow down death
        detection). Never raises."""
        try:
            self._inject("peer_ping", "")
            sock = self._checkout()
            try:
                send_msg(sock, {"op": "ping"})
                resp, _ = recv_msg(sock)
            except (OSError, PeerError):
                try:
                    sock.close()
                except OSError:
                    pass
                return False
            self._checkin(sock)
            return bool(resp.get("ok"))
        except PeerError:
            return False

    def fetch(self, key: str, start: int, end: int, *,
              owner: bool = False) -> bytes | None:
        """Fetch block bytes from the sibling. ``owner=True`` authorizes
        the sibling — this block's home host — to perform the one
        backing-store GET on a miss; ``owner=False`` is a pure cache
        probe. Returns None on a miss; raises `PeerError` when the
        sibling is unreachable (after retries)."""
        bid = span_block_id(key, start, end)
        header = {"op": "fetch", "key": key, "start": start, "end": end,
                  "owner": owner}
        resp, data = self._rpc("peer_fetch", header, key=bid)
        if resp.get("status") == "miss":
            return None
        if len(data) != end - start:
            raise PeerError(
                f"peer {self.peer_id}: truncated block {bid}: "
                f"got {len(data)} of {end - start} bytes"
            )
        return data

    def put(self, key: str, start: int, end: int, data: bytes) -> bool:
        """Push a block to the sibling (HSM demotion into a `PeerTier`
        homed there). Returns True when the sibling stored it."""
        bid = span_block_id(key, start, end)
        # Attest what we are pushing: the sibling re-verifies before
        # publishing, so a frame corrupted on the way OVER is rejected
        # there instead of poisoning its cache.
        header = {"op": "put", "key": key, "start": start, "end": end,
                  "digest": block_digest(data)}
        resp, _ = self._rpc("peer_put", header, payload=data, key=bid)
        return resp.get("status") == "stored"

    def has(self, key: str, start: int, end: int) -> bool:
        bid = span_block_id(key, start, end)
        header = {"op": "has", "key": key, "start": start, "end": end}
        resp, _ = self._rpc("peer_has", header, key=bid)
        return resp.get("status") == "hit"

    def snapshot(self) -> dict:
        with self._lock:
            return dict(rpcs=self.rpcs, failures=self.failures,
                        integrity_failures=self.integrity_failures,
                        bytes_received=self.bytes_received,
                        bytes_sent=self.bytes_sent)
