"""Unified I/O subsystem: the `PrefetchFS` facade, `IOPolicy` config, the
`Reader` protocol and reader-engine registry on the consume side, and the
URI store registry plus write-behind `Writer` pipeline on the produce side.

This is the one construction path for prefetched reads AND pipelined
writes — the S3Fs-shaped API the paper argues for, extended with policy
objects and backend registries so new engines and stores plug in without
touching call sites::

    from repro.io import IOPolicy, PrefetchFS, open_store

    fs = PrefetchFS("sims3://bucket?latency_ms=40&bw_mbps=200",
                    policy=IOPolicy(engine="rolling", blocksize=1 << 20))
    with fs.open_many(files) as f:      # one logical stream over many objects
        data = f.read()
    with fs.open_write("out/key") as w:  # background part uploads
        w.write(data)                    # close() = durable atomic publish
    print(fs.stats().snapshot())
"""

from repro.io.fs import FSStats, PrefetchFS
from repro.io.policy import IOPolicy
from repro.io.reader import DirectReader, DirectStats, Reader
from repro.io.registry import available_engines, engine_spec, register_reader
from repro.io.retry import Hedger, Retrier, RetryPolicy
from repro.io.stores import (
    StoreURI,
    available_stores,
    clear_store_cache,
    open_store,
    parse_store_uri,
    register_store,
)
from repro.io.write import UploadPool, Writer, WriteStats

__all__ = [
    "FSStats",
    "PrefetchFS",
    "IOPolicy",
    "Reader",
    "DirectReader",
    "DirectStats",
    "available_engines",
    "engine_spec",
    "register_reader",
    "RetryPolicy",
    "Retrier",
    "Hedger",
    "StoreURI",
    "available_stores",
    "clear_store_cache",
    "open_store",
    "parse_store_uri",
    "register_store",
    "UploadPool",
    "Writer",
    "WriteStats",
]
