"""Shared crash-consistent cache tiers (PR tentpole + satellites).

Covers: the persistent journaled `DirTier` (crash recovery, torn-block
discard, orphan/tmp cleanup, collision-free filenames), the shared
`CacheIndex` (single-flight fetch registration, refcount-aware eviction,
warm reuse), cross-reader sharing for the rolling AND sequential engines
through `PrefetchFS`, warm restarts (zero store GETs for recovered
blocks), and the write-path fixes (UploadPool submit/close race,
Writer.abort multipart part leak, tier `used` overwrite accounting).
"""

from __future__ import annotations

import json
import os
import threading
import time

import pytest

from repro.io import IOPolicy, PrefetchFS, UploadPool
from repro.store import (
    BlockMeta,
    CacheIndex,
    DirStore,
    DirTier,
    LinkModel,
    MemTier,
    SimS3Store,
)
from repro.store.base import ObjectMeta, StoreError


def payload(n: int, seed: int = 0) -> bytes:
    return bytes((i * 31 + seed * 7) % 256 for i in range(n))


def make_store(objects: dict[str, bytes], latency=0.0, **kw) -> SimS3Store:
    store = SimS3Store(link=LinkModel(latency_s=latency, **kw))
    for k, v in objects.items():
        store.backing.put(k, v)
    return store


def metas(store) -> list[ObjectMeta]:
    return store.backing.list_objects()


# --------------------------------------------------------------------------- #
# DirTier filename encoding (satellite: key-collision fix)
# --------------------------------------------------------------------------- #
class TestDirTierPathEncoding:
    def test_slash_and_literal_underscores_do_not_collide(self, tmp_path):
        """Regression: the old `replace("/", "__")` mapped distinct ids
        `a/b` and `a__b` onto the same file and silently served wrong
        bytes."""
        tier = DirTier(1 << 20, root=str(tmp_path / "t"))
        tier.write("a/b", b"slash")
        tier.write("a__b", b"underscore")
        assert tier.read("a/b") == b"slash"
        assert tier.read("a__b") == b"underscore"

    def test_hostile_ids_roundtrip(self, tmp_path):
        tier = DirTier(1 << 20, root=str(tmp_path / "t"))
        ids = ["k@000-100", "k%2Fx", "a/b/c", "a b c", "%", "..", "blk-x",
               "_index.jsonl"]
        for i, bid in enumerate(ids):
            tier.write(bid, payload(32, seed=i))
        for i, bid in enumerate(ids):
            assert tier.read(bid) == payload(32, seed=i), bid
        # The journal survived writes of ids that mimic its own name.
        tier.close()
        tier2 = DirTier(1 << 20, root=str(tmp_path / "t"))
        assert tier2.recovered_blocks == len(ids)


# --------------------------------------------------------------------------- #
# tier `used` accounting (satellite: overwrite double-count fix)
# --------------------------------------------------------------------------- #
class TestOverwriteAccounting:
    @pytest.mark.parametrize("make_tier", [
        lambda tmp: MemTier(1 << 20),
        lambda tmp: DirTier(1 << 20, root=str(tmp / "t")),
    ])
    def test_overwrite_credits_replaced_bytes(self, tmp_path, make_tier):
        tier = make_tier(tmp_path)
        data = payload(1000)
        for _ in range(3):
            assert tier.reserve(len(data))
            tier.write("blk", data)
            tier.commit(len(data))
        # Without the credit-back, used would read 3000 until some later
        # verify_used() happened to run.
        assert tier.used == len(data)
        assert tier.verify_used() == tier.capacity - len(data)


# --------------------------------------------------------------------------- #
# DirTier journal: persistence + crash recovery (satellite: crash test)
# --------------------------------------------------------------------------- #
class TestDirTierPersistence:
    def test_restart_recovers_index_and_used(self, tmp_path):
        root = str(tmp_path / "cache")
        tier = DirTier(1 << 20, root=root)
        blocks = {f"k@{i}": payload(200 + i, seed=i) for i in range(5)}
        for bid, data in blocks.items():
            tier.write(bid, data, meta=BlockMeta(key="k", offset=0))
        tier.delete("k@0")
        del blocks["k@0"]

        tier.close()   # "process" dies; the restart owns the root
        tier2 = DirTier(1 << 20, root=root)
        assert tier2.recovered_blocks == len(blocks)
        assert dict(tier2.resident_blocks()) == {
            bid: len(d) for bid, d in blocks.items()
        }
        # `used` is seeded with the recovered bytes, so reserve() cannot
        # overshoot the budget, and verify_used is already consistent.
        assert tier2.used == sum(len(d) for d in blocks.values())
        for bid, data in blocks.items():
            assert tier2.read(bid) == data

    def test_crash_between_tmp_write_and_replace(self, tmp_path, monkeypatch):
        """Kill the tier mid-`_write` (after the tmp file, before the
        atomic rename): reconstruction must recover the intact blocks,
        discard the torn one, and converge verify_used."""
        import repro.store.tiers as tiers_mod

        root = str(tmp_path / "cache")
        tier = DirTier(1 << 20, root=root)
        tier.write("good", payload(300))

        real_replace = os.replace

        def crashing_replace(src, dst):
            if os.path.basename(dst).startswith(DirTier.BLOCK_PREFIX):
                raise OSError("injected crash before rename")
            return real_replace(src, dst)

        monkeypatch.setattr(tiers_mod.os, "replace", crashing_replace)
        with pytest.raises(OSError):
            tier.write("torn", payload(400))
        monkeypatch.setattr(tiers_mod.os, "replace", real_replace)

        tier.close()
        tier2 = DirTier(1 << 20, root=root)
        assert tier2.recovered_blocks == 1
        assert tier2.read("good") == payload(300)
        assert not tier2.contains("torn")
        # No leftover tmp files, and accounting converges to reality.
        assert not [f for f in os.listdir(root) if f.endswith(".tmp")]
        assert tier2.used == 300
        assert tier2.verify_used() == tier2.capacity - 300
        assert tier2._resident_bytes() == 300

    def test_torn_block_discarded_by_checksum(self, tmp_path):
        root = str(tmp_path / "cache")
        tier = DirTier(1 << 20, root=root)
        tier.write("good", payload(300))
        tier.write("torn", payload(400))
        # Corrupt "torn" behind the journal's back (a partial flush the
        # rename made visible anyway, bit rot, ...).
        with open(tier._path("torn"), "wb") as f:
            f.write(payload(400)[:123])

        tier.close()
        tier2 = DirTier(1 << 20, root=root)
        assert tier2.recovered_blocks == 1
        assert tier2.discarded_blocks == 1
        assert not tier2.contains("torn")          # file deleted too
        assert tier2.read("good") == payload(300)

    def test_torn_journal_tail_is_ignored(self, tmp_path):
        root = str(tmp_path / "cache")
        tier = DirTier(1 << 20, root=root)
        tier.write("a", payload(100))
        with open(os.path.join(root, DirTier.INDEX_NAME), "a") as f:
            f.write('{"op": "put", "id": "half')   # crash mid-append
        tier.close()
        tier2 = DirTier(1 << 20, root=root)
        assert tier2.recovered_blocks == 1
        assert tier2.read("a") == payload(100)

    def test_transient_staging_not_resurrected(self, tmp_path):
        """Write-behind staging parts (durable=False) must die with the
        process — recovery deletes them as orphans."""
        root = str(tmp_path / "cache")
        tier = DirTier(1 << 20, root=root)
        tier.write("wb/0001/out/000000", payload(256), durable=False)
        tier.write("real", payload(100))
        assert tier.contains("wb/0001/out/000000")
        assert tier.resident_blocks() == [("real", 100)]

        tier.close()
        tier2 = DirTier(1 << 20, root=root)
        assert not tier2.contains("wb/0001/out/000000")
        assert tier2.resident_blocks() == [("real", 100)]

    def test_second_live_tier_is_nondestructive(self, tmp_path):
        """A sibling DirTier over the same root (two replicas sharing a
        node's cache dir) must never sweep the live owner's files: it
        recovers read-only and skips orphan/torn cleanup + compaction."""
        root = str(tmp_path / "cache")
        owner = DirTier(1 << 20, root=root)
        owner.write("a", payload(100))
        # A block file the journal doesn't know yet (mid-flight sibling
        # write between rename and journal append).
        with open(owner._path("inflight"), "wb") as f:
            f.write(payload(50))

        sibling = DirTier(1 << 20, root=root)
        assert sibling.owns_root is False
        assert sibling.recovered_blocks == 1          # journal replayed
        assert os.path.exists(owner._path("inflight"))  # NOT swept
        assert owner.read("a") == payload(100)

        owner.close()
        sibling.close()
        restarted = DirTier(1 << 20, root=root)        # sole owner again
        assert restarted.owns_root is True
        assert not os.path.exists(owner._path("inflight"))  # now swept

    def test_fcntl_unavailable_fallback_is_single_owner(self, tmp_path,
                                                        monkeypatch):
        """Regression (non-POSIX fallback): without fcntl, EVERY opener
        used to believe it owned the root, and two live tiers would sweep
        each other's files as orphans. The marker-file fallback makes
        ownership first-opener-wins; later openers recover read-only."""
        import repro.store.tiers as tiers_mod

        monkeypatch.setattr(tiers_mod, "fcntl", None)
        root = str(tmp_path / "cache")
        owner = DirTier(1 << 20, root=root)
        assert owner.owns_root is True
        owner.write("a", payload(100))
        # A block file the journal doesn't know (mid-flight sibling write).
        with open(owner._path("inflight"), "wb") as f:
            f.write(payload(50))

        sibling = DirTier(1 << 20, root=root)
        assert sibling.owns_root is False              # NOT a second owner
        assert sibling.recovered_blocks == 1           # journal replayed
        assert os.path.exists(owner._path("inflight"))  # not swept
        assert sibling.read("a") == payload(100)

        owner.close()
        sibling.close()
        reopened = DirTier(1 << 20, root=root)         # marker released
        assert reopened.owns_root is True
        assert not os.path.exists(owner._path("inflight"))  # owner sweeps
        reopened.close()

    def test_fcntl_unavailable_stale_marker_is_conservative(self, tmp_path,
                                                            monkeypatch):
        """A crash leaves the owner marker behind; the next opener must
        come up read-only (never destructive) until it is removed."""
        import repro.store.tiers as tiers_mod

        monkeypatch.setattr(tiers_mod, "fcntl", None)
        root = str(tmp_path / "cache")
        crashed = DirTier(1 << 20, root=root)
        crashed.write("a", payload(64))
        # No close(): simulated crash; the marker file is still there.
        after = DirTier(1 << 20, root=root)
        assert after.owns_root is False
        assert after.read("a") == payload(64)
        os.remove(os.path.join(root, DirTier.LOCK_NAME + ".owner"))
        reclaimed = DirTier(1 << 20, root=root)
        assert reclaimed.owns_root is True
        reclaimed.close()

    def test_compaction_racing_nonowner_writer_keeps_its_blocks(self,
                                                                tmp_path):
        """Satellite: owner journal compaction racing a live read-only
        sibling's writes. The compaction rewrite replays the journal under
        the cross-process flock, so records the sibling appended mid-churn
        survive — a restart recovers BOTH writers' blocks."""
        root = str(tmp_path / "cache")
        owner = DirTier(1 << 20, root=root)
        owner._COMPACT_SLACK = 10       # compact every ~15 records
        sibling = DirTier(1 << 20, root=root)
        assert sibling.owns_root is False
        stop, errs = threading.Event(), []

        def sib_writes():
            try:
                i = 0
                while not stop.is_set():
                    sibling.write(f"sib{i % 10}", payload(64, seed=i))
                    i += 1
            except Exception as e:   # repro: allow[RP005] — surfaced below
                errs.append(e)

        t = threading.Thread(target=sib_writes)
        t.start()
        # Owner churn forces repeated compaction while the sibling writes.
        for round_ in range(20):
            for i in range(5):
                owner.write(f"own{i}", payload(64, seed=round_))
        stop.set()
        t.join(timeout=30)
        assert not errs
        owner.close()
        sibling.close()

        restarted = DirTier(1 << 20, root=root)
        resident = dict(restarted.resident_blocks())
        for i in range(5):
            assert f"own{i}" in resident
            assert restarted.read(f"own{i}") == payload(64, seed=19)
        sib_blocks = [b for b in resident if b.startswith("sib")]
        assert sib_blocks, "sibling's journal records lost in compaction"
        for bid in sib_blocks:
            assert len(restarted.read(bid)) == 64
        restarted.close()

    def test_journal_compaction_preserves_state(self, tmp_path):
        root = str(tmp_path / "cache")
        tier = DirTier(1 << 20, root=root)
        tier._COMPACT_SLACK = 10
        for round_ in range(8):
            for i in range(5):
                tier.write(f"b{i}", payload(64, seed=round_))
        journal = os.path.join(root, DirTier.INDEX_NAME)
        with open(journal) as f:
            assert len(f.readlines()) <= 15   # compacted, not 40 records
        tier.close()
        tier2 = DirTier(1 << 20, root=root)
        assert tier2.recovered_blocks == 5
        for i in range(5):
            assert tier2.read(f"b{i}") == payload(64, seed=7)


# --------------------------------------------------------------------------- #
# CacheIndex unit behaviour
# --------------------------------------------------------------------------- #
class TestCacheIndex:
    def _tier(self) -> MemTier:
        return MemTier(1 << 20)

    def test_single_flight_and_waiter_pinning(self):
        tier = self._tier()
        idx = CacheIndex([tier])
        kind, flight = idx.acquire("b")
        assert kind == "leader"
        kind2, flight2 = idx.acquire("b")
        assert kind2 == "wait" and flight2 is flight
        tier.reserve(3)
        tier.write("b", b"xyz")
        tier.commit(3)
        idx.publish(flight, tier, 3)
        assert idx.join(flight) == ("hit", tier)
        # Leader + one waiter hold pins: first want_evict unpin keeps the
        # block alive for the other reader.
        assert idx.unpin("b", want_evict=True) is False
        assert tier.contains("b")
        assert idx.unpin("b", want_evict=True) is True
        assert not tier.contains("b")
        assert tier.used == 0

    def test_keep_cached_defers_to_capacity_pressure(self):
        tier = self._tier()
        idx = CacheIndex([tier], keep_cached=True)
        kind, flight = idx.acquire("b")
        assert kind == "leader"
        tier.reserve(4)
        tier.write("b", b"data")
        tier.commit(4)
        idx.publish(flight, tier, 4)
        assert idx.unpin("b", want_evict=True) is False   # kept warm
        assert tier.contains("b")
        kind, t = idx.acquire("b")                        # next epoch: hit
        assert kind == "hit" and t is tier
        idx.unpin("b")
        assert idx.evict_from(tier, 1) == 4               # pressure evicts
        assert not tier.contains("b")

    def test_pinned_blocks_survive_pressure_eviction(self):
        tier = self._tier()
        idx = CacheIndex([tier])
        for bid in ("p", "q"):
            _, fl = idx.acquire(bid)
            tier.reserve(2)
            tier.write(bid, b"..")
            tier.commit(2)
            idx.publish(fl, tier, 2)
        idx.unpin("q")   # q unpinned -> evictable; p still pinned
        assert idx.evict_from(tier, 1 << 10) == 2
        assert tier.contains("p") and not tier.contains("q")

    def test_leader_failure_lets_waiters_take_over(self):
        idx = CacheIndex([self._tier()])
        kind, flight = idx.acquire("b")
        assert kind == "leader"
        kind, same = idx.acquire("b")
        assert kind == "wait"
        idx.abort_fetch(flight, StoreError("boom"))
        kind, err = idx.join(same)
        assert kind == "failed" and isinstance(err, StoreError)
        kind, retry = idx.acquire("b")
        assert kind == "leader"    # the waiter retries as the new leader
        idx.abort_fetch(retry)

    def test_primes_from_persistent_tier(self, tmp_path):
        root = str(tmp_path / "cache")
        tier = DirTier(1 << 20, root=root)
        tier.write("warm", payload(128))
        tier.close()
        tier2 = DirTier(1 << 20, root=root)
        idx = CacheIndex([tier2])
        assert idx.recovered == 1
        kind, t = idx.acquire("warm")
        assert kind == "hit" and t is tier2
        assert t.read("warm") == payload(128)


# --------------------------------------------------------------------------- #
# cross-reader single flight through PrefetchFS
# --------------------------------------------------------------------------- #
class TestSharedReaders:
    def test_n_rolling_readers_fetch_each_block_once(self):
        objects = {"f": payload(16 << 10)}
        store = make_store(objects, latency=0.004)
        n_readers, blocksize = 4, 1024
        nblocks = len(objects["f"]) // blocksize
        fs = PrefetchFS(store,
                        policy=IOPolicy(engine="rolling", blocksize=blocksize,
                                        keep_cached=True,
                                        eviction_interval_s=0.01),
                        tiers=[MemTier(1 << 20)])
        results, readers, errs = [None] * n_readers, [None] * n_readers, []

        def run(i):
            try:
                f = fs.open("f")
                readers[i] = f
                results[i] = f.read()
                f.close()
            except Exception as e:   # repro: allow[RP005] — surfaced below
                errs.append(e)

        threads = [threading.Thread(target=run, args=(i,))
                   for i in range(n_readers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        fs.close()
        assert not errs
        assert all(r == objects["f"] for r in results)
        # The tentpole claim: N concurrent readers of one file issue ~1x
        # (not Nx) block fetches — every block crosses the store once.
        total_fetched = sum(r.stats.blocks_fetched for r in readers)
        assert total_fetched == nblocks
        served = sum(r.stats.blocks_fetched + r.stats.cache_hits
                     + r.stats.flight_joins for r in readers)
        assert served == n_readers * nblocks

    def test_reopen_is_warm_with_keep_cached(self):
        objects = {"f": payload(8 << 10)}
        store = make_store(objects)
        fs = PrefetchFS(store,
                        policy=IOPolicy(engine="rolling", blocksize=1024,
                                        keep_cached=True,
                                        eviction_interval_s=0.01),
                        tiers=[MemTier(1 << 20)])
        with fs:
            f1 = fs.open("f")
            assert f1.read() == objects["f"]
            f1.close()
            f2 = fs.open("f")
            assert f2.read() == objects["f"]
            f2.close()
            assert f2.stats.blocks_fetched == 0      # second epoch: all warm
            assert f2.stats.cache_hits == 8
            assert fs.stats().cache["hits"] >= 8

    def test_backward_seek_served_from_warm_cache(self):
        """With keep_cached, a backward seek to a consumed block is a
        local cache hit, not a fresh store GET."""
        objects = {"f": payload(4096)}
        store = make_store(objects)
        fs = PrefetchFS(store,
                        policy=IOPolicy(engine="rolling", blocksize=1024,
                                        keep_cached=True,
                                        eviction_interval_s=0.01),
                        tiers=[MemTier(1 << 20)])
        with fs:
            f = fs.open("f")
            assert f.read() == objects["f"]
            f.seek(0)
            assert f.read(1024) == objects["f"][:1024]
            assert f.stats.direct_reads == 0
            assert f.stats.cache_hits >= 1
            f.close()

    def test_sequential_engine_shares_through_fs_tiers(self):
        objects = {"f": payload(4 << 10)}
        store = make_store(objects)
        fs = PrefetchFS(store,
                        policy=IOPolicy(engine="sequential", blocksize=512,
                                        keep_cached=True),
                        tiers=[MemTier(1 << 20)])
        with fs:
            r1 = fs.open("f")
            assert r1.read() == objects["f"]
            r2 = fs.open("f")
            assert r2.read() == objects["f"]
            assert r1.stats.store_requests == 8
            assert r2.stats.store_requests == 0
            assert r2.stats.cache_hits == 8

    def test_sequential_without_keep_cached_does_not_retain(self):
        """Default policy: published blocks are evicted once consumed, so
        a long-lived fs does not silently hold tier capacity."""
        objects = {"f": payload(2 << 10)}
        store = make_store(objects)
        tier = MemTier(1 << 20)
        fs = PrefetchFS(store,
                        policy=IOPolicy(engine="sequential", blocksize=512),
                        tiers=[tier])
        with fs:
            r1 = fs.open("f")
            assert r1.read() == objects["f"]
        assert tier.used == 0
        assert tier._resident_bytes() == 0

    def test_bare_sequential_baseline_unchanged(self):
        """No index -> the paper's baseline request shape is untouched."""
        from repro.core import SequentialFile

        objects = {"f": payload(4 << 10)}
        store = make_store(objects)
        f = SequentialFile(store, metas(store), blocksize=512)
        assert f.read() == objects["f"]
        assert f.stats.store_requests == f.stats.blocks_fetched == 8


# --------------------------------------------------------------------------- #
# warm restart: persistent DirTier + recovered index => zero store GETs
# --------------------------------------------------------------------------- #
class TestWarmRestart:
    def test_restarted_job_pays_zero_gets_for_cached_blocks(self, tmp_path):
        objects = {"f0": payload(6 << 10), "f1": payload(6 << 10, seed=1)}
        store = make_store(objects)
        root = str(tmp_path / "cache")
        policy = IOPolicy(engine="rolling", blocksize=1024, keep_cached=True,
                          eviction_interval_s=0.01)

        fs1 = PrefetchFS(store, policy=policy,
                         tiers=[DirTier(1 << 20, root=root)])
        with fs1:
            f = fs1.open_many(metas(store))
            assert f.read() == objects["f0"] + objects["f1"]
            f.close()
        cold_fetched = fs1.stats().totals["blocks_fetched"]
        assert cold_fetched == 12

        # "Restart": a brand-new tier object recovers the journal, a
        # brand-new fs primes its index from it.
        bytes_before = store.link.bytes_moved
        fs2 = PrefetchFS(store, policy=policy,
                         tiers=[DirTier(1 << 20, root=root)])
        with fs2:
            f = fs2.open_many(metas(store))
            assert f.read() == objects["f0"] + objects["f1"]
            f.close()
        snap = fs2.stats()
        assert snap.totals["blocks_fetched"] == 0
        assert snap.totals["cache_hits"] == 12
        assert snap.cache["recovered"] == 12
        # Only metadata (size HEADs) touched the link — zero data bytes.
        assert store.link.bytes_moved == bytes_before

    def test_ckpt_restore_cache_dir_makes_second_restore_warm(self, tmp_path):
        pytest.importorskip("jax")
        import numpy as np

        from repro.ckpt.manager import restore_checkpoint, save_checkpoint

        store = make_store({})
        rng = np.random.default_rng(0)
        state = {"w": rng.normal(size=(64, 16)).astype(np.float32),
                 "b": rng.normal(size=(256,)).astype(np.float32)}
        save_checkpoint(store, "ckpt", 3, state)
        cache = str(tmp_path / "wcache")

        r1, _ = restore_checkpoint(store, "ckpt", state, cache_dir=cache,
                                   policy=IOPolicy(engine="rolling",
                                                   blocksize=2048,
                                                   eviction_interval_s=0.01))
        bytes_before = store.link.bytes_moved
        r2, _ = restore_checkpoint(store, "ckpt", state, cache_dir=cache,
                                   policy=IOPolicy(engine="rolling",
                                                   blocksize=2048,
                                                   eviction_interval_s=0.01))
        for k in state:
            assert np.array_equal(np.asarray(r1[k]), state[k])
            assert np.array_equal(np.asarray(r2[k]), state[k])
        # Second restore re-reads the manifest but no leaf blocks.
        leaf_bytes = sum(a.nbytes for a in state.values())
        assert store.link.bytes_moved - bytes_before < leaf_bytes


# --------------------------------------------------------------------------- #
# UploadPool submit/close race (satellite)
# --------------------------------------------------------------------------- #
class TestUploadPoolClose:
    def test_jobs_accepted_before_close_all_run(self):
        pool = UploadPool()
        pool.ensure(2)
        done = []
        lock = threading.Lock()

        def job(i):
            def run():
                time.sleep(0.002)
                with lock:
                    done.append(i)
            return run

        for i in range(20):
            pool.submit(job(i))
        pool.close()   # sentinels must land BEHIND every accepted job
        assert sorted(done) == list(range(20))

    def test_submit_after_close_raises(self):
        pool = UploadPool()
        pool.ensure(1)
        pool.close()
        with pytest.raises(ValueError, match="closed UploadPool"):
            pool.submit(lambda: None)


# --------------------------------------------------------------------------- #
# Writer.abort multipart part leak (satellite)
# --------------------------------------------------------------------------- #
class TestWriterAbort:
    def test_abort_leaves_no_orphaned_parts_on_dirstore(self, tmp_path):
        store_root = str(tmp_path / "store")
        fs = PrefetchFS(DirStore(store_root),
                        policy=IOPolicy(blocksize=512, write_depth=2))
        w = fs.open_write("out/obj")
        for i in range(6):
            w.write(payload(512, seed=i))   # several multipart parts
        w.abort()
        fs.close()                          # drains in-flight pool jobs
        leftovers = [
            os.path.join(d, f)
            for d, _, files in os.walk(store_root) for f in files
        ]
        assert leftovers == [], f"orphaned part files: {leftovers}"

    def test_abort_drops_sims3_parts_and_never_publishes(self):
        store = make_store({})
        fs = PrefetchFS(store, policy=IOPolicy(blocksize=512, write_depth=2))
        w = fs.open_write("out/obj")
        for i in range(4):
            w.write(payload(512, seed=i))
        mp = w._mp
        w.abort()
        fs.close()
        assert mp._parts == {}
        assert not store.backing.list_objects("out/obj")

    def test_part_landing_during_abort_sweep_is_cleaned(self, tmp_path,
                                                        monkeypatch):
        """The race the fix closes: abort() sweeps part files while a
        `put_part` is between its abort-check and its rename — the rename
        used to resurrect the part file forever."""
        import repro.store.local as local_mod

        store = DirStore(str(tmp_path / "store"))
        mp = store.start_multipart("k")
        real_replace = os.replace

        def replace_then_abort(src, dst):
            real_replace(src, dst)
            mp.abort()   # abort lands right after the rename

        monkeypatch.setattr(local_mod.os, "replace", replace_then_abort)
        with pytest.raises(StoreError, match="aborted"):
            mp.put_part(0, b"data")
        monkeypatch.setattr(local_mod.os, "replace", real_replace)
        leftovers = [
            f for d, _, files in os.walk(str(tmp_path / "store"))
            for f in files
        ]
        assert leftovers == []


# --------------------------------------------------------------------------- #
# write staging stays transient on persistent tiers
# --------------------------------------------------------------------------- #
class TestStagingOnPersistentTier:
    def test_writer_not_starved_by_retained_cache_blocks(self):
        """A tier filled to capacity with keep_cached blocks must not
        starve the write path: staging backpressure pressure-evicts
        unpinned cache blocks instead of waiting forever on uploads that
        free nothing."""
        store = make_store({})
        data = bytes(256) * 512            # 128 KiB
        store.backing.put("f", data)
        tier = MemTier(128 << 10)          # exactly dataset-sized
        fs = PrefetchFS(store,
                        policy=IOPolicy(blocksize=32 << 10, keep_cached=True,
                                        eviction_interval_s=0.01),
                        tiers=[tier])
        f = fs.open("f")
        assert f.read() == data
        f.close()
        assert tier.used == 128 << 10      # fully retained
        done: list = []

        def produce():
            w = fs.open_write("out")
            for i in range(4):
                w.write(bytes([i]) * (32 << 10))
            w.close()
            done.append(True)

        t = threading.Thread(target=produce)
        t.start()
        t.join(timeout=20)
        assert done, "writer starved by retained cache blocks"
        fs.close()
        assert store.backing.get("out") == b"".join(
            bytes([i]) * (32 << 10) for i in range(4)
        )

    def test_staged_parts_never_journal(self, tmp_path):
        root = str(tmp_path / "cache")
        tier = DirTier(1 << 20, root=root)
        store = make_store({})
        fs = PrefetchFS(store, policy=IOPolicy(blocksize=512, write_depth=2),
                        tiers=[tier])
        with fs:
            w = fs.open_write("out/obj")
            for i in range(4):
                w.write(payload(512, seed=i))
            w.close()
        assert store.backing.get("out/obj") == b"".join(
            payload(512, seed=i) for i in range(4)
        )
        # Nothing about the staging survived into the journal/index.
        assert DirTier(1 << 20, root=root).recovered_blocks == 0

    def test_journal_is_valid_jsonl(self, tmp_path):
        root = str(tmp_path / "cache")
        tier = DirTier(1 << 20, root=root)
        tier.write("k@0-9", payload(9), meta=BlockMeta(key="k", offset=0))
        with open(os.path.join(root, DirTier.INDEX_NAME)) as f:
            recs = [json.loads(line) for line in f if line.strip()]
        assert recs[-1]["op"] == "put"
        assert recs[-1]["id"] == "k@0-9"
        assert recs[-1]["key"] == "k"
        assert recs[-1]["off"] == 0
        assert recs[-1]["len"] == 9
        assert "crc" in recs[-1]
