"""Length-prefixed peer wire protocol.

One frame = an 8-byte big-endian prefix (header length, payload length),
a JSON header, and a raw payload::

    >II | {"op": "fetch", "key": ..., "start": ..., "end": ...} | <bytes>

JSON headers keep the protocol debuggable and versionable; block payloads
ride outside the JSON so a block transfer is one memcpy, not a base64
round-trip. Requests and responses share the framing; a response header
carries ``ok`` plus a ``status`` ("hit" / "fetched" / "miss" / "stored"
/ "rejected") and the payload when there is one.

Block identity on the wire is (key, start, end) — the same triple
`repro.core.plan.Block.block_id` content-addresses blocks with — so any
two hosts running the same blocksize policy name the same stored bytes
identically with no coordination.
"""

from __future__ import annotations

import json
import socket
import struct

from repro.store.base import TransientStoreError

# Header-length, payload-length prefix.
_PREFIX = struct.Struct(">II")

# A frame a sibling could not possibly send: cap both lengths so a
# corrupt / non-protocol peer cannot make us allocate unbounded buffers.
MAX_HEADER = 1 << 20
MAX_PAYLOAD = 1 << 31

#: FaultSchedule operation names for the peer transport, the analogue of
#: `repro.store.faults.READ_OPS` for peer RPCs — route a schedule's
#: stall/transient/cut/throttle rules through these to chaos-test the
#: peer path.
PEER_OPS = ("peer_fetch", "peer_put", "peer_has", "peer_ping")


class PeerError(TransientStoreError):
    """A peer RPC failed (connection refused/reset, timeout, protocol
    violation, remote error). Transient by construction: the peer layer
    is a cache, so every `PeerError` degrades to a cache miss — the
    caller falls back to the backing store, never surfaces the error."""


def span_block_id(key: str, start: int, end: int) -> str:
    """The content-addressed block id for bytes [start, end) of `key` —
    must match `repro.core.plan.Block.block_id` byte for byte."""
    return f"{key}@{start:015d}-{end:015d}"


def parse_block_id(block_id: str) -> tuple[str, int, int]:
    """Inverse of :func:`span_block_id` (keys may contain ``@``; the
    final one delimits the range suffix)."""
    key, _, span = block_id.rpartition("@")
    if not key:
        raise ValueError(f"not a block id: {block_id!r}")
    lo, _, hi = span.partition("-")
    return key, int(lo), int(hi)


def send_msg(sock: socket.socket, header: dict, payload: bytes = b"") -> None:
    # Every frame with a payload declares its length INSIDE the header
    # too. The binary prefix frames the read; the header's "len" is the
    # sender's claim about the block itself, and `recv_msg` rejects any
    # frame where the two disagree — a short write, a truncating proxy,
    # or a raw-socket peer lying about its payload would otherwise
    # deliver a wrong-sized block that only fails much later (or never).
    if payload:
        header = dict(header, len=len(payload))
    raw = json.dumps(header, separators=(",", ":")).encode()
    # One sendall: the prefix, header, and payload leave as a single
    # buffer so a thread switch cannot interleave frames on a shared
    # socket (callers still serialize per-socket for responses).
    sock.sendall(_PREFIX.pack(len(raw), len(payload)) + raw + payload)


def recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(n - len(buf), 1 << 20))
        if not chunk:
            raise PeerError("peer connection closed mid-frame")
        buf += chunk
    return bytes(buf)


def recv_msg(sock: socket.socket) -> tuple[dict, bytes]:
    hlen, plen = _PREFIX.unpack(recv_exact(sock, _PREFIX.size))
    if hlen > MAX_HEADER or plen > MAX_PAYLOAD:
        raise PeerError(
            f"peer frame too large (header {hlen}, payload {plen})"
        )
    header = json.loads(recv_exact(sock, hlen))
    payload = recv_exact(sock, plen) if plen else b""
    declared = header.get("len")
    if declared is not None and declared != len(payload):
        # The prefix framed `plen` bytes but the header promised
        # `declared`: a protocol violation, not a miss. Refuse the frame
        # — the bytes cannot be trusted to be the block they claim.
        raise PeerError(
            f"peer frame length mismatch: header declares {declared} "
            f"payload bytes, received {len(payload)}"
        )
    return header, payload
