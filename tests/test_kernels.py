"""Pallas kernel validation: shape/dtype sweeps in interpret mode against
the pure-jnp oracles in repro.kernels.ref."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref, ssd_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.models.ssd import ssd_chunked


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-3, atol=2e-3
    )


# --------------------------------------------------------------------------- #
# Flash attention
# --------------------------------------------------------------------------- #
FLASH_CASES = [
    # (b, hq, hkv, sq, sk, d, causal, dtype)
    (1, 4, 4, 256, 256, 64, True, jnp.float32),     # MHA causal
    (2, 8, 2, 256, 256, 128, True, jnp.float32),    # GQA
    (1, 8, 1, 128, 128, 64, True, jnp.float32),     # MQA
    (1, 4, 4, 128, 384, 64, False, jnp.float32),    # cross-shaped, bidir
    (2, 4, 2, 256, 256, 64, True, jnp.bfloat16),    # bf16
    (1, 2, 2, 512, 512, 128, True, jnp.bfloat16),   # larger seq bf16
    (1, 4, 4, 128, 128, 32, False, jnp.float32),    # small head_dim
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=[str(c[:7]) for c in FLASH_CASES])
def test_flash_attention_matches_ref(case):
    b, hq, hkv, sq, sk, d, causal, dtype = case
    ks = jax.random.split(jax.random.key(hash(case[:7]) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    assert out.dtype == q.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_flash_attention_block_shapes():
    """Block size must not change the result (pure tiling parameter)."""
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (1, 4, 512, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 4, 512, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 4, 512, 64), jnp.float32)
    base = flash_attention(q, k, v, causal=True, interpret=True)
    for bq, bk in [(64, 64), (128, 256), (256, 128), (512, 512)]:
        out = flash_attention(
            q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(base), rtol=1e-5, atol=1e-5,
            err_msg=f"block ({bq},{bk})",
        )


def test_flash_attention_long_causal_row_sums():
    """Each causal row attends only to columns <= row: verify via a probe
    value pattern (v = one-hot positions)."""
    sq = 256
    q = jnp.ones((1, 1, sq, 64), jnp.float32)
    k = jnp.zeros((1, 1, sq, 64), jnp.float32)   # uniform scores
    v = jnp.broadcast_to(
        jnp.arange(sq, dtype=jnp.float32)[None, None, :, None], (1, 1, sq, 64)
    )
    out = flash_attention(q, k, v, causal=True, interpret=True)
    # Uniform attention over first (i+1) positions -> mean of 0..i = i/2.
    want = jnp.arange(sq, dtype=jnp.float32) / 2.0
    np.testing.assert_allclose(
        np.asarray(out[0, 0, :, 0]), np.asarray(want), rtol=1e-4, atol=1e-4
    )


@given(
    b=st.integers(1, 2),
    hkv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 4]),
    sq_blocks=st.integers(1, 3),
    d=st.sampled_from([32, 64]),
)
@settings(max_examples=10, deadline=None)
def test_flash_attention_property(b, hkv, group, sq_blocks, d):
    sq = 128 * sq_blocks
    hq = hkv * group
    ks = jax.random.split(jax.random.key(b * 1000 + hq * 10 + sq), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, sq, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, sq, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------- #
# SSD scan
# --------------------------------------------------------------------------- #
SSD_CASES = [
    # (b, s, h, g, p, n, chunk, dtype)
    (1, 128, 4, 1, 32, 32, 32, jnp.float32),
    (2, 256, 8, 2, 64, 64, 64, jnp.float32),
    (1, 512, 4, 4, 64, 128, 128, jnp.float32),
    (1, 256, 4, 1, 64, 128, 256, jnp.float32),   # single chunk
    (2, 256, 4, 1, 32, 64, 64, jnp.bfloat16),
]


def _ssd_inputs(case):
    b, s, h, g, p, n, chunk, dtype = case
    ks = jax.random.split(jax.random.key(hash(case[:7]) % 2**31), 4)
    x = (jax.random.normal(ks[0], (b, s, h, p)) * 0.5).astype(dtype)
    dt_a = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.3
    bp = (jax.random.normal(ks[2], (b, s, g, n)) * 0.3).astype(dtype)
    cp = (jax.random.normal(ks[3], (b, s, g, n)) * 0.3).astype(dtype)
    return x, dt_a, bp, cp


@pytest.mark.parametrize("case", SSD_CASES, ids=[str(c[:7]) for c in SSD_CASES])
def test_ssd_kernel_matches_sequential_ref(case):
    chunk, dtype = case[6], case[7]
    x, dt_a, bp, cp = _ssd_inputs(case)
    y_k, h_k = ssd_scan(x, dt_a, bp, cp, chunk=chunk, interpret=True)
    y_r, h_r = ssd_ref(x, dt_a, bp, cp)
    assert y_k.dtype == x.dtype
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32), np.asarray(y_r, np.float32), **_tol(dtype)
    )
    np.testing.assert_allclose(
        np.asarray(h_k), np.asarray(h_r), **_tol(dtype)
    )


@pytest.mark.parametrize("case", SSD_CASES[:3], ids=[str(c[:7]) for c in SSD_CASES[:3]])
def test_ssd_chunked_jnp_matches_sequential_ref(case):
    """The model's chunked jnp path (dry-run path) against the recurrence."""
    chunk = case[6]
    x, dt_a, bp, cp = _ssd_inputs(case)
    y_c, h_c = ssd_chunked(x, dt_a, bp, cp, chunk)
    y_r, h_r = ssd_ref(x, dt_a, bp, cp)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_r),
                               rtol=2e-3, atol=2e-3)


def test_ssd_kernel_initial_state_continuation():
    """Splitting a sequence and passing the carry state must equal one
    pass over the full sequence (the decode/prefill contract)."""
    case = (1, 256, 4, 1, 32, 64, 64, jnp.float32)
    x, dt_a, bp, cp = _ssd_inputs(case)
    y_full, h_full = ssd_scan(x, dt_a, bp, cp, chunk=64, interpret=True)
    half = 128
    y1, h1 = ssd_scan(x[:, :half], dt_a[:, :half], bp[:, :half], cp[:, :half],
                      chunk=64, interpret=True)
    y2, h2 = ssd_scan(x[:, half:], dt_a[:, half:], bp[:, half:], cp[:, half:],
                      chunk=64, initial_state=h1, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


@given(
    s_chunks=st.integers(1, 4),
    h=st.sampled_from([2, 4]),
    g=st.sampled_from([1, 2]),
    p=st.sampled_from([16, 32]),
    n=st.sampled_from([16, 64]),
)
@settings(max_examples=10, deadline=None)
def test_ssd_property(s_chunks, h, g, p, n):
    if h % g:
        g = 1
    chunk = 32
    case = (1, chunk * s_chunks, h, g, p, n, chunk, jnp.float32)
    x, dt_a, bp, cp = _ssd_inputs(case)
    y_k, h_k = ssd_scan(x, dt_a, bp, cp, chunk=chunk, interpret=True)
    y_r, h_r = ssd_ref(x, dt_a, bp, cp)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=5e-3, atol=5e-3)
