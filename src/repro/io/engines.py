"""Built-in reader engines.

  * ``rolling``    — the paper's Rolling Prefetch (three-thread engine over
    bounded cache tiers); requires tiers, which `PrefetchFS` supplies;
  * ``sequential`` — the S3Fs/fsspec-style on-demand block cache baseline;
  * ``direct``     — uncached pass-through range reads.

Each factory receives ``(store, files, tiers, policy)`` and returns a
`Reader`. New engines (real S3, async, sharded multi-host) register the
same way and become reachable from every `PrefetchFS` call site.

The core engine modules are imported lazily inside the factories: they
depend on ``repro.io.retry`` (the unified resilience layer), and a
module-level import here would close an import cycle through the
``repro.io`` package init.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

from repro.io.policy import IOPolicy
from repro.io.registry import register_reader
from repro.store.base import ObjectMeta, ObjectStore
from repro.store.tiers import CacheIndex, CacheTier

if TYPE_CHECKING:
    from repro.core.autotune import BlockSizeTuner


@register_reader("rolling", needs_tiers=True, accepts_tuner=True,
                 accepts_index=True)
def open_rolling(store: ObjectStore, files: list[ObjectMeta],
                 tiers: list[CacheTier], policy: IOPolicy,
                 tuner: "BlockSizeTuner | None" = None,
                 index: CacheIndex | None = None):
    from repro.core.rolling import RollingPrefetcher, RollingPrefetchFile

    return RollingPrefetchFile(
        RollingPrefetcher(
            store, files, tiers, policy.blocksize,
            depth=policy.depth,
            max_depth=policy.max_depth,
            coalesce=policy.coalesce if policy.coalesce is not None else 1,
            readahead_blocks=policy.readahead_blocks,
            eviction_interval_s=policy.eviction_interval_s,
            retry=policy.retry_policy(),
            hedge_timeout_s=policy.hedge_timeout_s,
            max_hedges=policy.max_hedges,
            throttle_aimd=policy.throttle_aimd,
            tuner=tuner,
            index=index,
            io_class=policy.io_class,
            verify=policy.verify,
        )
    )


@register_reader("sequential", accepts_tuner=True, accepts_index=True)
def open_sequential(store: ObjectStore, files: list[ObjectMeta],
                    tiers: list[CacheTier], policy: IOPolicy,
                    tuner: "BlockSizeTuner | None" = None,
                    index: CacheIndex | None = None):
    from repro.core.sequential import SequentialFile

    return SequentialFile(store, files, policy.blocksize,
                          cache_blocks=policy.cache_blocks, tuner=tuner,
                          index=index, retry=policy.retry_policy(),
                          io_class=policy.io_class, verify=policy.verify)


@register_reader("direct")
def open_direct(store: ObjectStore, files: list[ObjectMeta],
                tiers: list[CacheTier], policy: IOPolicy):
    from repro.io.reader import DirectReader

    return DirectReader(store, files)
