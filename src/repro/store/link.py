"""Link model: injects latency + shared-bandwidth cost into byte transfers.

Models the paper's Table I measurements. Latency is paid per request and
overlaps freely across threads (S3 is highly concurrent); bandwidth is a
shared serial resource (the instance NIC / DIMM bus), modeled as a
reservation queue: each transfer reserves the link for `bytes / bandwidth`
seconds starting no earlier than the previous reservation ends. This
reproduces the contention behaviour the paper discusses for parallel
workloads (§III-C).
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field

from repro.store.base import ThrottleError, TransientStoreError


@dataclass
class LinkModel:
    latency_s: float = 0.0
    bandwidth_Bps: float = float("inf")
    # Multiplicative jitter applied to latency (lognormal-ish, seeded).
    jitter: float = 0.0
    seed: int = 0
    # Failure injection: probability per request, and an explicit
    # fail-next counter (used by fault-tolerance tests).
    fail_prob: float = 0.0
    # Requests-per-second admission model (S3 per-prefix throttling): a
    # token bucket refilling at `rps_limit` with burst headroom
    # `rps_burst` (default: a quarter second's worth, at least 1). A
    # request arriving with no token pays its round-trip latency — the
    # 503 comes back one RTT later — and raises `ThrottleError`.
    # `rps_penalty` models SlowDown *escalation*: each rejected request
    # additionally drains that many tokens (floored at -burst), the way
    # real object stores extend throttling for clients that keep
    # hammering after a 503 — backing off (and shrinking concurrency)
    # is then genuinely cheaper than retrying at full pressure.
    rps_limit: float = float("inf")
    rps_burst: float | None = None
    rps_penalty: float = 0.0
    name: str = "link"

    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    _free_at: float = field(default=0.0, repr=False)
    _rng: random.Random = field(default=None, repr=False)  # type: ignore
    _fail_next: int = field(default=0, repr=False)
    _tokens: float = field(default=0.0, repr=False)
    _tokens_t: float | None = field(default=None, repr=False)
    # Telemetry (read by the online autotuner and benchmarks).
    bytes_moved: int = field(default=0, repr=False)
    requests: int = field(default=0, repr=False)
    busy_s: float = field(default=0.0, repr=False)
    latency_paid_s: float = field(default=0.0, repr=False)
    # Failure telemetry: every raising request (injected fault, throttle)
    # counts into `failed_requests`; throttles also into `throttled`.
    # Failed requests still pay — and record — their request latency, so
    # benchmark timings under fault schedules stay honest.
    failed_requests: int = field(default=0, repr=False)
    throttled: int = field(default=0, repr=False)
    # Coalesced-transfer accounting: a vectorized get_ranges run charges
    # ONE request for several logical spans — `spans_served` counts the
    # spans, `coalesced_requests` the requests that carried more than one.
    spans_served: int = field(default=0, repr=False)
    coalesced_requests: int = field(default=0, repr=False)

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)

    # -- failure injection ------------------------------------------------
    def fail_next(self, n: int = 1) -> None:
        with self._lock:
            self._fail_next += n

    def _check_fail(self) -> str | None:
        """Failure decision for one request. Caller holds `_lock`."""
        if self._fail_next > 0:
            self._fail_next -= 1
            return f"{self.name}: injected failure"
        if self.fail_prob > 0.0 and self._rng.random() < self.fail_prob:
            return f"{self.name}: injected random failure"
        return None

    def _admit(self) -> bool:
        """Token-bucket admission at `rps_limit`. Caller holds `_lock`.
        A rejected request does not consume its token, so backed-off
        retries find capacity once pressure drops — but with
        `rps_penalty` set it *drains* penalty tokens (escalating
        SlowDown), so sustained hammering pushes the bucket below zero
        and admission recovers only after the pressure actually
        relents. The floor at ``-burst`` bounds the starvation."""
        if self.rps_limit == float("inf"):
            return True
        burst = (self.rps_burst if self.rps_burst is not None
                 else max(1.0, self.rps_limit / 4.0))
        now = time.perf_counter()
        if self._tokens_t is None:
            self._tokens = burst
        else:
            self._tokens = min(
                burst, self._tokens + (now - self._tokens_t) * self.rps_limit
            )
        self._tokens_t = now
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return True
        if self.rps_penalty > 0.0:
            self._tokens = max(-burst, self._tokens - self.rps_penalty)
        return False

    # -- transfer ---------------------------------------------------------
    def transfer(self, nbytes: int, spans: int = 1) -> None:
        """Block for the simulated duration of moving `nbytes` as ONE
        request. `spans` is telemetry only: how many logical ranges the
        request carried (a coalesced get_ranges run pays one latency for
        all of them; the cost charged here is identical either way).

        Raises `ThrottleError` under rps pressure and
        `TransientStoreError` for injected faults — in both cases AFTER
        paying the request latency: a 503 or dropped connection still
        costs a round trip, and the paid time lands in the telemetry.
        """
        lat = self.latency_s
        if self.jitter > 0.0:
            with self._lock:
                lat *= max(0.0, 1.0 + self._rng.gauss(0.0, self.jitter))
        # Latency overlaps across threads: plain sleep.
        if lat > 0.0:
            time.sleep(lat)
        with self._lock:
            self.requests += 1
            self.latency_paid_s += lat
            if not self._admit():
                self.failed_requests += 1
                self.throttled += 1
                raise ThrottleError(
                    f"{self.name}: rate limit exceeded "
                    f"({self.rps_limit:g} req/s)"
                )
            fail = self._check_fail()
            if fail is not None:
                self.failed_requests += 1
                raise TransientStoreError(fail)
        # Bandwidth is a shared serial resource: reserve a slot.
        if self.bandwidth_Bps != float("inf") and nbytes > 0:
            dur = nbytes / self.bandwidth_Bps
            with self._lock:
                now = time.perf_counter()
                start = max(now, self._free_at)
                self._free_at = start + dur
                finish = self._free_at
                self.busy_s += dur
            delay = finish - time.perf_counter()
            if delay > 0.0:
                time.sleep(delay)
        with self._lock:
            self.bytes_moved += nbytes
            self.spans_served += max(1, spans)
            if spans > 1:
                self.coalesced_requests += 1

    # -- observed constants (for the cost-model autotuner) -----------------
    def observed_bandwidth(self) -> float:
        with self._lock:
            if self.busy_s == 0.0:
                return self.bandwidth_Bps
            return self.bytes_moved / self.busy_s

    def observed_latency(self) -> float:
        """Mean per-request latency actually paid (== `latency_s` when
        jitter is off); the ground truth the closed-loop tuner's estimate
        is validated against."""
        with self._lock:
            if self.requests == 0:
                return self.latency_s
            return self.latency_paid_s / self.requests


@dataclass
class PeerLinkModel(LinkModel):
    """The LAN/loopback hop between sibling hosts of one job.

    A distinct class (not just different numbers) so peer transfers are
    billed to their own link — never to the backing-store WAN link — and
    so call sites can tell the two apart (`repro.peer` charges every
    block served from a sibling here, and the peer tier's `TierCostModel`
    seeds from these constants). Defaults model a ~10 GbE intra-cluster
    hop: sub-millisecond latency, two orders of magnitude above the
    scaled S3 bandwidth; all knobs stay URI-tunable through ``peer://``
    (``peer_latency_ms`` / ``peer_bw_mbps`` / ``peer_rps``) so
    ``bench_peer.py`` can sweep realistic LAN-vs-WAN ratios.
    """

    latency_s: float = 2e-4
    bandwidth_Bps: float = 1.25e9
    name: str = "peer"


# Paper Table I constants (t2.xlarge, us-west-2), in SI bytes/sec.
PAPER_S3 = dict(latency_s=0.1, bandwidth_Bps=91e6)
PAPER_MEM = dict(latency_s=1.6e-6, bandwidth_Bps=2221e6)
# Default intra-cluster peer hop (see `PeerLinkModel`).
PEER_LAN = dict(latency_s=2e-4, bandwidth_Bps=1.25e9)
