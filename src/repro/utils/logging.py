"""Structured logging for the framework.

Every subsystem logs through here so launcher-level configuration (rank
prefixes, verbosity) applies uniformly.
"""

from __future__ import annotations

import logging
import os
import sys

_CONFIGURED = False


def _configure_root() -> None:
    global _CONFIGURED
    if _CONFIGURED:
        return
    level = os.environ.get("REPRO_LOG_LEVEL", "WARNING").upper()
    handler = logging.StreamHandler(sys.stderr)
    handler.setFormatter(
        logging.Formatter("%(asctime)s %(levelname)s %(name)s: %(message)s")
    )
    root = logging.getLogger("repro")
    root.setLevel(level)
    root.addHandler(handler)
    root.propagate = False
    _CONFIGURED = True


def get_logger(name: str) -> logging.Logger:
    _configure_root()
    if not name.startswith("repro"):
        name = f"repro.{name}"
    return logging.getLogger(name)
