"""Encoder-decoder backbone (Whisper-style).

The conv frontend is stubbed per the assignment: encoder inputs arrive as
precomputed frame embeddings (B, S_enc, D). Positional information is
sinusoidal on both stacks (Whisper uses sinusoidal-encoder / learned-
decoder; a learned 500k-row table is replaced by sinusoidal for the
assigned long decode shapes — documented in configs/whisper_large_v3.py).

Shape-cell semantics: train = teacher-forced decode over seq_len with
encoder over seq_len frames; prefill = encoder(seq_len) + decoder prompt of
cfg.dec_prefill_len; decode = one decoder token against self-KV seq_len +
cross-KV seq_len.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import BlockDef, ModelConfig
from repro.models import layers as L
from repro.models import lm as LM
from repro.sharding.rules import constrain

ENC_PATTERN = (BlockDef("attn", "dense"),)


def encdec_spec(cfg: ModelConfig) -> dict:
    return {
        "embed": L.embedding_spec(cfg),
        "enc_layers": LM.stack_spec(cfg, ENC_PATTERN, cfg.enc_layers),
        "enc_final_norm": L.norm_spec(cfg),
        "layers": LM.stack_spec(cfg),           # decoder (cross_attn pattern)
        "final_norm": L.norm_spec(cfg),
    }


def _add_sinusoid(x: jax.Array, offset: int = 0) -> jax.Array:
    pe = L.sinusoidal_positions(x.shape[1], x.shape[2], offset)
    return (x + pe[None].astype(x.dtype)).astype(x.dtype)


def encode(p: dict, cfg: ModelConfig, enc_inputs: jax.Array, *,
           q_chunk: int = 512, remat: bool = False) -> jax.Array:
    """enc_inputs: (B, S_enc, D) stub frame embeddings -> encoder states."""
    x = _add_sinusoid(enc_inputs.astype(L.COMPUTE_DTYPE))
    x = constrain(x, "batch", None, "residual")
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, _, _ = LM.stack_fwd(
        p["enc_layers"], cfg, x,
        positions=positions,
        causal=False,
        q_chunk=q_chunk,
        remat=remat,
        pattern=ENC_PATTERN,
    )
    return L.apply_norm(p["enc_final_norm"], cfg, x)


def decode_train(
    p: dict, cfg: ModelConfig, enc_hidden: jax.Array, dec_ids: jax.Array,
    *, q_chunk: int = 512, remat: bool = False,
) -> jax.Array:
    x = L.embed_tokens(p["embed"], cfg, dec_ids)
    x = _add_sinusoid(x)
    x = constrain(x, "batch", None, "residual")
    positions = jnp.arange(dec_ids.shape[1], dtype=jnp.int32)
    x, _, _ = LM.stack_fwd(
        p["layers"], cfg, x,
        positions=positions,
        enc_hidden=enc_hidden,
        causal=True,
        q_chunk=q_chunk,
        remat=remat,
    )
    return L.apply_norm(p["final_norm"], cfg, x)


def encdec_loss(
    p: dict, cfg: ModelConfig, enc_inputs: jax.Array, dec_ids: jax.Array,
    labels: jax.Array, *, q_chunk: int = 512, loss_chunk: int = 512,
    remat: bool = True,
) -> jax.Array:
    enc_hidden = encode(p, cfg, enc_inputs, q_chunk=q_chunk, remat=remat)
    h = decode_train(p, cfg, enc_hidden, dec_ids, q_chunk=q_chunk, remat=remat)
    return LM.chunked_xent(p, cfg, h, labels, chunk=loss_chunk)


def build_cross_caches(p: dict, cfg: ModelConfig, enc_hidden: jax.Array):
    """Per-period read-only cross-attention KV from encoder states; stacked
    on the periods axis to match the decoder scan."""

    def per_period(_, pp):
        kv = L.compute_kv(pp["block0"]["cross"], cfg, enc_hidden)
        return None, kv

    _, stacked_kv = jax.lax.scan(per_period, None, p["layers"])
    return stacked_kv


def encdec_prefill(
    p: dict, cfg: ModelConfig, enc_inputs: jax.Array, dec_prompt: jax.Array,
    *, max_len: int | None = None, q_chunk: int = 512,
):
    """Encoder pass + decoder prompt prefill. Returns (logits, caches)."""
    b, s_dec = dec_prompt.shape
    max_len = max_len if max_len is not None else s_dec
    enc_hidden = encode(p, cfg, enc_inputs, q_chunk=q_chunk)
    cross = build_cross_caches(p, cfg, enc_hidden)

    caches = LM.make_stack_cache(cfg, b, max_len)
    caches = _merge_cross(caches, cross)

    x = L.embed_tokens(p["embed"], cfg, dec_prompt)
    x = _add_sinusoid(x)
    x = constrain(x, "batch", None, "residual")
    positions = jnp.arange(s_dec, dtype=jnp.int32)
    x, caches, _ = LM.stack_fwd(
        p["layers"], cfg, x,
        positions=positions,
        caches=caches,
        update_cache=True,
        causal=True,
        q_chunk=q_chunk,
    )
    h = L.apply_norm(p["final_norm"], cfg, x)
    logits = LM.logits_from_hidden(p, cfg, h[:, -1:, :])[:, 0]
    return logits, caches


def _merge_cross(caches: dict, cross) -> dict:
    out = dict(caches)
    blk = dict(out["block0"])
    blk["cross"] = cross
    out["block0"] = blk
    return out


def encdec_decode_step(p: dict, cfg: ModelConfig, ids: jax.Array, caches,
                       position):
    """One decoder token step with self + cross caches."""
    x = L.embed_tokens(p["embed"], cfg, ids)
    pe = L.sinusoidal_positions(1, cfg.d_model, 0)  # offset applied below
    # Sinusoid at the true position (traced scalar offset).
    pos = jnp.asarray(position, jnp.int32)
    dim = jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos.astype(jnp.float32) / jnp.power(10000.0, dim / cfg.d_model)
    pe = jnp.zeros((1, cfg.d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    x = (x + pe[None].astype(x.dtype)).astype(x.dtype)
    x = constrain(x, "batch", None, "residual")

    positions = pos[None]
    x, new_caches, _ = LM.stack_fwd(
        p["layers"], cfg, x,
        positions=positions,
        caches=caches,
        update_cache=True,
        causal=True,
        q_chunk=1,
    )
    h = L.apply_norm(p["final_norm"], cfg, x)
    logits = LM.logits_from_hidden(p, cfg, h)[:, 0]
    return logits, new_caches


def make_decode_caches(cfg: ModelConfig, batch: int, self_len: int,
                       cross_len: int, *, length: int = 0) -> dict:
    return LM.make_stack_cache(
        cfg, batch, self_len, cross_len=cross_len, length=length
    )
