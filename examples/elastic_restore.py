"""Elastic scaling: save a sharded train state on one mesh, restore it onto
a different topology (grow/shrink) purely through the checkpoint template.

Run with multiple CPU placeholder devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/elastic_restore.py
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.ft import snapshot_resharded
from repro.io import IOPolicy, open_store
from repro.launch.mesh import make_mesh_compat
from repro.models import make_model
from repro.models.spec import param_shardings
from repro.sharding.rules import ShardingRules, TRAIN_RULES


def mesh_of(data: int, model: int) -> jax.sharding.Mesh:
    return make_mesh_compat((data, model), ("data", "model"))


def main() -> None:
    cfg = get_config("olmo-1b").reduced()
    model = make_model(cfg)
    spec = model.spec()

    # --- train-time topology: 4 x 2 ------------------------------------------
    mesh_a = mesh_of(4, 2)
    rules_a = ShardingRules(mesh_a, dict(TRAIN_RULES))
    with mesh_a:
        params = model.init(jax.random.key(0))
        shardings_a = param_shardings(spec, rules_a)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s else x, params, shardings_a
        )

    store = open_store("sims3://elastic?latency_ms=2&bw_mbps=200")
    save_checkpoint(store, "elastic", 0, params,
                    policy=IOPolicy(write_depth=4))
    print(f"saved on mesh {dict(zip(mesh_a.axis_names, mesh_a.devices.shape))}")

    # --- restore onto a DIFFERENT topology: 2 x 4 ------------------------------
    mesh_b = mesh_of(2, 4)
    rules_b = ShardingRules(mesh_b, dict(TRAIN_RULES))
    shardings_b = param_shardings(spec, rules_b)
    template = jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s)
        if s is not None else jax.ShapeDtypeStruct(x.shape, x.dtype),
        params, shardings_b,
    )
    with mesh_b:
        restored, _ = restore_checkpoint(
            store, "elastic", template,
            policy=IOPolicy(engine="rolling", depth=2,
                            eviction_interval_s=0.2),
        )

    # --- verify bit-identical logical arrays, new physical layout --------------
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    n_resharded = sum(
        s is not None for s in jax.tree.leaves(
            shardings_b, is_leaf=lambda x: x is None or hasattr(x, "spec"))
    )
    print(f"restored onto mesh {dict(zip(mesh_b.axis_names, mesh_b.devices.shape))}: "
          f"values identical, {n_resharded} sharded leaves re-laid-out")

    # --- snapshot the resized job so the reshard is immediately crash-safe -----
    snapshot_resharded(store, "elastic", 1, restored, shardings_b,
                       policy=IOPolicy(write_depth=4))
    assert latest_step(store, "elastic") == 1
    print("OK: elastic restore verified; post-reshard snapshot committed")


if __name__ == "__main__":
    main()
