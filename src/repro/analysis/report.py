"""Reporting: text/JSON renderers, the grandfathering baseline, and the
Report object the CLI, benchmark, and tests all consume.

The baseline is a checked-in JSON file of finding fingerprints
(rule + file + flagged source text). Findings in it are reported but do
not fail the gate — the mechanism for landing the analyzer against a
tree with known debt, then ratcheting the debt down without ever letting
it grow. This repo's baseline is empty on purpose: every first-run
finding was fixed or suppressed with a reason instead.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

from repro.analysis.core import Finding
from repro.analysis.lockgraph import LockGraph

BASELINE_VERSION = 1


@dataclass
class Baseline:
    fingerprints: dict[str, dict] = field(default_factory=dict)

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path, encoding="utf-8") as fh:
            raw = json.load(fh)
        if raw.get("version") != BASELINE_VERSION:
            raise ValueError(
                f"baseline {path}: unsupported version {raw.get('version')!r}"
            )
        return cls(fingerprints={f["fingerprint"]: f
                                 for f in raw.get("findings", [])})

    @classmethod
    def from_findings(cls, findings: list[Finding]) -> "Baseline":
        return cls(fingerprints={
            f.fingerprint(): {
                "fingerprint": f.fingerprint(),
                "rule": f.rule,
                "path": f.path.replace("\\", "/"),
                "snippet": f.snippet.strip(),
                "message": f.message,
            }
            for f in findings
        })

    def save(self, path: str) -> None:
        doc = {
            "version": BASELINE_VERSION,
            "findings": sorted(self.fingerprints.values(),
                               key=lambda f: (f["path"], f["rule"],
                                              f["snippet"])),
        }
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")

    def covers(self, finding: Finding) -> bool:
        return finding.fingerprint() in self.fingerprints


@dataclass
class Report:
    """The gate's verdict: what fired, what was silenced, and why."""

    new: list[Finding] = field(default_factory=list)         # fail the gate
    baselined: list[Finding] = field(default_factory=list)   # grandfathered
    suppressed: list[Finding] = field(default_factory=list)  # annotated
    lock_graph: LockGraph | None = None

    @classmethod
    def build(cls, findings: list[Finding], *,
              baseline: Baseline | None = None,
              lock_graph: LockGraph | None = None) -> "Report":
        rep = cls(lock_graph=lock_graph)
        for f in findings:
            if f.suppressed:
                rep.suppressed.append(f)
            elif baseline is not None and baseline.covers(f):
                rep.baselined.append(f)
            else:
                rep.new.append(f)
        return rep

    @property
    def cycles(self) -> list[list[str]]:
        return self.lock_graph.cycles() if self.lock_graph else []

    @property
    def ok(self) -> bool:
        return not self.new and not self.cycles

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "summary": {
                "new": len(self.new),
                "baselined": len(self.baselined),
                "suppressed": len(self.suppressed),
                "lock_cycles": len(self.cycles),
            },
            "findings": [f.to_dict() for f in self.new],
            "baselined": [f.to_dict() for f in self.baselined],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "lock_graph": (self.lock_graph.to_dict()
                           if self.lock_graph else None),
        }


def render_json(report: Report) -> str:
    return json.dumps(report.to_dict(), indent=2) + "\n"


def render_text(report: Report, *, verbose: bool = False) -> str:
    lines: list[str] = []
    for f in report.new:
        lines.append(f"{f.location()}: {f.rule}: {f.message}")
        if f.snippet.strip():
            lines.append(f"    {f.snippet.strip()}")
    if verbose:
        for f in report.baselined:
            lines.append(f"{f.location()}: {f.rule}: [baselined] {f.message}")
        for f in report.suppressed:
            lines.append(f"{f.location()}: {f.rule}: "
                         f"[allowed: {f.suppress_reason}]")
    for cyc in report.cycles:
        lines.append("LOCK CYCLE: " + " -> ".join(cyc + [cyc[0]]))
    n, s, b = len(report.new), len(report.suppressed), len(report.baselined)
    lines.append(
        f"{n} finding(s), {s} suppressed, {b} baselined, "
        f"{len(report.cycles)} lock cycle(s)"
        + (" — OK" if report.ok else " — FAIL")
    )
    return "\n".join(lines) + "\n"
