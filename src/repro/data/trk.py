"""Streamline (.trk-style) codec — the paper's data format.

Files carry a fixed 1000-byte header and a body of variable-length
streamline records: int32 point count, then npoints x 3 float32
coordinates, then n_properties float32 per-streamline properties
(paper §II-C). The reader is nibabel-like: a lazy generator over any
file-like object (any `repro.io.Reader` from `PrefetchFS.open`, or a
plain BytesIO), issuing
one small read per record section — reproducing the paper's observation
that "Nibabel reads may incur significant overhead: three read calls for
each streamline" — and always applying the header affine to coordinates
("some amount of compute is always executed when data is read").
"""

from __future__ import annotations

import io
import struct
from dataclasses import dataclass
from typing import Iterator

import numpy as np

HEADER_SIZE = 1000
MAGIC = b"TRKR"
_HDR = struct.Struct("<4sIII")  # magic, version, n_count, n_properties
_AFFINE_OFFSET = 16             # affine stored right after the fixed fields


@dataclass
class TrkHeader:
    n_count: int
    n_properties: int
    affine: np.ndarray  # (4, 4) float32
    version: int = 1

    def to_bytes(self) -> bytes:
        buf = bytearray(HEADER_SIZE)
        _HDR.pack_into(buf, 0, MAGIC, self.version, self.n_count,
                       self.n_properties)
        buf[_AFFINE_OFFSET:_AFFINE_OFFSET + 64] = (
            self.affine.astype("<f4").tobytes()
        )
        return bytes(buf)

    @classmethod
    def from_bytes(cls, raw: bytes) -> "TrkHeader":
        if len(raw) < HEADER_SIZE:
            raise ValueError(f"truncated header: {len(raw)} bytes")
        magic, version, n_count, n_props = _HDR.unpack_from(raw, 0)
        if magic != MAGIC:
            raise ValueError(f"bad magic: {magic!r}")
        affine = np.frombuffer(
            raw, dtype="<f4", count=16, offset=_AFFINE_OFFSET
        ).reshape(4, 4).copy()
        return cls(n_count=n_count, n_properties=n_props, affine=affine,
                   version=version)


@dataclass
class Streamline:
    points: np.ndarray       # (n, 3) float32, affine-transformed
    properties: np.ndarray   # (n_properties,) float32


def write_trk(
    streamlines: list[tuple[np.ndarray, np.ndarray]],
    *,
    affine: np.ndarray | None = None,
    n_properties: int | None = None,
) -> bytes:
    """Serialize [(points (n,3), properties (p,)), ...] to .trk bytes."""
    if affine is None:
        affine = np.eye(4, dtype=np.float32)
    if n_properties is None:
        n_properties = len(streamlines[0][1]) if streamlines else 0
    out = io.BytesIO()
    out.write(
        TrkHeader(
            n_count=len(streamlines), n_properties=n_properties, affine=affine
        ).to_bytes()
    )
    for points, props in streamlines:
        points = np.asarray(points, dtype="<f4").reshape(-1, 3)
        props = np.asarray(props, dtype="<f4").reshape(-1)
        if len(props) != n_properties:
            raise ValueError(f"expected {n_properties} properties, got {len(props)}")
        out.write(struct.pack("<i", points.shape[0]))
        out.write(points.tobytes())
        out.write(props.tobytes())
    return out.getvalue()


def synth_trk(
    rng: np.random.Generator,
    n_streamlines: int,
    *,
    mean_points: int = 40,
    n_properties: int = 2,
) -> bytes:
    """Synthetic tractography shard (benchmark data generator)."""
    affine = np.eye(4, dtype=np.float32)
    affine[:3, 3] = rng.normal(size=3).astype(np.float32)
    streamlines = []
    for _ in range(n_streamlines):
        n = max(3, int(rng.poisson(mean_points)))
        pts = rng.normal(size=(n, 3)).astype(np.float32).cumsum(axis=0)
        props = rng.normal(size=n_properties).astype(np.float32)
        streamlines.append((pts, props))
    return write_trk(streamlines, affine=affine, n_properties=n_properties)


class LazyTrkReader:
    """Nibabel-style lazy streamline iterator over a file-like object.

    Reads the 1000-byte header eagerly; `streamlines()` yields one record
    at a time with three reads per record (count, points, properties) and
    applies the affine to every coordinate.
    """

    def __init__(self, fileobj) -> None:
        self.f = fileobj
        self.header = TrkHeader.from_bytes(fileobj.read(HEADER_SIZE))
        self._rot = self.header.affine[:3, :3].astype(np.float32)
        self._trans = self.header.affine[:3, 3].astype(np.float32)

    def streamlines(self) -> Iterator[Streamline]:
        n_props = self.header.n_properties
        for _ in range(self.header.n_count):
            raw_n = self.f.read(4)
            if len(raw_n) < 4:
                return  # truncated (multi-file stream boundary handled upstream)
            (npoints,) = struct.unpack("<i", raw_n)
            pts = np.frombuffer(
                self.f.read(npoints * 12), dtype="<f4"
            ).reshape(npoints, 3)
            props = np.frombuffer(
                self.f.read(n_props * 4), dtype="<f4"
            ) if n_props else np.empty(0, np.float32)
            # Affine is always applied on read (paper: compute is inherent).
            pts = pts @ self._rot.T + self._trans
            yield Streamline(points=pts, properties=props)


def iter_streamlines_multi(fileobj, total_size: int) -> Iterator[Streamline]:
    """Iterate streamlines across a concatenated multi-file logical stream
    (Rolling Prefetch treats the shard list as one file; each shard carries
    its own header)."""
    while fileobj.tell() < total_size:
        reader = LazyTrkReader(fileobj)
        yield from reader.streamlines()
