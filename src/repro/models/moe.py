"""Mixture-of-Experts with capacity-based top-k routing.

Dispatch uses sort-free scatter/gather indexing (cumulative-position
slotting) instead of GShard dispatch einsums: the (tokens, E, capacity)
one-hot dispatch tensor those einsums materialize is O(T·E·C) — terabytes
at our train shapes — while the slot-index formulation is O(T·E + E·C·D).

Expert-dimension sharding resolves through the logical rules: when the
expert count divides the tensor axis (dbrx 16, jamba 16) the expert dim
shards over "model" and token transport lowers to all-to-all-style
collectives; otherwise (granite's 40) the "expert" rule falls back and the
per-expert d_ff shards instead ("tp") — both from the same annotation,
because `ShardingRules.spec` assigns axes first-come-first-served per
tensor with divisibility fallback.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _act
from repro.models.spec import ParamSpec
from repro.sharding.rules import constrain


def moe_spec(cfg: ModelConfig) -> dict:
    e, d, f = cfg.moe_padded_experts, cfg.d_model, cfg.d_ff
    spec = {
        "w_router": ParamSpec((d, e), ("fsdp", None), ("fan_in", d)),
        "w_up": ParamSpec((e, d, f), ("expert", "fsdp", "tp"), ("fan_in", d)),
        "w_down": ParamSpec((e, f, d), ("expert", "tp", "fsdp"), ("fan_in", f)),
    }
    if cfg.glu:
        spec["w_gate"] = ParamSpec((e, d, f), ("expert", "fsdp", "tp"), ("fan_in", d))
    return spec


def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    cap = int(
        math.ceil(tokens_per_group * cfg.moe_top_k * cfg.moe_capacity_factor
                  / cfg.moe_num_experts)
    )
    # MXU-align large capacities; tiny groups (decode: one token per row)
    # keep exact capacity — the align-to-8 floor inflated decode-cell
    # expert FLOPs 8x (dbrx decode_32k useful 0.61 -> 0.04).
    if cap >= 8:
        return -(-cap // 8) * 8
    return max(1, cap)


def moe(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balancing loss scalar).

    Routing is PER BATCH ROW (GShard-style groups = batch rows): every
    routing tensor keeps the batch dimension, so with batch sharded over
    the data axes all cumsums / gathers / scatters stay shard-local.
    The original global-token formulation forced GSPMD to all-gather the
    (tokens x E) cumsum AND the gathered (E*C, D) dispatch buffer on every
    chip — measured 2.1e12 collective bytes/chip/layer and ~70x replicated
    expert FLOPs on granite train_4k (see EXPERIMENTS.md §Perf)."""
    b, s, d = x.shape
    e, k = cfg.moe_padded_experts, cfg.moe_top_k
    e_real = cfg.moe_num_experts
    cap = capacity(cfg, s)
    n_slots = e * cap

    # --- routing (fp32, per-row) --------------------------------------------
    logits = jnp.einsum(
        "bsd,de->bse", x, p["w_router"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    if e != e_real:
        # Dummy padding experts (sharding alignment) never win routing.
        pad_mask = jnp.arange(e) >= e_real
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)                   # (B, S, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)           # (B, S, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- slot assignment (per-row cumulative positions) -----------------------
    flat_e = expert_idx.reshape(b, s * k)                     # (B, S*k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # (B, S*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1
    keep = pos_in_e < cap
    slot = jnp.where(keep, flat_e * cap + pos_in_e, n_slots)  # overflow->trash
    tok = jnp.broadcast_to(
        jnp.arange(s * k, dtype=jnp.int32) // k, (b, s * k)
    )

    # Row-local scatters/gathers are expressed through vmap so the batch
    # dimension reaches HLO as a true scatter/gather batch dim — explicit
    # row-index arrays turn dim 0 into a scattered dimension and force
    # GSPMD to replicate + all-reduce the full (B, S, D) combine (measured
    # 4.1e11 B/chip on granite train_4k before this change).
    gate_flat = (gate_vals.reshape(b, s * k) * keep).astype(jnp.float32)
    slot_tok = jax.vmap(
        lambda sl, tk: jnp.full((n_slots + 1,), s, jnp.int32).at[sl].set(tk)
    )(slot, tok)
    slot_gate = jax.vmap(
        lambda sl, gv: jnp.zeros((n_slots + 1,), jnp.float32).at[sl].set(gv)
    )(slot, gate_flat)

    # --- expert computation (all gathers/scatters row-local) -------------------
    xp = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    xe = jax.vmap(lambda xr, st: jnp.take(xr, st, axis=0))(
        xp, slot_tok[:, :n_slots]
    ).reshape(b, e, cap, d)
    xe = constrain(xe, "batch", "expert", None, None)
    h = jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(x.dtype))
    if cfg.glu:
        gate = jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(x.dtype))
        h = _act(cfg, gate) * h
    else:
        h = _act(cfg, h)
    h = constrain(h, "batch", "expert", None, "tp")
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))

    # --- combine (row-local scatter-add) ---------------------------------------
    yflat = ye.reshape(b, n_slots, d) * slot_gate[:, :n_slots, None].astype(ye.dtype)
    y = jax.vmap(
        lambda st, yf: jnp.zeros((s + 1, d), yf.dtype).at[st].add(yf)
    )(slot_tok[:, :n_slots], yflat)[:, :s]
    y = constrain(y, "batch", None, "residual")

    # --- aux load-balancing loss (Switch-style) -------------------------------
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return y, aux
