"""Parameter specifications: one declarative tree drives real init, abstract
(ShapeDtypeStruct) init for the no-allocation dry-run, and NamedSharding
assignment — guaranteeing the three can never drift apart."""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.sharding.rules import ShardingRules


@dataclass(frozen=True)
class Ax:
    """Leaf marker carrying logical sharding axes for a non-parameter tensor
    (caches, activations) in a structure-matched axes tree. A plain tuple
    cannot serve: tuples are pytree nodes and would dissolve into leaves."""

    axes: tuple


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]       # logical axis names
    init: tuple | str = ("normal", 0.02)
    dtype: object = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def stacked(n: int, tree):
    """Add a leading stacking dim (scan-over-periods) to every spec."""
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), (None, *s.axes), s.init, s.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _materialize(spec: ParamSpec, key, dtype) -> jax.Array:
    kind = spec.init if isinstance(spec.init, str) else spec.init[0]
    if kind == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if kind == "ones":
        return jnp.ones(spec.shape, dtype)
    if kind == "constant":
        return jnp.full(spec.shape, spec.init[1], dtype)
    if kind == "normal":
        std = spec.init[1]
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    if kind == "fan_in":
        fan_in = spec.init[1]
        std = 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    if kind == "uniform":
        lo, hi = spec.init[1], spec.init[2]
        return (jax.random.uniform(key, spec.shape, jnp.float32, lo, hi)).astype(dtype)
    if kind == "a_log":
        # Mamba-2 A initialization: A = -exp(a_log), a_log = log(U[1,16]).
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if kind == "dt_bias":
        # dt bias such that softplus(dt_bias) ~ U[dt_min, dt_max].
        dt = jnp.exp(
            jax.random.uniform(key, spec.shape, jnp.float32)
            * (math.log(0.1) - math.log(1e-3))
            + math.log(1e-3)
        )
        return jnp.log(jnp.expm1(dt)).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(spec_tree, key, param_dtype=jnp.float32):
    """Materialize real parameters; per-leaf keys derive from tree paths so
    adding a parameter never reshuffles the others' randomness."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=_is_spec
    )[0]

    def leaf_key(path) -> jax.Array:
        k = key
        for entry in path:
            name = getattr(entry, "key", None) or getattr(entry, "idx", None)
            k = jax.random.fold_in(k, hash(str(name)) % (2**31))
        return k

    out = {jax.tree_util.keystr(p): _materialize(s, leaf_key(p), s.dtype if s.dtype != jnp.float32 else param_dtype)
           for p, s in leaves_with_paths}
    treedef = jax.tree_util.tree_structure(spec_tree, is_leaf=_is_spec)
    ordered = [out[jax.tree_util.keystr(p)] for p, _ in leaves_with_paths]
    return jax.tree_util.tree_unflatten(treedef, ordered)


def abstract_params(spec_tree, rules: ShardingRules | None = None,
                    param_dtype=jnp.float32):
    """ShapeDtypeStruct tree with shardings — the dry-run's no-allocation
    stand-in for real parameters."""

    def leaf(s: ParamSpec):
        dtype = s.dtype if s.dtype != jnp.float32 else param_dtype
        sharding = rules.sharding(s.axes, s.shape) if rules else None
        if sharding is not None:
            return jax.ShapeDtypeStruct(s.shape, dtype, sharding=sharding)
        return jax.ShapeDtypeStruct(s.shape, dtype)

    return jax.tree.map(leaf, spec_tree, is_leaf=_is_spec)


def param_shardings(spec_tree, rules: ShardingRules):
    return jax.tree.map(
        lambda s: rules.sharding(s.axes, s.shape), spec_tree, is_leaf=_is_spec
    )


def abstract_like(shape_tree, axes_tree, rules: ShardingRules | None):
    """Attach shardings (from an Ax tree) to a ShapeDtypeStruct tree."""

    def leaf(sds, ax):
        sharding = None
        if rules is not None and isinstance(ax, Ax):
            sharding = rules.sharding(ax.axes, sds.shape)
        if sharding is None:
            return jax.ShapeDtypeStruct(sds.shape, sds.dtype)
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sharding)

    return jax.tree.map(
        leaf, shape_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, Ax) or x is None,
    )


def param_count(spec_tree) -> int:
    return sum(
        math.prod(s.shape)
        for s in jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    )


def param_bytes(spec_tree, bytes_per_param: int = 4) -> int:
    return param_count(spec_tree) * bytes_per_param
