"""Benchmark suite entry point: one module per paper figure/table plus the
beyond-paper pipelines. Prints ``name,us_per_call,derived`` CSV rows.

  python -m benchmarks.run [--quick] [--only fig2_filecount,...]
"""

from __future__ import annotations

import argparse
import sys
import time

BENCHES = {
    "fig2_filecount": "benchmarks.bench_filecount",
    "fig4_blocksize": "benchmarks.bench_blocksize",
    "fig3_parallel": "benchmarks.bench_parallel",
    "fig5_usecases": "benchmarks.bench_usecases",
    "model_validation": "benchmarks.bench_model_validation",
    "training_pipeline": "benchmarks.bench_training_pipeline",
    "ckpt_restore": "benchmarks.bench_ckpt_restore",
    "adaptive_read": "benchmarks.bench_adaptive_read",
    "write_pipeline": "benchmarks.bench_write_pipeline",
    "cache_reuse": "benchmarks.bench_cache_reuse",
    "hsm": "benchmarks.bench_hsm",
    "peer": "benchmarks.bench_peer",
    "resilience": "benchmarks.bench_resilience",
    "integrity": "benchmarks.bench_integrity",
    "roofline": "benchmarks.bench_roofline",
    "analysis": "benchmarks.bench_analysis",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(BENCHES))
    args = ap.parse_args()
    selected = [s for s in args.only.split(",") if s] or list(BENCHES)

    import importlib

    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        mod = importlib.import_module(BENCHES[name])
        t0 = time.time()
        try:
            mod.main(quick=args.quick)
            print(f"bench_{name}_wall,{(time.time() - t0) * 1e6:.0f},status=ok")
        except AssertionError as e:
            failures.append((name, e))
            print(f"bench_{name}_wall,{(time.time() - t0) * 1e6:.0f},"
                  f"status=CLAIM_FAILED:{e}")
        except Exception as e:  # repro: allow[RP005] — recorded as status=ERROR; run exits 1
            failures.append((name, e))
            print(f"bench_{name}_wall,{(time.time() - t0) * 1e6:.0f},"
                  f"status=ERROR:{type(e).__name__}:{e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
