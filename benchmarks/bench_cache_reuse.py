"""Shared/persistent cache A/B: crash-warm restarts and cross-reader
single-flight, on the scaled-Table-I simulated S3 store.

Two scenarios, mirroring the north-star workload (many readers / restarted
jobs hitting the same objects):

  * ``restart`` — the same logical job runs twice over a persistent
    journaled `DirTier`. The cold run fetches every block from the store;
    the "restarted" run constructs a brand-new tier over the same
    directory (journal recovery) and a brand-new `PrefetchFS` (index
    primed from the recovered tier). Acceptance: the warm run performs
    **zero** store GETs for cached blocks.
  * ``shared`` — N concurrent readers stream the same file. With the
    shared `CacheIndex` (one fs), single-flight registration means every
    block crosses the store once (~1x); the baseline arm gives each
    reader its own fs + tier (the pre-PR behaviour) and pays ~Nx.

Emits ``name,us_per_call,derived`` CSV rows and writes the full record to
``BENCH_cache.json`` so CI tracks cache-reuse behaviour over time.

  PYTHONPATH=src python -m benchmarks.bench_cache_reuse [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import time

from benchmarks.common import S3_BW, S3_LATENCY, emit, make_trk_dataset
from repro.io import IOPolicy, PrefetchFS, open_store
from repro.store import DirTier, MemTier


def _store(ds, bucket: str):
    store = open_store(
        f"sims3://{bucket}?latency_ms={S3_LATENCY * 1e3:g}"
        f"&bw_mbps={S3_BW / 1e6:g}",
        fresh=True,
    )
    for k, v in ds.objects.items():
        store.backing.put(k, v)
    return store


# --------------------------------------------------------------------------- #
# scenario 1: cold vs warm (crash/restart) through a persistent DirTier
# --------------------------------------------------------------------------- #
def bench_restart(n_files: int, blocksize: int, cache_root: str) -> dict:
    ds = make_trk_dataset(n_files)
    store = _store(ds, "bench-cache-restart")
    policy = IOPolicy(engine="rolling", blocksize=blocksize, depth=2,
                      keep_cached=True, eviction_interval_s=0.05)
    capacity = 2 * ds.total_bytes

    def run() -> tuple[float, dict]:
        tier = DirTier(capacity, root=cache_root)
        fs = PrefetchFS(store, policy=policy, tiers=[tier])
        t0 = time.perf_counter()
        try:
            with fs:
                f = fs.open_many(ds.metas())
                data = f.read()
                f.close()
            dt = time.perf_counter() - t0
        finally:
            tier.close()   # release the root lock; the "restart" owns it next
        assert data == b"".join(v for _, v in sorted(ds.objects.items()))
        return dt, fs.stats().snapshot()

    t_cold, cold = run()
    bytes_before_warm = store.link.bytes_moved
    t_warm, warm = run()                     # fresh tier object: recovery
    warm_fetched = warm["totals"].get("blocks_fetched", 0)
    cold_fetched = cold["totals"].get("blocks_fetched", 0)
    # Acceptance: a restarted job pays ZERO store GETs for cached blocks
    # (the link moves no data bytes; size HEADs are payload-free).
    assert warm_fetched == 0, f"warm restart refetched {warm_fetched} blocks"
    assert store.link.bytes_moved == bytes_before_warm
    assert warm["cache"]["recovered"] == cold_fetched
    speedup = t_cold / t_warm
    emit("cache_restart_cold", t_cold * 1e6, f"blocks={cold_fetched}")
    emit("cache_restart_warm", t_warm * 1e6,
         f"store_gets=0;hits={warm['totals'].get('cache_hits', 0)};"
         f"speedup={speedup:.2f}x")
    return dict(
        cold_s=t_cold,
        warm_s=t_warm,
        speedup=speedup,
        cold_blocks_fetched=cold_fetched,
        warm_blocks_fetched=warm_fetched,
        warm_cache_hits=warm["totals"].get("cache_hits", 0),
        recovered_blocks=warm["cache"]["recovered"],
        params=dict(n_files=n_files, blocksize=blocksize,
                    dataset_bytes=ds.total_bytes),
    )


# --------------------------------------------------------------------------- #
# scenario 2: N concurrent readers, shared index vs per-reader caches
# --------------------------------------------------------------------------- #
def bench_shared_readers(n_readers: int, blocksize: int) -> dict:
    ds = make_trk_dataset(1, streamlines_per_file=8000)
    want = b"".join(v for _, v in sorted(ds.objects.items()))
    nblocks = -(-ds.total_bytes // blocksize)
    policy = IOPolicy(engine="rolling", blocksize=blocksize, depth=2,
                      keep_cached=True, eviction_interval_s=0.05)

    def run_threads(open_reader) -> tuple[float, list]:
        readers: list = [None] * n_readers
        errs: list = []

        def go(i):
            try:
                f = open_reader()
                readers[i] = f
                assert f.read() == want
                f.close()
            except Exception as e:   # repro: allow[RP005] — surfaced below
                errs.append(e)

        threads = [threading.Thread(target=go, args=(i,))
                   for i in range(n_readers)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        assert not errs, errs
        return dt, readers

    # Shared arm: ONE fs -> one CacheIndex -> single-flight fetches.
    store_a = _store(ds, "bench-cache-shared")
    fs = PrefetchFS(store_a, policy=policy,
                    tiers=[MemTier(2 * ds.total_bytes)])
    t_shared, readers = run_threads(lambda: fs.open_many(ds.metas()))
    shared_fetched = sum(r.stats.blocks_fetched for r in readers)
    shared_hits = sum(r.stats.cache_hits + r.stats.flight_joins
                      for r in readers)
    fs.close()

    # Baseline arm: every reader brings its own fs + tier (pre-PR shape).
    store_b = _store(ds, "bench-cache-unshared")

    def own_fs_reader():
        one = PrefetchFS(store_b, policy=policy,
                         tiers=[MemTier(2 * ds.total_bytes)])
        return one.open_many(ds.metas())

    t_unshared, readers_b = run_threads(own_fs_reader)
    unshared_fetched = sum(r.stats.blocks_fetched for r in readers_b)

    # Acceptance: shared readers issue ~1x (not Nx) block fetches.
    assert shared_fetched == nblocks, (
        f"shared arm fetched {shared_fetched}, expected {nblocks}"
    )
    assert unshared_fetched == n_readers * nblocks
    speedup = t_unshared / t_shared
    emit("cache_shared_readers", t_shared * 1e6,
         f"n={n_readers};fetched={shared_fetched};hits={shared_hits};"
         f"speedup={speedup:.2f}x")
    emit("cache_unshared_readers", t_unshared * 1e6,
         f"n={n_readers};fetched={unshared_fetched}")
    return dict(
        shared_s=t_shared,
        unshared_s=t_unshared,
        speedup=speedup,
        n_readers=n_readers,
        blocks=nblocks,
        shared_blocks_fetched=shared_fetched,
        unshared_blocks_fetched=unshared_fetched,
        fetch_amplification_shared=shared_fetched / nblocks,
        fetch_amplification_unshared=unshared_fetched / nblocks,
        params=dict(blocksize=blocksize, dataset_bytes=ds.total_bytes),
    )


def main(quick: bool = False, out: str = "BENCH_cache.json") -> None:
    with tempfile.TemporaryDirectory(prefix="bench-cache-") as tmp:
        cache_root = os.path.join(tmp, "tier")
        if quick:
            restart = bench_restart(n_files=4, blocksize=64 << 10,
                                    cache_root=cache_root)
            shared = bench_shared_readers(n_readers=4, blocksize=64 << 10)
        else:
            restart = bench_restart(n_files=12, blocksize=128 << 10,
                                    cache_root=cache_root)
            shared = bench_shared_readers(n_readers=8, blocksize=64 << 10)

    record = dict(
        restart=restart,
        shared=shared,
        link=dict(latency_s=S3_LATENCY, bandwidth_Bps=S3_BW),
        smoke=bool(quick),
    )
    with open(out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {out}: warm restart {restart['speedup']:.2f}x with "
          f"{restart['warm_blocks_fetched']} store GETs; "
          f"{shared['n_readers']} shared readers fetched "
          f"{shared['fetch_amplification_shared']:.2f}x blocks "
          f"(unshared {shared['fetch_amplification_unshared']:.2f}x)")


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_cache.json")
    args = ap.parse_args()
    main(quick=args.smoke, out=args.out)


if __name__ == "__main__":
    _cli()
