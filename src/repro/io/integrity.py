"""Per-block content digests: mint once, verify at every boundary.

The chaos harness long conceded that corruption was "delivered, not
detected": engines length-check but never checksum, so a flipped byte
from the store, a bit-rotted block in a persistent `DirTier`, or a
byzantine peer frame reached the application silently. This module is
the one place digests are defined; every path that moves block bytes —
store fetch, cache-tier read, HSM promotion/demotion, the peer wire
protocol, checkpoint manifests — carries the string this module mints
and calls :func:`check_block` at its boundary.

A digest is a short self-describing string, ``"<algo>:<hex>"``:

  * ``crc32:%08x`` — `zlib.crc32`, the default. Fast enough to sit on
    the hot read path (the "edges" verify mode is benchmarked at <5%
    read-throughput overhead) and *identical* to the crc the `DirTier`
    journal already records, so a journal record and an index digest
    are interchangeable (`crc_digest` converts).
  * ``blake2:<32 hex>`` — `hashlib.blake2b` (16-byte digest) for
    callers that want collision resistance over speed (checkpoint
    manifests default to crc32 too; flip `algo=` to harden).

On mismatch the caller raises (or lets :func:`check_block` raise)
`IntegrityError` — a `TransientStoreError` subclass, so the shared
`Retrier` re-fetches from the next-more-authoritative source instead
of surfacing wrong bytes; see `repro.io.retry` for the typed
exhaustion contract.
"""

from __future__ import annotations

import hashlib
import zlib

from repro.store.base import IntegrityError

__all__ = [
    "DIGEST_ALGOS",
    "IntegrityError",
    "block_digest",
    "check_block",
    "crc_digest",
    "digest_matches",
]

DIGEST_ALGOS = ("crc32", "blake2")

DEFAULT_ALGO = "crc32"


def block_digest(data: bytes, algo: str = DEFAULT_ALGO) -> str:
    """Content digest of a block payload, as ``"<algo>:<hex>"``."""
    if algo == "crc32":
        return f"crc32:{zlib.crc32(data) & 0xFFFFFFFF:08x}"
    if algo == "blake2":
        return f"blake2:{hashlib.blake2b(data, digest_size=16).hexdigest()}"
    raise ValueError(f"unknown digest algo {algo!r} (want one of {DIGEST_ALGOS})")


def crc_digest(crc: int) -> str:
    """Canonical digest string for a raw crc32 value — the bridge from
    `DirTier` journal records (which store the bare int) to the digest
    strings everything else carries."""
    return f"crc32:{crc & 0xFFFFFFFF:08x}"


def digest_matches(data: bytes, digest: str) -> bool:
    """Recompute ``digest``'s algorithm over ``data`` and compare. An
    unparseable digest never matches (fail closed)."""
    algo, _, _ = digest.partition(":")
    if algo not in DIGEST_ALGOS:
        return False
    return block_digest(data, algo) == digest


def check_block(data: bytes, digest: str | None, *,
                what: str = "block") -> None:
    """Raise `IntegrityError` when ``data`` does not match ``digest``.
    A ``None`` digest is a no-op — callers pass through whatever the
    index/journal/wire knows, which may be nothing (verify="off"
    producers, pre-digest journals)."""
    if digest is None:
        return
    if not digest_matches(data, digest):
        algo = digest.partition(":")[0]
        got = (block_digest(data, algo) if algo in DIGEST_ALGOS
               else "<unparseable reference>")
        raise IntegrityError(
            f"digest mismatch for {what}: expected {digest}, got {got} "
            f"over {len(data)} bytes"
        )
