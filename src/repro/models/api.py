"""Unified model facade: one object per architecture config exposing
spec/init/loss/prefill/decode regardless of family (decoder-only LM,
enc-dec, SSM, hybrid, VLM)."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as ED
from repro.models import layers as L
from repro.models import lm as LM
from repro.models.spec import (
    abstract_like,
    abstract_params,
    init_params,
    param_count,
)
from repro.sharding.rules import ShardingRules


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- parameters -----------------------------------------------------------
    def spec(self) -> dict:
        if self.cfg.is_encdec:
            return ED.encdec_spec(self.cfg)
        return LM.lm_spec(self.cfg)

    def init(self, key, param_dtype=jnp.float32) -> dict:
        return init_params(self.spec(), key, param_dtype)

    def abstract_params(self, rules: ShardingRules | None = None,
                        param_dtype=jnp.float32):
        return abstract_params(self.spec(), rules, param_dtype)

    def param_count(self) -> int:
        return param_count(self.spec())

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of num_experts)."""
        total = self.param_count()
        cfg = self.cfg
        if not cfg.is_moe:
            return total
        from repro.models.moe import moe_spec
        from repro.models.spec import param_count as pc

        moe_layers = sum(1 for b in cfg.pattern if b.ffn == "moe") * cfg.periods
        per_layer = pc(moe_spec(cfg))
        router = cfg.d_model * cfg.moe_num_experts
        expert_part = per_layer - router
        inactive = moe_layers * expert_part * (
            1 - cfg.moe_top_k / cfg.moe_num_experts
        )
        return int(total - inactive)

    # -- training -------------------------------------------------------------
    def loss(self, params, batch: dict, *, q_chunk: int = 512,
             loss_chunk: int = 512, remat: bool = True) -> jax.Array:
        cfg = self.cfg
        if cfg.is_encdec:
            return ED.encdec_loss(
                params, cfg, batch["enc_inputs"], batch["dec_ids"],
                batch["labels"], q_chunk=q_chunk, loss_chunk=loss_chunk,
                remat=remat,
            )
        return LM.lm_loss(
            params, cfg, batch["inputs"], batch["labels"],
            q_chunk=q_chunk, loss_chunk=loss_chunk, remat=remat,
        )

    # -- serving ---------------------------------------------------------------
    def prefill(self, params, batch: dict, *, q_chunk: int = 512):
        cfg = self.cfg
        if cfg.is_encdec:
            return ED.encdec_prefill(
                params, cfg, batch["enc_inputs"], batch["dec_prompt"],
                q_chunk=q_chunk,
            )
        return LM.lm_prefill(params, cfg, batch["inputs"], q_chunk=q_chunk)

    def decode_step(self, params, inputs, caches, position):
        cfg = self.cfg
        if cfg.is_encdec:
            return ED.encdec_decode_step(params, cfg, inputs, caches, position)
        return LM.lm_decode_step(params, cfg, inputs, caches, position)

    # -- decode-state construction (concrete and abstract) ----------------------
    def make_decode_caches(self, batch: int, seq_len: int, *, filled: bool):
        """Concrete decode caches; `filled` marks seq_len-1 positions valid
        (the assigned decode cells: one new token against a seq_len cache)."""
        cfg = self.cfg
        length = seq_len - 1 if filled else 0
        if cfg.is_encdec:
            return ED.make_decode_caches(
                cfg, batch, seq_len, cross_len=seq_len, length=length
            )
        return LM.make_stack_cache(cfg, batch, seq_len, length=length)

    def abstract_decode_caches(self, batch: int, seq_len: int,
                               rules: ShardingRules | None):
        shapes = jax.eval_shape(
            lambda: self.make_decode_caches(batch, seq_len, filled=True)
        )
        axes = LM.stack_cache_axes(self.cfg)
        return abstract_like(shapes, axes, rules)

    def decode_inputs(self, batch: int):
        """Concrete one-token decode inputs."""
        if self.cfg.embed_inputs and not self.cfg.is_encdec:
            return jnp.zeros((batch, 1, self.cfg.d_model), L.COMPUTE_DTYPE)
        return jnp.zeros((batch, 1), jnp.int32)


def make_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
