"""codeqwen1.5-7b — Qwen1.5-architecture dense transformer.

32L, d_model 4096, 32 heads (GQA kv=32, i.e. MHA), d_ff 13440,
vocab 92416. Qwen1.5 specifics: QKV bias, RMSNorm, SwiGLU.
[hf:Qwen/CodeQwen1.5-7B; hf]
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="codeqwen1.5-7b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        d_ff=13440,
        vocab_size=92416,
        pattern=(BlockDef("attn", "dense"),),
        norm_type="rmsnorm",
        qkv_bias=True,
        act="silu",
        glu=True,
        rope_theta=1000000.0,
        source="hf:Qwen/CodeQwen1.5-7B",
    )
)
