"""Online closed-loop tuning (beyond the paper).

The paper derives the optimal block count n̂_b = sqrt(c·f/l_c) (Eq. 4) but
leaves selection to the user. At thousand-node scale nobody hand-tunes
per-dataset block sizes, so we close the loop three ways:

  * `BlockSizeTuner` fits (l_c, b_cr, c) from observed request timings and
    reader compute gaps, then retunes block size AND coalesce width
    between opens. Per-request samples feed a least-squares fit of
    `seconds = l_c + nbytes / b_cr` — request sizes vary (coalesced runs,
    short tail blocks), which is exactly what separates the intercept
    (latency) from the slope (1/bandwidth). EWMA fallbacks cover callers
    that observe latency/bandwidth directly and let drifting cloud
    conditions (the paper's §III-C bandwidth variability) track.
  * `coalesce width` — Eq. 1's `n_b·l_c` term says adjacent blocks should
    share one request while the link is latency-bound (see
    `cost_model.coalesce_width`).
  * `AimdDepthController` — concurrent fetch streams are grown additively
    while observed fetch throughput keeps improving and cut
    multiplicatively when it regresses, the classic congestion-control
    loop applied to request concurrency.
"""

from __future__ import annotations

import math
import threading
import time
from collections import deque
from dataclasses import dataclass

from repro.core import cost_model


@dataclass
class Ewma:
    alpha: float = 0.2
    value: float | None = None

    def update(self, x: float) -> float:
        self.value = x if self.value is None else (1 - self.alpha) * self.value + self.alpha * x
        return self.value


class BlockSizeTuner:
    def __init__(
        self,
        min_blocksize: int = 1 << 20,
        max_blocksize: int = 1 << 31,
        alpha: float = 0.2,
        max_samples: int = 512,
    ) -> None:
        self.min_blocksize = min_blocksize
        self.max_blocksize = max_blocksize
        self._lat = Ewma(alpha)
        self._bw = Ewma(alpha)
        self._cpb = Ewma(alpha)  # compute seconds per byte
        # (nbytes, seconds) per store request, for the least-squares fit.
        self._samples: deque[tuple[float, float]] = deque(maxlen=max_samples)
        self._fit: tuple[float | None, float | None] | None = None
        self._lock = threading.Lock()

    # -- observations -------------------------------------------------------
    def observe_request(self, nbytes: int, seconds: float) -> None:
        """One store request (possibly a coalesced multi-block GET):
        `nbytes` payload moved in `seconds` wall time. Varied request
        sizes let the regression split latency from bandwidth."""
        if nbytes <= 0 or seconds <= 0:
            return
        with self._lock:
            self._samples.append((float(nbytes), float(seconds)))
            self._fit = None  # recompute lazily

    def observe_fetch(self, nbytes: int, seconds: float) -> None:
        """Back-compat alias for :meth:`observe_request`."""
        self.observe_request(nbytes, seconds)

    def observe_latency(self, seconds: float) -> None:
        self._lat.update(max(seconds, 0.0))

    def observe_bandwidth(self, bytes_per_s: float) -> None:
        if bytes_per_s > 0:
            self._bw.update(bytes_per_s)

    def observe_compute(self, nbytes: int, seconds: float) -> None:
        if nbytes > 0 and seconds >= 0:
            self._cpb.update(seconds / nbytes)

    # -- the request-timing fit --------------------------------------------
    def _fitted(self) -> tuple[float | None, float | None]:
        """(latency_s, bandwidth_Bps) from least squares over the request
        samples; (None, None) while underdetermined (too few samples or no
        size variance — a fixed-width scheduler at one block size cannot
        separate the two, which is why the scheduler probes widths)."""
        with self._lock:
            if self._fit is not None:
                return self._fit
            n = len(self._samples)
            if n < 4:
                self._fit = (None, None)
                return self._fit
            xs = [s[0] for s in self._samples]
            ys = [s[1] for s in self._samples]
            mx = sum(xs) / n
            my = sum(ys) / n
            sxx = sum((x - mx) ** 2 for x in xs)
            if sxx <= (0.01 * mx) ** 2 * n:  # effectively no size variance
                self._fit = (None, None)
                return self._fit
            slope = sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / sxx
            if slope <= 0:
                # Noise swamped the payload term: everything we saw was
                # latency. Report the mean request time as latency.
                self._fit = (max(my, 0.0), None)
                return self._fit
            intercept = my - slope * mx
            self._fit = (max(intercept, 0.0), 1.0 / slope)
            return self._fit

    # -- estimates ----------------------------------------------------------
    @property
    def latency_s(self) -> float | None:
        if self._lat.value is not None:
            return self._lat.value
        return self._fitted()[0]

    @property
    def bandwidth_Bps(self) -> float | None:
        if self._bw.value is not None:
            return self._bw.value
        return self._fitted()[1]

    @property
    def compute_s_per_byte(self) -> float | None:
        return self._cpb.value

    @property
    def n_requests_observed(self) -> int:
        with self._lock:
            return len(self._samples)

    def estimates(self) -> dict:
        """Snapshot of every estimate (surfaced through `FSStats`)."""
        return {
            "latency_s": self.latency_s,
            "bandwidth_Bps": self.bandwidth_Bps,
            "compute_s_per_byte": self.compute_s_per_byte,
            "requests_observed": self.n_requests_observed,
        }

    # -- planning ---------------------------------------------------------
    def suggest_blocksize(self, total_bytes: int,
                          cache_budget: int | None = None,
                          default: int | None = None) -> int:
        """Eq.-4 optimum, clamped to [min, max, cache budget]; `default`
        (falling back to the paper's 64 MiB) while unobserved."""
        lc = self.latency_s
        c = self._cpb.value
        if not lc or c is None:
            if default:
                # The caller's configured blocksize is not ours to clamp
                # to the tuner's [min, max] — only the cache budget binds.
                if cache_budget is not None:
                    default = min(default, max(1, cache_budget // 2))
                return max(1, default)
            return self._clamp(64 << 20, cache_budget)
        nb = cost_model.optimal_num_blocks(total_bytes, c, lc)
        if not math.isfinite(nb) or nb < 1:
            nb = 1.0
        return self._clamp(int(total_bytes / nb), cache_budget)

    def suggest_coalesce(self, blocksize: int, max_width: int) -> int:
        """Cost-model coalesce width for the estimated link; 1 while the
        link constants are unknown (the scheduler probes instead)."""
        lc, bw = self.latency_s, self.bandwidth_Bps
        if not lc:
            return 1
        return cost_model.coalesce_width(
            lc, bw if bw else float("inf"), blocksize, max_width
        )

    def _clamp(self, blocksize: int, cache_budget: int | None) -> int:
        blocksize = max(self.min_blocksize, min(self.max_blocksize, blocksize))
        if cache_budget is not None:
            # Leave room for at least two blocks so the pipeline can roll.
            blocksize = min(blocksize, max(1, cache_budget // 2))
        return max(1, blocksize)

    def predicted_speedup(self, total_bytes: int, blocksize: int) -> float | None:
        lc, bw, c = self.latency_s, self.bandwidth_Bps, self._cpb.value
        if not lc or not bw or c is None:
            return None
        nb = max(1, math.ceil(total_bytes / blocksize))
        p = cost_model.CostParams(f=total_bytes, n_b=nb, l_c=lc, b_cr=bw, c=c)
        return cost_model.speedup(p)


class AimdDepthController:
    """Additive-increase / multiplicative-decrease control of concurrent
    prefetch streams, driven by observed fetch throughput.

    Every `window` completed fetches close a measurement window; if the
    window's throughput held (>= `tolerance` x the previous window's) the
    target grows by one stream, otherwise it halves — concurrency keeps
    probing upward while the store rewards it (S3 scales with request
    concurrency) and backs off fast when a shared link saturates.
    Thread-safe: fetch completions arrive from several streams at once.
    """

    def __init__(self, initial: int, max_depth: int, *, window: int = 4,
                 tolerance: float = 0.85,
                 throttle_cooldown_s: float = 0.25) -> None:
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.max_depth = max_depth
        self.target = max(1, min(initial, max_depth))
        self.peak = self.target
        self._window = max(1, window)
        self._tolerance = tolerance
        self._cooldown = throttle_cooldown_s
        self._lock = threading.Lock()
        self._n = 0
        self._bytes = 0
        self._t0: float | None = None
        self._last_thr: float | None = None
        self._last_cut: float | None = None
        self._last_grow: float | None = None
        self.adjustments = 0
        self.throttle_cuts = 0

    def on_throttle(self, now: float | None = None) -> int:
        """Backend pushback (503 SlowDown, `ThrottleError`): cut the
        stream target multiplicatively NOW, without waiting for a
        throughput window to close — the store has said, explicitly,
        that concurrency is too high. Like TCP's one-halving-per-RTT
        rule, cuts within ``throttle_cooldown_s`` of the last one are
        coalesced: N streams throttled by the same pressure burst count
        as ONE signal, not N halvings to the floor. The measurement
        window resets so the next throughput sample doesn't mix the
        pre- and post-throttle regimes; additive growth then re-probes
        upward once throughput holds — rate-limited to one step per
        cooldown while pushback is recent (within 8x the cooldown),
        since per-window growth at high fetch rates would climb right
        back into the throttled regime before the next cut is even
        allowed (see :meth:`_may_grow`)."""
        if now is None:
            now = time.perf_counter()   # same clock as on_fetch callers
        with self._lock:
            if (self._last_cut is not None
                    and now - self._last_cut < self._cooldown):
                return self.target
            self._last_cut = now
            # Ceil halving: 3 -> 2, not 3 -> 1 — at small depths floor
            # division overshoots the cut and strands the target below
            # the sustainable point.
            new = max(1, (self.target + 1) // 2)
            if new != self.target:
                self.target = new
                self.adjustments += 1
            self.throttle_cuts += 1
            self._n = 0
            self._bytes = 0
            self._t0 = None
            self._last_thr = None
            return self.target

    def _may_grow(self, now: float) -> bool:
        """Additive-increase gate. Caller holds `_lock`. Free-running
        when the backend has never pushed back (or not for 8x the
        cooldown); under recent throttle pressure, at most one +1 step
        per cooldown — the TCP-flavoured asymmetry that lets the target
        settle near the sustainable depth instead of sawtoothing at the
        window-close rate."""
        if self._cooldown <= 0.0 or self._last_cut is None:
            return True
        if now - self._last_cut >= 8.0 * self._cooldown:
            return True
        return (self._last_grow is None
                or now - self._last_grow >= self._cooldown)

    def on_fetch(self, nbytes: int, now: float) -> int:
        """Record one completed fetch; returns the (possibly updated)
        target stream count."""
        with self._lock:
            if self._t0 is None:
                self._t0 = now
                return self.target
            self._n += 1
            self._bytes += nbytes
            if self._n < self._window:
                return self.target
            thr = self._bytes / max(now - self._t0, 1e-9)
            last, self._last_thr = self._last_thr, thr
            self._n, self._bytes, self._t0 = 0, 0, now
            if last is None or thr >= last * self._tolerance:
                if not self._may_grow(now):
                    return self.target
                self._last_grow = now
                new = min(self.max_depth, self.target + 1)
            else:
                new = max(1, self.target // 2)
            if new != self.target:
                self.target = new
                self.adjustments += 1
                self.peak = max(self.peak, new)
            return self.target
