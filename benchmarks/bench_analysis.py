"""Static-analyzer benchmark: how long the tier-1 gate itself takes.

The analyzer runs in CI before the test stage, so its wall time is part
of every developer's feedback loop. This benchmark times a full
``analyze(src, tests)`` pass, the typestate (RP009+) interpreter alone,
the lock-graph build, and an interleaving-explorer smoke (the racy
fixture must be caught, the safe one must pass), and asserts the gate's
own invariants hold:

  * zero unsuppressed findings over the real tree,
  * an acyclic lock graph with the engine lock outermost,
  * the explorer catches the seeded race and clears the safe fixture,
  * the whole pass stays under a CI-scale wall-time budget.

Emits ``name,us_per_call,derived`` CSV rows and writes the full record
to ``BENCH_analysis.json`` so CI tracks the gate's cost over time.

  PYTHONPATH=src python -m benchmarks.bench_analysis [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks.common import emit
from repro.analysis import analyze, build_lock_graph, load_project
from repro.analysis.explore import (
    RacySingleFlightModel,
    SafeSingleFlightModel,
    explore,
    fuzz,
)
from repro.analysis.typestate import run_typestate

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))
# Generous CI-machine bound; the point is catching an accidental
# complexity blow-up (the call-graph and path fixpoints are the risky
# part), not micro-timing.
FULL_PASS_BUDGET_S = 60.0


def main(quick: bool = False, out: str = "BENCH_analysis.json") -> None:
    paths = [os.path.join(REPO_ROOT, "src")]
    if not quick:
        paths.append(os.path.join(REPO_ROOT, "tests"))

    t0 = time.perf_counter()
    project, findings = analyze(paths)
    t_analyze = time.perf_counter() - t0

    t0 = time.perf_counter()
    graph = build_lock_graph(project)
    t_graph = time.perf_counter() - t0

    n_files = len(project.modules)
    new = [f for f in findings if not f.suppressed]
    emit("analysis_full_pass", t_analyze * 1e6,
         f"files={n_files};findings={len(findings)};new={len(new)}")
    emit("analysis_lock_graph", t_graph * 1e6,
         f"locks={len(graph.nodes)};edges={len(graph.edges)}")

    assert new == [], [f.location() for f in new]
    assert graph.cycles() == [], graph.cycles()
    order = graph.topo_order()
    assert order is not None
    assert t_analyze + t_graph < FULL_PASS_BUDGET_S, (
        f"analysis pass took {t_analyze + t_graph:.1f}s"
    )

    # Parse cost alone (project load, no rules) for the breakdown.
    t0 = time.perf_counter()
    fresh_project, _ = load_project(paths)
    t_load = time.perf_counter() - t0
    emit("analysis_parse_only", t_load * 1e6, f"files={n_files}")

    # Typestate interpreter alone, on a fresh (uncached) project.
    t0 = time.perf_counter()
    ts_findings = 0
    for module in fresh_project.modules:
        ts_findings += len(run_typestate(module, fresh_project))
    t_typestate = time.perf_counter() - t0
    emit("analysis_typestate_pass", t_typestate * 1e6,
         f"files={n_files};findings={ts_findings}")

    # Interleaving-explorer smoke: the racy fixture must be caught, the
    # safe one must survive a bounded exhaustive pass.
    t0 = time.perf_counter()
    racy = fuzz(RacySingleFlightModel, seed=3, runs=10)
    t_fuzz = time.perf_counter() - t0
    assert not racy.ok, "explorer missed the seeded race"
    emit("explore_fuzz_racy", t_fuzz * 1e6, f"schedules={racy.schedules}")

    t0 = time.perf_counter()
    safe = explore(SafeSingleFlightModel, preemption_bound=1,
                   max_schedules=60)
    t_explore = time.perf_counter() - t0
    assert safe.ok, safe.describe()
    emit("explore_bounded_safe", t_explore * 1e6,
         f"schedules={safe.schedules}")

    record = {
        "bench": "analysis",
        "smoke": quick,
        "files": n_files,
        "findings": len(findings),
        "new": len(new),
        "lock_nodes": len(graph.nodes),
        "lock_edges": len(graph.edges),
        "typestate_findings": ts_findings,
        "timings_s": {
            "full_pass": t_analyze,
            "lock_graph": t_graph,
            "parse_only": t_load,
            "typestate_pass": t_typestate,
            "explore_fuzz_racy": t_fuzz,
            "explore_bounded_safe": t_explore,
        },
        "explorer": {
            "racy_schedules": racy.schedules,
            "racy_caught": not racy.ok,
            "safe_schedules": safe.schedules,
            "safe_ok": safe.ok,
        },
    }
    with open(out, "w", encoding="utf-8") as f:
        json.dump(record, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="src only (the CI-sized quick pass)")
    ap.add_argument("--out", default="BENCH_analysis.json")
    args = ap.parse_args()
    main(quick=args.smoke, out=args.out)
