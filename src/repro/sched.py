"""Deterministic cooperative scheduler for interleaving exploration.

Real OS threads, exactly one runnable at a time: every task parks on a
private gate and the driver loop wakes exactly one per step, chosen by a
pluggable *picker* over the name-sorted runnable set. Scheduling points
sit where real races live — lock acquire, condition wait, thread
start/join, sleep — so a decision sequence IS an interleaving, and the
same decision sequence replays the same interleaving bit-for-bit.

Code under test is captured the same way the ``traced_locks`` fixture
captures it: the ``threading`` module's ``Lock`` / ``RLock`` /
``Condition`` / ``Thread`` constructor names are swapped while a
scheduler is active (`CoopScheduler.activate`), so anything built during
the window — including ``threading.Event`` and ``queue.Queue``, whose
initialisers resolve those names at call time — becomes cooperative
without touching the code under test. ``time.monotonic`` / ``time.time``
/ ``time.perf_counter`` / ``time.sleep`` are bound to a virtual clock
that only advances when every task is blocked on a deadline, so TTL and
timeout paths run instantly and deterministically.

`repro.analysis.explore` builds seeded schedule fuzzing and
preemption-bounded exhaustive exploration on top of the decision log
recorded here.
"""

from __future__ import annotations

import contextlib
import random
import threading
import time as _time_mod

__all__ = [
    "CoopScheduler",
    "SchedLock",
    "SchedRLock",
    "SchedCondition",
    "SchedThread",
    "SchedulerAbort",
    "DeadlockError",
    "LivelockError",
    "TaskFailed",
    "RandomPicker",
    "ReplayPicker",
    "patch_threading_ctors",
]

# Captured at import, before any patching can happen (conftest imports
# this module at collection time for the same reason).
_RealLock = threading.Lock
_RealRLock = threading.RLock
_RealCondition = threading.Condition
_RealThread = threading.Thread

_REAL_TIME = ("monotonic", "time", "perf_counter", "sleep")

#: Real-seconds ceiling on any single driver<->task handshake. A healthy
#: handshake is microseconds; hitting this means a task escaped the
#: cooperative discipline (e.g. blocked on an unpatched primitive).
_HANDSHAKE_TIMEOUT_S = 30.0

#: Owner token for primitives used from the driver thread (model
#: ``setup()``/``check()`` run outside any task).
_DRIVER = object()


class SchedulerAbort(BaseException):
    """Raised inside task threads at scheduling points during teardown.

    BaseException so user-level ``except Exception`` cleanup cannot
    swallow it; the task bootstrap catches it and exits the thread.
    """


class DeadlockError(RuntimeError):
    """Every non-daemon task is blocked with no deadline to advance to."""


class LivelockError(RuntimeError):
    """The schedule exceeded ``max_steps`` without completing."""


class TaskFailed(RuntimeError):
    """A task died on an uncaught exception; the schedule is aborted."""

    def __init__(self, name: str, exc: BaseException) -> None:
        super().__init__(f"task {name!r} died: {exc!r}")
        self.task_name = name
        self.exc = exc


def patch_threading_ctors(lock=None, rlock=None, condition=None, thread=None):
    """Swap the ``threading`` module's constructor names; returns a
    restore callable. Shared by `CoopScheduler.activate` and the test
    suite's ``traced_locks`` fixture — one mechanism, two instruments."""
    saved = (threading.Lock, threading.RLock, threading.Condition,
             threading.Thread)
    if lock is not None:
        threading.Lock = lock
    if rlock is not None:
        threading.RLock = rlock
    if condition is not None:
        threading.Condition = condition
    if thread is not None:
        threading.Thread = thread

    def restore() -> None:
        (threading.Lock, threading.RLock, threading.Condition,
         threading.Thread) = saved

    return restore


@contextlib.contextmanager
def _ctors_unpatched():
    """Temporarily restore the real constructors. Used while creating
    the real OS thread behind a task: ``Thread.__init__`` builds its
    internal events from the (patched) threading-module globals."""
    saved = (threading.Lock, threading.RLock, threading.Condition,
             threading.Thread)
    (threading.Lock, threading.RLock, threading.Condition,
     threading.Thread) = (_RealLock, _RealRLock, _RealCondition, _RealThread)
    try:
        yield
    finally:
        (threading.Lock, threading.RLock, threading.Condition,
         threading.Thread) = saved


class _Gate:
    """A real event immune to constructor patching (a ``threading.Event``
    created during an active patch would itself become cooperative)."""

    __slots__ = ("_cond", "_flag")

    def __init__(self) -> None:
        self._cond = _RealCondition(_RealLock())
        self._flag = False

    def set(self) -> None:
        with self._cond:
            self._flag = True
            self._cond.notify_all()

    def clear(self) -> None:
        with self._cond:
            self._flag = False

    def wait(self, timeout: float | None) -> bool:
        with self._cond:
            self._cond.wait_for(lambda: self._flag, timeout)
            return self._flag


class _Task:
    __slots__ = ("name", "daemon", "thread", "gate", "state", "reason",
                 "deadline", "timed_out", "exc", "joiners")

    def __init__(self, name: str, daemon: bool) -> None:
        self.name = name
        self.daemon = daemon
        self.thread: threading.Thread | None = None
        self.gate = _Gate()
        self.state = "ready"            # ready | blocked | done
        self.reason = ""
        self.deadline: float | None = None
        self.timed_out = False
        self.exc: BaseException | None = None
        self.joiners: list[_Task] = []


# The active scheduler; SchedThread construction resolves through this.
_ACTIVE: CoopScheduler | None = None


class RandomPicker:
    """Seeded uniform choice over the runnable set — schedule fuzzing."""

    def __init__(self, seed) -> None:
        self._rng = random.Random(seed)

    def __call__(self, names: tuple[str, ...], cur: int | None) -> int:
        return self._rng.randrange(len(names))


class ReplayPicker:
    """Follow a decision prefix, then run nonpreemptively (stay with the
    current task while it is runnable). An empty prefix is the baseline
    schedule; `repro.analysis.explore` branches prefixes off it."""

    def __init__(self, prefix=()) -> None:
        self.prefix = tuple(prefix)
        self._i = 0

    def __call__(self, names: tuple[str, ...], cur: int | None) -> int:
        i = self._i
        self._i += 1
        if i < len(self.prefix):
            return min(self.prefix[i], len(names) - 1)
        return cur if cur is not None else 0


class CoopScheduler:
    """Drives a set of tasks through one deterministic interleaving.

    Usage::

        sched = CoopScheduler(ReplayPicker(()))
        with sched.activate():
            ... build objects (their locks become cooperative) ...
            sched.spawn(body_a, name="a")
            sched.spawn(body_b, name="b")
            sched.run()
            ... assert on final state ...

    `run` returns when every non-daemon task finished; daemon tasks
    still parked (an upload pool's idle workers) are aborted on exit
    from the ``activate`` block. The schedule's decision log is in
    ``decisions`` / ``points`` and the human-readable step log in
    ``trace`` — both are pure functions of (model, picker).
    """

    def __init__(self, picker=None, *, max_steps: int = 20000) -> None:
        self.picker = picker if picker is not None else ReplayPicker(())
        self.max_steps = max_steps
        self.now = 0.0
        self.trace: list[str] = []
        #: one entry per decision: (runnable names, chosen idx, idx of the
        #: previously-running task if still runnable else None).
        self.points: list[tuple[tuple[str, ...], int, int | None]] = []
        self.decisions: list[int] = []
        self._tasks: dict[str, _Task] = {}
        self._order: list[_Task] = []
        self._by_ident: dict[int, _Task] = {}
        self._wake = _Gate()
        self._current: _Task | None = None
        self._aborting = False

    # -- patching -----------------------------------------------------------
    @contextlib.contextmanager
    def activate(self):
        """Install the cooperative primitives and the virtual clock for
        the duration of the block; tears the schedule down on exit."""
        global _ACTIVE
        if _ACTIVE is not None:
            raise RuntimeError("another CoopScheduler is already active")
        _ACTIVE = self
        sched = self
        restore_ctors = patch_threading_ctors(
            lock=lambda: SchedLock(sched),
            rlock=lambda: SchedRLock(sched),
            condition=lambda lock=None: SchedCondition(sched, lock),
            thread=SchedThread,
        )
        saved_time = {k: getattr(_time_mod, k) for k in _REAL_TIME}
        _time_mod.monotonic = lambda: sched.now
        _time_mod.time = lambda: sched.now
        _time_mod.perf_counter = lambda: sched.now
        _time_mod.sleep = sched.sleep
        try:
            yield self
        finally:
            # Teardown runs with the patches still active: aborted tasks
            # unwind through user ``finally`` blocks that touch the
            # cooperative primitives (which no-op while aborting).
            self.shutdown()
            for k, v in saved_time.items():
                setattr(_time_mod, k, v)
            restore_ctors()
            _ACTIVE = None

    # -- task management ----------------------------------------------------
    def spawn(self, fn, name: str | None = None, daemon: bool = False) -> _Task:
        base = name or f"task-{len(self._order)}"
        name, i = base, 1
        while name in self._tasks:
            name = f"{base}-{i}"
            i += 1
        task = _Task(name, daemon)
        self._tasks[name] = task
        self._order.append(task)

        def bootstrap() -> None:
            self._by_ident[threading.get_ident()] = task
            task.gate.wait(None)
            task.gate.clear()
            if not self._aborting:
                try:
                    fn()
                except SchedulerAbort:
                    pass
                except BaseException as e:  # repro: allow[RP005] — harness boundary: every task exception is rethrown by run() as TaskFailed
                    task.exc = e
            task.state = "done"
            for j in task.joiners:
                self._make_ready(j)
            task.joiners.clear()
            self._wake.set()

        with _ctors_unpatched():
            t = _RealThread(target=bootstrap, name=name, daemon=True)
            task.thread = t
            t.start()
        return task

    def current_task(self) -> _Task | None:
        return self._by_ident.get(threading.get_ident())

    # -- driver loop --------------------------------------------------------
    def run(self) -> None:
        steps = 0
        while True:
            failed = next((t for t in self._order if t.exc is not None), None)
            if failed is not None:
                exc, failed.exc = failed.exc, None
                self._abort_tasks()
                raise TaskFailed(failed.name, exc) from exc
            live = [t for t in self._order if t.state != "done"]
            if not any(not t.daemon for t in live):
                return                      # program exit: daemons die with it
            runnable = sorted((t for t in live if t.state == "ready"),
                              key=lambda t: t.name)
            if not runnable:
                timed = [t for t in live if t.deadline is not None]
                if not timed:
                    blocked = ", ".join(
                        f"{t.name}({t.reason})" for t in live if not t.daemon)
                    self._abort_tasks()
                    raise DeadlockError(f"all tasks blocked: {blocked}")
                target = min(t.deadline for t in timed)
                if target > self.now:
                    self.now = target
                    self.trace.append(f"clock {self.now:.6f}")
                for t in timed:
                    if t.deadline is not None and t.deadline <= self.now:
                        t.deadline = None
                        t.timed_out = True
                        t.state = "ready"
                        t.reason = ""
                continue
            steps += 1
            if steps > self.max_steps:
                self._abort_tasks()
                raise LivelockError(
                    f"schedule exceeded {self.max_steps} steps")
            names = tuple(t.name for t in runnable)
            cur = (runnable.index(self._current)
                   if self._current in runnable else None)
            chosen = self.picker(names, cur)
            chosen = max(0, min(int(chosen), len(runnable) - 1))
            self.points.append((names, chosen, cur))
            self.decisions.append(chosen)
            task = runnable[chosen]
            self.trace.append(f"run {task.name}")
            self._resume(task)

    def _resume(self, task: _Task) -> None:
        self._current = task
        self._wake.clear()
        task.gate.set()
        if not self._wake.wait(_HANDSHAKE_TIMEOUT_S):
            self._abort_tasks()
            raise RuntimeError(
                f"task {task.name} never handed control back "
                f"(blocked on an unpatched primitive?)")

    # -- task-side switch points -------------------------------------------
    def _switch_out(self, task: _Task) -> None:
        self._wake.set()
        task.gate.wait(None)
        task.gate.clear()
        if self._aborting:
            raise SchedulerAbort()

    def yield_point(self, reason: str) -> None:
        """A scheduling point: the running task offers the driver a
        chance to preempt it. No-op outside a task (driver context)."""
        task = self.current_task()
        if task is None:
            return
        if self._aborting:
            raise SchedulerAbort()
        task.state = "ready"
        task.reason = reason
        self.trace.append(f"{task.name} {reason}")
        self._switch_out(task)

    def block(self, reason: str, deadline: float | None = None) -> bool:
        """Park the calling task until `_make_ready` or the virtual
        clock reaches `deadline`. Returns True when woken by deadline.

        From driver context a bounded wait just advances the clock (the
        run is over, nobody will notify); an unbounded one is a
        programming error in the model's ``check()``."""
        task = self.current_task()
        if task is None:
            if deadline is not None:
                if deadline > self.now:
                    self.now = deadline
                return True
            raise DeadlockError(f"driver would block forever on {reason}")
        if self._aborting:
            raise SchedulerAbort()
        task.state = "blocked"
        task.reason = reason
        task.deadline = deadline
        task.timed_out = False
        self.trace.append(f"{task.name} blocked {reason}")
        self._switch_out(task)
        return task.timed_out

    def _make_ready(self, task: _Task) -> None:
        if task.state == "blocked":
            task.state = "ready"
            task.deadline = None
            task.timed_out = False
            task.reason = ""

    def sleep(self, seconds: float) -> None:
        if seconds is not None and seconds > 0:
            self.block(f"sleep {seconds:g}", self.now + seconds)
        else:
            self.yield_point("sleep 0")

    # -- teardown -----------------------------------------------------------
    def _abort_tasks(self) -> None:
        self._aborting = True
        for t in self._order:
            if t.state != "done":
                t.gate.set()

    def shutdown(self) -> None:
        self._abort_tasks()
        for t in self._order:
            if t.thread is not None:
                t.thread.join(timeout=_HANDSHAKE_TIMEOUT_S)


# ---------------------------------------------------------------------------
# Cooperative primitives. While the scheduler is aborting, every
# operation degrades to a benign no-op success so unwinding user
# ``finally`` blocks cannot wedge the teardown.
# ---------------------------------------------------------------------------

class SchedLock:
    """Cooperative ``threading.Lock`` stand-in."""

    def __init__(self, sched: CoopScheduler) -> None:
        self._sched = sched
        self._owner = None
        self._waiters: list[_Task] = []

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        sched = self._sched
        if sched._aborting:
            return True
        task = sched.current_task()
        if task is None:
            if self._owner is None:
                self._owner = _DRIVER
                return True
            raise DeadlockError("driver blocked on a lock held by a task")
        sched.yield_point("lock.acquire")
        if timeout is not None and timeout < 0:
            timeout = None
        deadline = None if timeout is None else sched.now + timeout
        while self._owner is not None:
            if not blocking:
                return False
            if deadline is not None and sched.now >= deadline:
                return False
            self._waiters.append(task)
            try:
                timed_out = sched.block("lock.wait", deadline)
            finally:
                try:
                    self._waiters.remove(task)
                except ValueError:
                    pass
            if timed_out and self._owner is not None:
                return False
        self._owner = task
        return True

    def release(self) -> None:
        sched = self._sched
        if sched._aborting:
            return
        if self._owner is None:
            raise RuntimeError("release unlocked lock")
        self._owner = None
        for w in list(self._waiters):
            sched._make_ready(w)

    def locked(self) -> bool:
        return self._owner is not None

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    # Condition-protocol hooks (mirror threading.Lock's use).
    def _release_save(self):
        self.release()
        return None

    def _acquire_restore(self, state) -> None:
        self.acquire()

    def _is_owned(self) -> bool:
        me = self._sched.current_task() or _DRIVER
        return self._owner is me


class SchedRLock:
    """Cooperative ``threading.RLock`` stand-in."""

    def __init__(self, sched: CoopScheduler) -> None:
        self._sched = sched
        self._owner = None
        self._count = 0
        self._waiters: list[_Task] = []

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        sched = self._sched
        if sched._aborting:
            return True
        me = sched.current_task() or _DRIVER
        if self._owner is me:
            self._count += 1
            return True
        if me is _DRIVER:
            if self._owner is None:
                self._owner, self._count = me, 1
                return True
            raise DeadlockError("driver blocked on an rlock held by a task")
        sched.yield_point("rlock.acquire")
        if timeout is not None and timeout < 0:
            timeout = None
        deadline = None if timeout is None else sched.now + timeout
        while self._owner is not None:
            if not blocking:
                return False
            if deadline is not None and sched.now >= deadline:
                return False
            self._waiters.append(me)
            try:
                timed_out = sched.block("rlock.wait", deadline)
            finally:
                try:
                    self._waiters.remove(me)
                except ValueError:
                    pass
            if timed_out and self._owner is not None:
                return False
        self._owner, self._count = me, 1
        return True

    def release(self) -> None:
        sched = self._sched
        if sched._aborting:
            return
        if self._count <= 0:
            raise RuntimeError("release unlocked rlock")
        self._count -= 1
        if self._count == 0:
            self._owner = None
            for w in list(self._waiters):
                sched._make_ready(w)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def _release_save(self):
        state = (self._count, self._owner)
        self._count = 0
        self._owner = None
        for w in list(self._waiters):
            self._sched._make_ready(w)
        return state

    def _acquire_restore(self, state) -> None:
        self.acquire()
        self._count = state[0]

    def _is_owned(self) -> bool:
        me = self._sched.current_task() or _DRIVER
        return self._owner is me


class SchedCondition:
    """Cooperative ``threading.Condition`` stand-in. `notify` removes
    the woken waiters from the queue (like the real one), so successive
    single notifies wake distinct waiters."""

    def __init__(self, sched: CoopScheduler, lock=None) -> None:
        self._sched = sched
        self._lock = lock if lock is not None else SchedRLock(sched)
        self._waiters: list[_Task] = []

    def acquire(self, *args, **kwargs) -> bool:
        return self._lock.acquire(*args, **kwargs)

    def release(self) -> None:
        self._lock.release()

    def __enter__(self):
        self._lock.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self._lock.release()

    def _is_owned(self) -> bool:
        return self._lock._is_owned()

    def wait(self, timeout: float | None = None) -> bool:
        sched = self._sched
        if sched._aborting:
            raise SchedulerAbort()
        task = sched.current_task()
        if task is None:
            if timeout is not None:
                sched.block("cond.wait", sched.now + timeout)
                return False
            raise DeadlockError("driver cond.wait() with no timeout")
        deadline = None if timeout is None else sched.now + timeout
        saved = self._lock._release_save()
        self._waiters.append(task)
        try:
            timed_out = sched.block("cond.wait", deadline)
        finally:
            try:
                self._waiters.remove(task)
            except ValueError:
                pass
            self._lock._acquire_restore(saved)
        return not timed_out

    def wait_for(self, predicate, timeout: float | None = None):
        sched = self._sched
        deadline = None if timeout is None else sched.now + timeout
        result = predicate()
        while not result:
            remaining = None
            if deadline is not None:
                remaining = deadline - sched.now
                if remaining <= 0:
                    break
            self.wait(remaining)
            result = predicate()
        return result

    def notify(self, n: int = 1) -> None:
        if self._sched._aborting:
            return
        woken = self._waiters[:n]
        del self._waiters[:len(woken)]
        for w in woken:
            self._sched._make_ready(w)

    def notify_all(self) -> None:
        self.notify(len(self._waiters))


class SchedThread:
    """``threading.Thread`` stand-in under an active CoopScheduler.
    Covers the subset the codebase uses: target/name/daemon ctor,
    `start`, `join(timeout)`, `is_alive`, `name`."""

    def __init__(self, group=None, target=None, name=None, args=(),
                 kwargs=None, *, daemon=None) -> None:
        sched = _ACTIVE
        if sched is None:
            raise RuntimeError("SchedThread outside an active CoopScheduler")
        self._sched = sched
        self._target = target
        self._args = tuple(args)
        self._kwargs = dict(kwargs or {})
        self.name = name or f"SchedThread-{len(sched._order)}"
        self.daemon = bool(daemon) if daemon is not None else False
        self._task: _Task | None = None

    def run(self) -> None:
        if self._target is not None:
            self._target(*self._args, **self._kwargs)

    def start(self) -> None:
        if self._task is not None:
            raise RuntimeError("threads can only be started once")
        self._task = self._sched.spawn(self.run, name=self.name,
                                       daemon=self.daemon)
        self.name = self._task.name
        self._sched.yield_point("thread.start")

    def join(self, timeout: float | None = None) -> None:
        sched = self._sched
        task = self._task
        if task is None:
            raise RuntimeError("cannot join thread before it is started")
        cur = sched.current_task()
        if cur is task:
            raise RuntimeError("cannot join current thread")
        deadline = None if timeout is None else sched.now + timeout
        while task.state != "done":
            if sched._aborting:
                raise SchedulerAbort()
            if deadline is not None and sched.now >= deadline:
                return
            if cur is None:
                if deadline is None:
                    raise DeadlockError(
                        f"driver join() on live task {task.name}")
                sched.block(f"join {task.name}", deadline)
                continue
            task.joiners.append(cur)
            try:
                sched.block(f"join {task.name}", deadline)
            finally:
                try:
                    task.joiners.remove(cur)
                except ValueError:
                    pass

    def is_alive(self) -> bool:
        return self._task is not None and self._task.state != "done"
