"""Rolling Prefetch — the paper's core contribution, with an adaptive
event-driven scheduler.

Three concurrent actors over a block plan (paper §II-A):

  * the READING thread (the caller of :meth:`RollingPrefetchFile.read`)
    serves bytes from cached blocks, blocking until the needed block has
    been prefetched, and flags fully-consumed blocks for eviction;
  * the PREFETCHING stream(s) claim *runs* of adjacent blocks inside a
    readahead horizon ahead of the reader, write them into the first
    priority-ordered cache tier with available budget (Algorithm 1:
    optimistic `used` accounting + `verify_used` reconciliation when a
    tier looks full), and park on a condition when no work is eligible —
    evictions and reader progress notify them, with a coarse wait timeout
    only as a missed-wakeup backstop;
  * the EVICTION thread deletes flagged blocks when notified (a consumed
    block pushed a tier past its high-water mark, or a prefetcher found
    every tier full), with the periodic interval only as a fallback, and
    performs a final sweep on shutdown.

Adaptive scheduling (all off by default so the faithful configuration is
the baseline):

  * ``coalesce > 1``: runs of adjacent blocks are fetched with ONE
    vectorized ``store.get_ranges`` request — one request latency for the
    whole run — when the cost model says the link is latency-bound
    (Eq. 1's ``n_b·l_c`` term dominates); results split back into
    per-block cache entries so eviction granularity is unchanged;
  * ``readahead_blocks``: bounds the fetch window to a horizon ahead of
    the reader position instead of racing to end-of-plan;
  * ``max_depth``: an AIMD controller grows concurrent fetch streams
    while observed fetch throughput holds and halves them when it
    regresses;
  * ``tuner``: a `BlockSizeTuner` fed per-request timings and reader
    compute gaps, closing the Eq.-4 loop (the `PrefetchFS` facade retunes
    blocksize/coalesce from it on the next open);
  * ``depth > 1``, ``hedge_timeout``, transient-failure retries: S3
    scales with request concurrency; thousand-node jobs need straggler +
    fault tolerance. All retrying and hedging resolves through the
    unified resilience layer (`repro.io.retry`): one `Retrier` with
    full-jitter backoff and one capped `Hedger` per prefetcher, shared
    by every stream and the reader's direct-GET fallbacks. A
    `ThrottleError` (503 SlowDown) additionally halves the AIMD stream
    target, so backend pushback shrinks prefetch concurrency instead of
    just rescheduling the same herd.
"""

from __future__ import annotations

import enum
import threading
import time
import warnings
from dataclasses import dataclass, field

from repro.core.autotune import AimdDepthController, BlockSizeTuner
from repro.core.plan import Block, BlockPlan
from repro.io.integrity import check_block
from repro.io.retry import Hedger, Retrier, RetryPolicy
from repro.store.base import (
    IntegrityError,
    ObjectMeta,
    ObjectStore,
    StoreError,
    TransientStoreError,
)
from repro.store.tiers import BlockMeta, CacheFlight, CacheIndex, CacheTier
from repro.utils import get_logger

log = get_logger("core.rolling")


class BlockState(enum.Enum):
    UNFETCHED = 0
    FETCHING = 1
    CACHED = 2
    CONSUMED = 3   # fully read; flagged for eviction
    EVICTED = 4
    FAILED = 5


@dataclass
class _BlockInfo:
    state: BlockState = BlockState.UNFETCHED
    tier: CacheTier | None = None
    error: Exception | None = None
    # The reader gave up waiting (READ_PATIENCE_S) and read this block
    # directly from the store: when the scheduled fetch finally lands, it
    # arrives pre-consumed so its pin is released instead of sitting
    # CACHED forever for a reader that already moved past it.
    abandoned: bool = False


@dataclass
class PrefetchStats:
    """Counters mutated from the reader, prefetch (possibly several when
    depth > 1), and eviction threads; all mutation goes through
    :meth:`bump` / :meth:`note_depth`, which serialize on an internal
    lock, and :meth:`snapshot` reads under the same lock for a consistent
    view."""

    blocks_fetched: int = 0
    blocks_evicted: int = 0
    bytes_fetched: int = 0
    bytes_read: int = 0
    reader_wait_s: float = 0.0
    fetch_s: float = 0.0        # cumulative time in store fetch + tier.write
    retries: int = 0
    throttles: int = 0          # ThrottleError responses (503 SlowDown)
    hedges: int = 0
    direct_reads: int = 0       # cache-miss fallbacks (backward seeks)
    cache_hits: int = 0         # blocks served from the shared index, no GET
    flight_joins: int = 0       # blocks obtained by joining another reader's GET
    store_requests: int = 0     # GETs issued (== blocks_fetched unless coalesced)
    coalesced_requests: int = 0  # GETs that carried more than one block
    coalesced_blocks: int = 0    # blocks delivered by coalesced GETs
    depth_peak: int = 0          # highest concurrent-stream target reached
    blocks_verified: int = 0     # digest checks that passed
    integrity_failures: int = 0  # digest mismatches detected (then healed)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def bump(self, **deltas: int | float) -> None:
        with self._lock:
            for name, delta in deltas.items():
                setattr(self, name, getattr(self, name) + delta)

    def note_depth(self, target: int) -> None:
        with self._lock:
            self.depth_peak = max(self.depth_peak, target)

    def snapshot(self) -> dict:
        with self._lock:
            return {k: v for k, v in self.__dict__.items()
                    if not k.startswith("_")}


class RollingPrefetcher:
    """Shared engine: block plan + tiered cache + the scheduler threads."""

    # Upper bound on how long the READER waits for a block the scheduler
    # has not delivered before degrading to a direct store read. Normal
    # waits are milliseconds; this only fires when the shared-cache
    # machinery is wedged (e.g. another reader's pinned readahead holds
    # every tier byte while that reader waits on our leader — a cycle no
    # eviction can break). A direct GET restores progress for everyone:
    # this reader consumes on, its pins release, the parked leader gets
    # space. The paper's worst-case contract (degrade to sequential
    # performance, never hang) is preserved.
    READ_PATIENCE_S = 30.0

    def __init__(
        self,
        store: ObjectStore,
        files: list[ObjectMeta],
        tiers: list[CacheTier],
        blocksize: int,
        *,
        depth: int = 1,
        max_depth: int | None = None,
        coalesce: int = 1,
        readahead_blocks: int | None = None,
        eviction_interval_s: float = 5.0,
        high_water: float = 0.75,
        max_retries: int = 3,
        retry_backoff_s: float = 0.05,
        retry: RetryPolicy | None = None,
        hedge_timeout_s: float | None = None,
        max_hedges: int = 4,
        throttle_aimd: bool = True,
        tuner: BlockSizeTuner | None = None,
        index: CacheIndex | None = None,
        io_class: str = "default",
        verify: str = "edges",
    ) -> None:
        if not tiers:
            raise ValueError("at least one cache tier is required")
        if verify not in ("off", "edges", "full"):
            raise ValueError(
                f"verify must be 'off', 'edges', or 'full', got {verify!r}"
            )
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if max_depth is not None and max_depth < depth:
            raise ValueError(
                f"max_depth ({max_depth}) must be >= depth ({depth})"
            )
        if coalesce < 1:
            raise ValueError(f"coalesce must be >= 1, got {coalesce}")
        if readahead_blocks is not None and readahead_blocks < 1:
            raise ValueError(
                f"readahead_blocks must be >= 1, got {readahead_blocks}"
            )
        self.store = store
        self.plan = BlockPlan(files, blocksize)
        self.tiers = tiers
        self.depth = depth
        self.coalesce = coalesce
        self.readahead_blocks = readahead_blocks
        self.eviction_interval_s = eviction_interval_s
        self.high_water = high_water
        self.hedge_timeout_s = hedge_timeout_s
        self.tuner = tuner
        # Unified resilience layer: ONE Retrier (shared jitter rng and
        # retry budget across all prefetch streams + the reader's direct
        # GETs) and ONE Hedger (the max-hedges-in-flight cap bounds
        # duplicates across concurrent streams). ThrottleError responses
        # reach `_on_throttle`, which shrinks the AIMD stream target —
        # backend pushback lowers prefetch concurrency, not just this
        # request's schedule.
        self.retry = (retry if retry is not None else RetryPolicy(
            max_retries=max_retries, backoff_s=retry_backoff_s))
        self.throttle_aimd = throttle_aimd
        # Shared cache index: residency + refcounts + single-flight fetch
        # registration. When the caller (PrefetchFS) supplies one, every
        # reader over these tiers shares it — N readers of the same key
        # issue ~1x store GETs, and a block pinned by any reader is never
        # evicted from under another. A private index (one reader) behaves
        # exactly like the paper's per-reader cache, except that a
        # persistent DirTier still primes it warm after a restart.
        self.index = index if index is not None else CacheIndex(tiers)
        # Workload class stamped on every acquire/reserve: the HSM index
        # keys admission (entry tier, protection, scan resistance) and
        # per-class hit accounting off it; a flat index ignores it.
        self.io_class = io_class
        # End-to-end integrity posture: "off" never hashes, "edges" mints
        # a digest at the store fetch and re-checks at tier boundaries
        # (trusting self-verifying tiers), "full" re-checks every cached
        # read. See `repro.io.integrity`.
        self.verify = verify
        self.stats = PrefetchStats()
        self._aimd = (
            AimdDepthController(depth, max_depth)
            if max_depth is not None else None
        )
        self._retrier = Retrier(
            self.retry,
            on_retry=lambda attempt, exc, pause: self.stats.bump(retries=1),
            on_throttle=self._on_throttle,
        )
        self._hedger = Hedger(
            hedge_timeout_s,
            max_in_flight=max_hedges,
            on_hedge=lambda: self.stats.bump(hedges=1),
        )
        self._streams = max_depth if max_depth is not None else depth
        self._spawned = 0             # streams actually started (lazy)

        self._info: list[_BlockInfo] = [_BlockInfo() for _ in self.plan.blocks]
        self._cond = threading.Condition()
        self._next_block = 0          # lowest block index not yet claimed
        self._reader_block = 0        # reader position, in block indexes
        self._target_depth = depth    # streams allowed to fetch right now
        self._probe_width = 0         # width alternator while tuner is cold
        self._fetch = True            # the paper's shared `fetch` flag
        self._threads: list[threading.Thread] = []
        self._started = False
        self._closed = False
        # Eviction wakeup channel: consumed-past-high-water and
        # tiers-all-full both notify here instead of waiting out the
        # periodic interval (which remains only as a fallback).
        self._evict_cond = threading.Condition()
        self._evict_wanted = False
        # Reader-side buffer of the current block: the application issues
        # many small reads (3 per streamline in the paper's Nibabel trace);
        # local storage is read once per block, small reads are served from
        # this buffer without touching locks or the tier.
        self._buf_index: int | None = None
        self._buf_data: bytes = b""
        # Compute-gap observation state (closed-loop autotune): wall time
        # between read_range calls is pure application compute.
        self._last_read_t: float | None = None
        self._last_read_bytes = 0

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def start(self) -> None:
        if self._closed:
            # close() cleared the fetch flag and block/tier state; worker
            # threads spawned now would exit immediately and the old ones
            # would be double-joined — refuse loudly instead.
            raise RuntimeError(
                "RollingPrefetcher cannot restart after close(); "
                "open a new reader instead"
            )
        if self._started:
            return
        self._started = True
        # Streams spawn lazily: `depth` now, more only if the AIMD target
        # actually grows — max_depth=64 must not cost 64 idle threads.
        self._spawn_streams(self._target_depth)
        t = threading.Thread(target=self._evict_loop, name="rp-evict", daemon=True)
        t.start()
        self._threads.append(t)

    def _spawn_streams(self, target: int) -> None:
        """Bring the number of spawned streams up to `min(target, ceiling)`.
        Workers above the current AIMD target park on `_cond`, so streams
        never need un-spawning when the target shrinks."""
        while True:
            with self._cond:
                if self._closed or not self._started:
                    return
                if self._spawned >= min(target, self._streams):
                    return
                i = self._spawned
                self._spawned += 1
            t = threading.Thread(
                target=self._prefetch_loop, args=(i,),
                name=f"rp-prefetch-{i}", daemon=True,
            )
            t.start()
            self._threads.append(t)

    def close(self) -> None:
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._fetch = False
            self._cond.notify_all()
        with self._evict_cond:
            self._evict_cond.notify_all()
        for t in self._threads:
            t.join(timeout=30.0)
        self._threads = []
        self._final_sweep()

    def __enter__(self) -> "RollingPrefetcher":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def target_depth(self) -> int:
        """Current AIMD stream target (== `depth` when adaptation is off)."""
        with self._cond:
            return self._target_depth

    # ------------------------------------------------------------------ #
    # prefetching streams (Algorithm 1 + adaptive scheduler)
    # ------------------------------------------------------------------ #
    def _effective_coalesce(self) -> int:
        """Blocks per request for the next claim. Caller holds `_cond`."""
        if self.coalesce <= 1:
            return 1
        if self.tuner is None:
            return self.coalesce
        if self.tuner.latency_s is None:
            # Cold tuner: alternate 1- and 2-block requests so sizes vary
            # and the request-timing fit can split latency from bandwidth.
            self._probe_width += 1
            return 1 + (self._probe_width % 2)
        return self.tuner.suggest_coalesce(self.plan.blocksize, self.coalesce)

    def _claim_run(self, worker_id: int) -> list[Block] | None:
        """Claim the next run of adjacent unfetched blocks inside the
        readahead horizon; parks (condition wait) while this stream is
        over the AIMD target or the horizon is exhausted."""
        with self._cond:
            while True:
                if not self._fetch:
                    return None
                if worker_id >= self._target_depth:
                    # Parked by the depth controller; woken when the
                    # target grows (or on close).
                    self._cond.wait(timeout=0.5)
                    continue
                while (self._next_block < len(self.plan)
                       and self._info[self._next_block].state
                       != BlockState.UNFETCHED):
                    self._next_block += 1
                if self._next_block >= len(self.plan):
                    return None  # plan fully claimed -> stream terminates
                idx = self._next_block
                limit = None
                if self.readahead_blocks is not None:
                    limit = self._reader_block + self.readahead_blocks
                    if idx >= limit:
                        # Horizon exhausted; reader progress notifies.
                        self._cond.wait(timeout=0.5)
                        continue
                run: list[Block] = []
                for b in self.plan.run_from(idx, self._effective_coalesce(),
                                            limit):
                    if self._info[b.index].state != BlockState.UNFETCHED:
                        break
                    self._info[b.index].state = BlockState.FETCHING
                    run.append(b)
                self._next_block = run[-1].index + 1
                return run

    def _unclaim(self, blocks: list[Block]) -> None:
        """Return claimed blocks to the pool. Caller holds `_cond`."""
        for b in blocks:
            self._info[b.index].state = BlockState.UNFETCHED
        if blocks:
            self._next_block = min(self._next_block, blocks[0].index)

    def _prefetch_loop(self, worker_id: int) -> None:
        while True:
            run = self._claim_run(worker_id)
            if run is None:
                return
            if not self._place_run(run):
                return

    def _place_run(self, run: list[Block]) -> bool:
        """Resolve each claimed block against the shared cache index:
        blocks already resident (another reader, a previous epoch, or a
        recovered persistent tier) are pinned without a store request,
        blocks another reader is fetching right now are joined, and only
        blocks this stream leads are fetched — contiguous leader groups
        still go out as ONE coalesced request. Returns False when this
        stream should exit."""
        group: list[tuple[Block, CacheFlight]] = []
        for pos, b in enumerate(run):
            # repro: allow[RP009] — the only call between acquire and
            # discharge is _flush_group, which handles every fetch error
            # internally (leak-free by construction, see _fail_group).
            kind, val = self.index.acquire(b.block_id, self.io_class)
            if kind == "leader":
                group.append((b, val))
                continue
            if not self._flush_group(group):
                self._fail_rest(run[pos:], skip_acquired=(b, kind, val))
                return False
            group = []
            if kind == "hit":
                self.stats.bump(cache_hits=1)
                self._mark_cached(b, val)
            elif not self._join_flight(b, val):
                self._fail_rest(run[pos + 1:])
                return False
        return self._flush_group(group)

    def _fail_rest(self, rest: list[Block], skip_acquired=None) -> None:
        """A group failed permanently mid-run: the remaining claimed
        blocks can never be fetched by this stream — mark them FAILED so
        the reader raises instead of waiting forever (matching the old
        whole-run-FAILED semantics). On shutdown they are unclaimed
        instead. Pins/flights already acquired for them are released."""
        with self._cond:
            closing = not self._fetch
            err: Exception | None = None
            unclaim: list[Block] = []
            for b in rest:
                info = self._info[b.index]
                if info.state != BlockState.FETCHING:
                    continue
                if skip_acquired is not None and skip_acquired[0] is b:
                    _, kind, val = skip_acquired
                    if kind == "hit":
                        # repro: allow[RP002] — index calls are engine-lock-
                        # safe (tiers.py contract); at worst a local unlink.
                        self.index.unpin(b.block_id)
                    elif kind == "wait":
                        # repro: allow[RP002] — same contract as above.
                        self.index.leave(val)
                if closing:
                    unclaim.append(b)
                    continue
                if err is None:
                    err = StoreError("prefetch stream failed upstream")
                info.state = BlockState.FAILED
                info.error = err
            if unclaim:
                self._unclaim(unclaim)
            self._cond.notify_all()

    def _flush_group(self, group: list[tuple[Block, CacheFlight]]) -> bool:
        """Reserve tier space for a contiguous group of leader blocks and
        fetch it as one request; shrinks to the head block when only one
        fits, parks (eviction-notified) when every tier is full. Returns
        False when this stream should exit."""
        if not group:
            return True
        while True:
            with self._cond:
                if not self._fetch:
                    for b, fl in group:
                        self.index.abort_fetch(fl)
                    self._unclaim([b for b, _ in group])
                    return False
            total = sum(b.size for b, _ in group)
            try:
                tier = self._reserve(total)
            except Exception as e:  # repro: allow[RP005] — flights MUST abort:
                # _reserve runs eviction I/O (tier deletes); if that
                # blows up with the group's flights registered, every
                # waiter parks until the TTL. Fail the group leak-free.
                self._fail_group(group, e)
                return False
            if tier is None and len(group) > 1:
                # The full group doesn't fit anywhere — give back the tail
                # and try the head block alone before parking.
                with self._cond:
                    for b, fl in group[1:]:
                        self.index.abort_fetch(fl)
                    self._unclaim([b for b, _ in group[1:]])
                    self._cond.notify_all()
                group = group[:1]
                continue
            if tier is None:
                # Every tier full: demand eviction, then park until the
                # evictor (or close) notifies.
                self._request_eviction()
                with self._cond:
                    if self._fetch:
                        self._cond.wait(timeout=0.5)
                continue
            try:
                self._fetch_group(group, tier)
                return True
            except Exception as e:  # repro: allow[RP005] — flights MUST abort:
                # a leaked flight would park every waiter (other readers
                # included) until their patience fallback, and this
                # reader's blocks would stay FETCHING forever.
                tier.cancel(total)
                self._fail_group(group, e)
                return False

    def _fail_group(self, group: list[tuple[Block, CacheFlight]],
                    e: Exception) -> None:
        """Abort every flight in `group` and mark its blocks FAILED —
        the one leak-free way out of a group that cannot be fetched."""
        err = e if isinstance(e, StoreError) else StoreError(
            f"fetch failed for blocks "
            f"{group[0][0].block_id}..{group[-1][0].block_id}: {e}"
        )
        with self._cond:
            for b, fl in group:
                self.index.abort_fetch(fl, err)
                self._info[b.index].state = BlockState.FAILED
                self._info[b.index].error = err
            self._cond.notify_all()
        log.error("blocks %s..%s failed permanently: %s",
                  group[0][0].block_id, group[-1][0].block_id, e)

    def _join_flight(self, b: Block, flight: CacheFlight) -> bool:
        """Another reader is fetching `b` right now: wait for its flight
        instead of issuing a duplicate GET. If the leader fails, retry the
        block ourselves (possibly becoming the new leader). Returns False
        when this stream should exit."""
        while True:
            with self._cond:
                if not self._fetch:
                    # repro: allow[RP002] — engine-lock-safe (tiers.py
                    # contract); at worst a local unlink.
                    self.index.leave(flight)
                    self._unclaim([b])
                    return False
            kind, val = self.index.join(flight, timeout=0.5)
            if kind == "timeout":
                continue
            if kind == "hit":
                self.stats.bump(flight_joins=1)
                self._mark_cached(b, val)
                return True
            # Leader failed (or abandoned): re-acquire; the block may have
            # landed meanwhile, someone else may be retrying it, or we
            # become the leader and run our own retry budget.
            kind, val = self.index.acquire(b.block_id, self.io_class)
            if kind == "hit":
                self.stats.bump(cache_hits=1)
                self._mark_cached(b, val)
                return True
            if kind == "wait":
                flight = val
                continue
            return self._flush_group([(b, val)])

    def _mark_cached(self, b: Block, tier: CacheTier) -> None:
        evict = False
        with self._cond:
            info = self._info[b.index]
            info.state = (BlockState.CONSUMED if info.abandoned
                          else BlockState.CACHED)
            info.tier = tier
            evict = info.abandoned
            self._cond.notify_all()
        if evict:
            self._request_eviction()

    def _reserve(self, nbytes: int) -> CacheTier | None:
        # Priority-ordered tier walk with verify_used reconciliation and
        # capacity-pressure LRU eviction of unpinned index blocks, shared
        # with the sequential engine via the index.
        return self.index.reserve_space(nbytes, self.io_class)

    def _fetch_group(self, group: list[tuple[Block, CacheFlight]],
                     tier: CacheTier) -> None:
        run = [b for b, _ in group]
        total = sum(b.size for b in run)
        t0 = time.perf_counter()
        pairs, store_s = self._fetch_with_retries(run)
        written: list[Block] = []
        try:
            for b, (d, _) in zip(run, pairs):
                tier.write(b.block_id, d,
                           meta=BlockMeta(key=b.key, offset=b.start))
                written.append(b)
        except Exception as e:
            # A mid-run write failure must not orphan the blocks that
            # already landed: the caller cancels the whole reservation,
            # and FAILED blocks are invisible to eviction, so resident
            # bytes would leak past the tier's accounting forever. None of
            # these blocks were published yet, so no index entry to undo.
            for b in written:
                try:
                    tier.delete(b.block_id)
                except Exception:  # repro: allow[RP005] — best-effort cleanup
                    pass
            if isinstance(e, StoreError):
                raise
            # Translate e.g. ENOSPC from a disk tier into the StoreError
            # the caller handles — anything else would skip the
            # reservation cancel and leave the run FETCHING forever
            # (reader deadlock).
            raise StoreError(
                f"tier write failed for blocks "
                f"{run[0].block_id}..{run[-1].block_id}"
            ) from e
        tier.commit(total)
        deltas: dict = dict(
            fetch_s=time.perf_counter() - t0,
            blocks_fetched=len(run),
            bytes_fetched=total,
            store_requests=1,
        )
        if len(run) > 1:
            deltas.update(coalesced_requests=1, coalesced_blocks=len(run))
        self.stats.bump(**deltas)
        if self.tuner is not None and store_s is not None:
            self.tuner.observe_request(total, store_s)
        if self._aimd is not None:
            new = self._aimd.on_fetch(total, time.perf_counter())
            self.stats.note_depth(new)
            grew = False
            with self._cond:
                if new != self._target_depth:
                    grew = new > self._target_depth
                    self._target_depth = new
                    self._cond.notify_all()
            if grew:
                self._spawn_streams(new)
        evict = False
        with self._cond:
            for (b, fl), (_, dig) in zip(group, pairs):
                # Publish pins the entry for us (plus any waiters); our
                # pin is released when this reader's eviction unpins it.
                # The digest minted at the fetch travels with the entry —
                # every later boundary crossing can re-check it.
                self.index.publish(fl, tier, b.size, digest=dig)
                info = self._info[b.index]
                info.state = (BlockState.CONSUMED if info.abandoned
                              else BlockState.CACHED)
                info.tier = tier
                evict = evict or info.abandoned
            self._cond.notify_all()
        if evict:
            self._request_eviction()

    def _on_throttle(self) -> None:
        """ThrottleError from the store (via the shared Retrier): record
        it and — when AIMD depth control is on — cut the stream target
        multiplicatively right now. Backoff alone would keep `max_depth`
        streams hammering a rate-limited backend; shrinking concurrency
        is what actually relieves the pressure."""
        self.stats.bump(throttles=1)
        if self._aimd is None or not self.throttle_aimd:
            return
        new = self._aimd.on_throttle()
        self.stats.note_depth(new)
        with self._cond:
            if new != self._target_depth:
                self._target_depth = new
                self._cond.notify_all()

    def _fetch_with_retries(
        self, run: list[Block]
    ) -> tuple[list[tuple[bytes, str | None]], float | None]:
        """One resilient (retried, optionally hedged) fetch of a
        contiguous run. Returns ((payload, digest) pairs, store seconds);
        seconds is None when a hedge fired — racing duplicates
        contaminate the timing, so hedged samples never reach the
        tuner."""
        return self._retrier.call(
            lambda: self._hedger.call(lambda: self._request(run)),
            label=f"blocks {run[0].block_id}..{run[-1].block_id}",
        )

    def _request(self, run: list[Block]) -> list[tuple[bytes, str | None]]:
        """One store round trip for a contiguous run. Returns (payload,
        digest) pairs — the digest is the store's attestation of the
        authoritative bytes (None with verify="off"), already verified
        against the payload actually received."""
        if self.verify == "off":
            if len(run) == 1:
                b = run[0]
                datas = [self.store.get_range(b.key, b.start, b.end)]
            else:
                datas = self.store.get_ranges(
                    run[0].key, [(b.start, b.end) for b in run]
                )
            pairs: list[tuple[bytes, str | None]] = [
                (d, None) for d in datas]
        else:
            if len(run) == 1:
                b = run[0]
                pairs = [self.store.get_range_verified(b.key, b.start, b.end)]
            else:
                pairs = self.store.get_ranges_verified(
                    run[0].key, [(b.start, b.end) for b in run]
                )
        for b, (d, dig) in zip(run, pairs):
            self._check_fetched(b, d, dig)
        return pairs

    def _check_fetched(self, b: Block, d: bytes, dig: str | None) -> None:
        if len(d) != b.size:
            # A short response the server reported as complete
            # (dropped connection, proxy truncation): caching it
            # would silently corrupt the stream. Surface it as a
            # transient fault so the Retrier re-requests.
            raise TransientStoreError(
                f"truncated response for {b.block_id}: "
                f"got {len(d)} of {b.size} bytes"
            )
        if dig is not None:
            # Received bytes vs the store's attested digest: a mismatch
            # (bit-flip in transit) is transient — the Retrier re-fetches
            # — and exhaustion surfaces as a typed IntegrityError rather
            # than wrong bytes.
            try:
                check_block(d, dig, what=f"fetched block {b.block_id}")
            except IntegrityError:
                self.stats.bump(integrity_failures=1)
                raise
            self.stats.bump(blocks_verified=1)

    # ------------------------------------------------------------------ #
    # reading path (called from the application thread)
    # ------------------------------------------------------------------ #
    def read_range(self, global_start: int, global_end: int,
                   *, view: bool = False) -> bytes | memoryview:
        """Read logical-stream bytes [global_start, global_end); blocks
        until the data has been prefetched (paper: the reader waits,
        bounding the worst case at sequential performance).

        With ``view=True`` a request contained in one cached block is
        served as a zero-copy `memoryview` over the block buffer (valid
        indefinitely — the underlying bytes are immutable); multi-block
        requests still return `bytes`.
        """
        self._observe_compute_gap()
        try:
            if global_end <= global_start:
                return b""
            block = self.plan.block_at(global_start)
            if global_end <= block.global_end:
                # Fast path: one block — at most one copy (zero with view).
                data = self._read_single(block, global_start, global_end,
                                         view=view)
                self._last_read_bytes = len(data)
                self.stats.bump(bytes_read=len(data))
                return data
            out = bytearray()
            pos = global_start
            while pos < global_end:
                block = self.plan.block_at(pos)
                hi = min(global_end, block.global_end)
                out += self._read_single(block, pos, hi, view=True)
                pos = hi
            self._last_read_bytes = len(out)
            self.stats.bump(bytes_read=len(out))
            return bytes(out)
        finally:
            self._last_read_t = time.perf_counter()

    def _observe_compute_gap(self) -> None:
        if self.tuner is None:
            return
        now = time.perf_counter()
        if self._last_read_t is not None and self._last_read_bytes > 0:
            self.tuner.observe_compute(self._last_read_bytes,
                                       now - self._last_read_t)

    def _read_single(self, block: Block, gstart: int, gend: int,
                     *, view: bool) -> bytes | memoryview:
        lo = gstart - block.global_start
        hi = gend - block.global_start
        if self._buf_index == block.index:
            data = (memoryview(self._buf_data)[lo:hi] if view
                    else self._buf_data[lo:hi])
        else:
            data = self._read_from_block(block, gstart, gend, view=view)
        if gend >= block.global_end:
            if self._buf_index == block.index:
                self._buf_index, self._buf_data = None, b""
            self._mark_consumed(block)
        return data

    def _direct_get(self, block: Block, lo: int, hi: int) -> bytes:
        """Direct store read on the reader thread (patience fallback,
        backward seek past eviction, integrity healing) — resilient via
        the shared Retrier like every other production store call."""
        self.stats.bump(direct_reads=1)

        def attempt() -> bytes:
            if self.verify == "off":
                data, dig = self.store.get_range(
                    block.key, block.start + lo, block.start + hi), None
            else:
                data, dig = self.store.get_range_verified(
                    block.key, block.start + lo, block.start + hi)
            if len(data) != hi - lo:
                # Same guard as _request: a short response the server
                # reported as complete must retry, not silently hand the
                # application fewer bytes than it asked for.
                raise TransientStoreError(
                    f"truncated response for {block.block_id}: "
                    f"got {len(data)} of {hi - lo} bytes"
                )
            if dig is not None:
                try:
                    check_block(data, dig,
                                what=f"direct read {block.block_id}")
                except IntegrityError:
                    self.stats.bump(integrity_failures=1)
                    raise
                self.stats.bump(blocks_verified=1)
            return data

        return self._retrier.call(
            attempt, label=f"direct read {block.block_id}",
        )

    def _verify_tier_read(self, tier: CacheTier, data: bytes,
                          block_id: str) -> None:
        """Engine-side digest re-check of a full-block tier read. "edges"
        trusts self-verifying tiers (DirTier's journal crc, the peer
        transport's frame check) — hashing twice would pay the <5%
        overhead budget twice for the same guarantee; "full" re-checks
        unconditionally. Raises `IntegrityError` (the caller quarantines
        and heals)."""
        if self.verify == "off":
            return
        if self.verify == "edges" and getattr(tier, "verifies_reads", False):
            return
        dig = self.index.digest_of(block_id)
        if dig is None:
            return
        # Mismatch counting happens at the catch site — the tier itself
        # may also raise (DirTier's crc), and both must count once.
        check_block(data, dig, what=f"cached block {block_id}")
        self.stats.bump(blocks_verified=1)

    def _read_from_block(self, block: Block, gstart: int, gend: int,
                         *, view: bool = False) -> bytes | memoryview:
        info = self._info[block.index]
        t0 = time.perf_counter()
        stalled = False
        with self._cond:
            # Advancing the reader position releases readahead-horizon
            # headroom — wake parked prefetch streams BEFORE waiting on
            # them, or neither side would move.
            if block.index > self._reader_block:
                self._reader_block = block.index
                self._cond.notify_all()
            while info.state in (BlockState.UNFETCHED, BlockState.FETCHING):
                # An already-abandoned block short-circuits: once one
                # read() burned the full patience on this block, later
                # reads into it go direct immediately instead of paying
                # another 30 s each.
                if info.abandoned or time.perf_counter() - t0 > self.READ_PATIENCE_S:
                    stalled = True
                    info.abandoned = True
                    break
                self._cond.wait(timeout=0.5)
            state, tier, err = info.state, info.tier, info.error
        self.stats.bump(reader_wait_s=time.perf_counter() - t0)
        lo = gstart - block.global_start
        hi = gend - block.global_start
        if stalled:
            # Patience expired: the scheduler owes us this block but can't
            # deliver (wedged tier space / leaked flight). Degrade to a
            # direct read so the pipeline unwedges instead of hanging.
            return self._direct_get(block, lo, hi)
        if state == BlockState.CACHED and tier is not None:
            try:
                # Load the whole block from the tier once; serve subsequent
                # small reads from the reader-side buffer.
                self._buf_data = tier.read(block.block_id, 0, block.size)
                self._verify_tier_read(tier, self._buf_data, block.block_id)
            except IntegrityError:
                # The cached copy is provably wrong (tier-level crc or the
                # index digest disagrees with the bytes). Quarantine —
                # evict + tombstone, so no reader (local or sibling) can
                # hit it again — and heal from the backing store. A rotted
                # cache block costs one GET, never wrong data.
                self.stats.bump(integrity_failures=1)
                self.index.quarantine(block.block_id)
                return self._direct_get(block, lo, hi)
            except StoreError:
                # A sibling process sharing a persistent cache dir may
                # have evicted the file beneath our index entry — the
                # bytes are one range GET away, don't crash the reader.
                # Drop the stale entry so the next acquire re-fetches into
                # the cache instead of paying a direct GET forever.
                self.index.invalidate(block.block_id)
                return self._direct_get(block, lo, hi)
            self._buf_index = block.index
            return (memoryview(self._buf_data)[lo:hi] if view
                    else self._buf_data[lo:hi])
        if state == BlockState.FAILED:
            # Keep the failure typed: unhealable corruption must surface as
            # IntegrityError at the reader, not a generic prefetch failure.
            cls = IntegrityError if isinstance(err, IntegrityError) else StoreError
            raise cls(f"block {block.block_id} failed to prefetch") from err
        # CONSUMED/EVICTED (backward seek): the shared cache may still
        # hold the block (keep_cached, another reader's pin) — serve it
        # locally before paying a store GET.
        kind, val = self.index.acquire(block.block_id, self.io_class)
        if kind == "hit":
            try:
                if self.verify == "off":
                    data = val.read(block.block_id, lo, hi)
                else:
                    # Digests cover whole blocks: read the full block so
                    # the check can run, then slice. A backward seek is
                    # rare enough that the extra bytes are noise next to
                    # serving rotted data from an unverified partial read.
                    full = val.read(block.block_id, 0, block.size)
                    self._verify_tier_read(val, full, block.block_id)
                    data = full[lo:hi]
                self.stats.bump(cache_hits=1)
                return data
            except IntegrityError:
                # Rotted beneath us: quarantine (the unpin below is a
                # no-op once the entry is gone) and go direct.
                self.stats.bump(integrity_failures=1)
                self.index.quarantine(block.block_id)
            except StoreError:
                # Vanished beneath us: drop the stale entry, go direct.
                self.index.invalidate(block.block_id)
            finally:
                self.index.unpin(block.block_id)
        elif kind == "leader":
            self.index.abort_fetch(val)   # not fetching into the tier here
        else:
            self.index.leave(val)
        return self._direct_get(block, lo, hi)

    def _mark_consumed(self, block: Block) -> None:
        notify_evict = False
        with self._cond:
            info = self._info[block.index]
            if block.index + 1 > self._reader_block:
                self._reader_block = block.index + 1
            if info.state == BlockState.CACHED:
                info.state = BlockState.CONSUMED
                tier = info.tier
                # Eviction-latency fix: a consumed block sitting in a tier
                # past its high-water mark wakes the evictor NOW — a full
                # tier must not stall prefetchers for up to the whole
                # eviction interval.
                if (tier is not None
                        and tier.used >= self.high_water * tier.capacity):
                    notify_evict = True
            self._cond.notify_all()
        if notify_evict:
            self._request_eviction()

    # ------------------------------------------------------------------ #
    # eviction thread
    # ------------------------------------------------------------------ #
    def _request_eviction(self) -> None:
        with self._evict_cond:
            self._evict_wanted = True
            self._evict_cond.notify_all()

    def _evictable(self) -> list[Block]:
        with self._cond:
            return [
                self.plan.blocks[i]
                for i, info in enumerate(self._info)
                if info.state == BlockState.CONSUMED
            ]

    def _evict_blocks(self, blocks: list[Block]) -> None:
        for block in blocks:
            with self._cond:
                info = self._info[block.index]
                if info.state != BlockState.CONSUMED or info.tier is None:
                    continue
                # Claim the transition before unpinning so overlapping
                # eviction rounds never double-release the same pin.
                info.state = BlockState.EVICTED
                info.tier = None
            # Refcount-aware eviction replaces the old fire-and-forget
            # delete: the block disappears only when the LAST reader's pin
            # drops (and stays resident under keep_cached, where capacity
            # pressure evicts instead).
            evicted = self.index.unpin(block.block_id, want_evict=True)
            with self._cond:
                self._cond.notify_all()
            if evicted:
                self.stats.bump(blocks_evicted=1)

    def _evict_loop(self) -> None:
        while True:
            with self._evict_cond:
                if self._fetch and not self._evict_wanted:
                    self._evict_cond.wait(timeout=self.eviction_interval_s)
                self._evict_wanted = False
            if not self._fetch:
                return
            self._evict_blocks(self._evictable())

    def _final_sweep(self) -> None:
        """Release this reader's pin on every remaining cached block
        (paper: the eviction thread ensures deletion of all remaining
        files prior to terminating). Blocks another reader still pins, or
        a keep_cached index keeps warm for the next open/restart, survive
        the sweep — only the pin is dropped."""
        for i, info in enumerate(self._info):
            with self._cond:
                tier = info.tier
                state = info.state
                if tier is not None and state in (BlockState.CACHED,
                                                  BlockState.CONSUMED):
                    info.state = BlockState.EVICTED
                    info.tier = None
                else:
                    continue
            self.index.unpin(self.plan.blocks[i].block_id, want_evict=True)


class RollingPrefetchFile:
    """File-like view over a prefetched multi-file logical stream.

    Matches the subset of the S3Fs file API the paper's applications use:
    sequential ``read``/``seek``/``tell``. Backward seeks degrade to direct
    store reads when the target block was already evicted. ``readview``
    is the zero-copy variant for consumers (numpy decoding, device upload)
    that accept a `memoryview`.
    """

    def __init__(self, prefetcher: RollingPrefetcher) -> None:
        self._pf = prefetcher
        self._pos = 0
        self._closed = False
        prefetcher.start()

    # Deprecated constructor: forwards to the PrefetchFS reader registry.
    @classmethod
    def open(
        cls,
        store: ObjectStore,
        files: list[ObjectMeta],
        tiers: list[CacheTier],
        blocksize: int,
        **kw,
    ) -> "RollingPrefetchFile":
        warnings.warn(
            "RollingPrefetchFile.open(...) is deprecated; use "
            "repro.io.PrefetchFS(store, policy=IOPolicy(engine='rolling', "
            "...)).open_many(files) instead",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.io import IOPolicy, PrefetchFS

        policy = IOPolicy(engine="rolling", blocksize=blocksize, **kw)
        return PrefetchFS(store, policy=policy, tiers=tiers).open_many(files)

    @property
    def size(self) -> int:
        return self._pf.plan.total_bytes

    @property
    def stats(self) -> PrefetchStats:
        return self._pf.stats

    @property
    def closed(self) -> bool:
        return self._closed

    def read(self, n: int = -1) -> bytes:
        data = self._read_impl(n, view=False)
        return data if type(data) is bytes else bytes(data)

    def readview(self, n: int = -1) -> bytes | memoryview:
        """Like :meth:`read` but may return a zero-copy `memoryview` over
        the cached block buffer when the request lies within one block.
        The view stays valid after subsequent reads (the underlying block
        bytes are immutable)."""
        return self._read_impl(n, view=True)

    def _read_impl(self, n: int, *, view: bool) -> bytes | memoryview:
        if self._closed:
            raise ValueError("read on closed file")
        if n < 0:
            n = self.size - self._pos
        end = min(self._pos + n, self.size)
        if end <= self._pos:
            return b""
        data = self._pf.read_range(self._pos, end, view=view)
        self._pos = end
        return data

    def seek(self, offset: int, whence: int = 0) -> int:
        if whence == 1:
            offset += self._pos
        elif whence == 2:
            offset += self.size
        if not 0 <= offset <= self.size:
            raise ValueError(f"seek out of range: {offset}")
        self._pos = offset
        return self._pos

    def tell(self) -> int:
        return self._pos

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._pf.close()

    def __enter__(self) -> "RollingPrefetchFile":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
