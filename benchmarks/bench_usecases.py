"""Paper Fig. 5: neuroimaging use-cases.

  * histogram of streamline lengths — data-intensive, lazy read
    (paper: ~1.5x with Rolling Prefetch);
  * bundle recognition — compute-intensive and NOT lazy (the pipeline
    loads everything, then computes), so reads cannot overlap compute
    within the task and the gain is limited to intra-read overlap
    (paper: 1.14x unsharded; better with more shards).

Claims validated: speedup(histogram) > speedup(bundle) and both < 2;
bundle-with-shards > bundle-single-file trend.
"""

from __future__ import annotations

import numpy as np

from repro.data.trk import iter_streamlines_multi

from benchmarks.common import (
    DEFAULT_BLOCK,
    emit,
    fresh_store,
    fresh_tiers,
    make_trk_dataset,
    open_reader,
    timed,
)


def _open(ds, mode: str, blocksize=DEFAULT_BLOCK):
    store = fresh_store(ds)
    if mode == "seq":
        return open_reader(store, ds.metas(), "sequential", blocksize=blocksize)
    return open_reader(store, ds.metas(), "rolling", blocksize=blocksize,
                       tiers=fresh_tiers())


def histogram_usecase(ds, mode: str) -> np.ndarray:
    """Lazily stream, collect lengths, 20-bin histogram (paper §II-D.4)."""
    f = _open(ds, mode)
    lengths = [
        float(np.linalg.norm(np.diff(sl.points, axis=0), axis=1).sum())
        for sl in iter_streamlines_multi(f, f.size)
    ]
    f.close()
    hist, _ = np.histogram(lengths, bins=20)
    return hist


def _resample(points: np.ndarray, n: int = 20) -> np.ndarray:
    t = np.linspace(0, 1, len(points))
    ti = np.linspace(0, 1, n)
    return np.stack([np.interp(ti, t, points[:, i]) for i in range(3)], axis=1)


def bundle_recognition_usecase(ds, mode: str) -> np.ndarray:
    """Load-all-then-compute (paper: no lazy loading -> reads cannot hide
    inside compute). Classifies each streamline against two reference
    bundles by mean-closest-distance after resampling."""
    f = _open(ds, mode)
    streamlines = [sl.points for sl in iter_streamlines_multi(f, f.size)]
    f.close()
    # Compute phase (distinct from the load phase, as in the paper).
    rng = np.random.default_rng(0)
    ref_cst = rng.normal(size=(20, 3)).cumsum(axis=0)
    ref_arc = rng.normal(size=(20, 3)).cumsum(axis=0) + 5.0
    labels = np.empty(len(streamlines), np.int32)
    for i, pts in enumerate(streamlines):
        r = _resample(pts)
        d_cst = float(np.mean(np.linalg.norm(r - ref_cst, axis=1)))
        d_arc = float(np.mean(np.linalg.norm(r - ref_arc, axis=1)))
        threshold = 8.0
        labels[i] = (
            0 if min(d_cst, d_arc) > threshold else (1 if d_cst < d_arc else 2)
        )
    return labels


def main(quick: bool = False) -> dict:
    reps = 2 if quick else 3
    n_files = 2 if quick else 4
    ds = make_trk_dataset(n_files, streamlines_per_file=4000, seed=21)

    t_h_seq, _, _ = timed(lambda: histogram_usecase(ds, "seq"), reps=reps)
    t_h_pf, _, _ = timed(lambda: histogram_usecase(ds, "pf"), reps=reps)
    sp_hist = t_h_seq / t_h_pf
    emit("fig5_histogram", t_h_pf * 1e6,
         f"seq_s={t_h_seq:.3f};pf_s={t_h_pf:.3f};speedup={sp_hist:.3f}")

    t_b_seq, _, _ = timed(lambda: bundle_recognition_usecase(ds, "seq"), reps=reps)
    t_b_pf, _, _ = timed(lambda: bundle_recognition_usecase(ds, "pf"), reps=reps)
    sp_bundle = t_b_seq / t_b_pf
    emit("fig5_bundle_sharded", t_b_pf * 1e6,
         f"seq_s={t_b_seq:.3f};pf_s={t_b_pf:.3f};speedup={sp_bundle:.3f}")

    # Single-shard variant (paper: no speedup with one small shard).
    ds1 = make_trk_dataset(1, streamlines_per_file=800, seed=22)
    t1_seq, _, _ = timed(lambda: bundle_recognition_usecase(ds1, "seq"), reps=reps)
    t1_pf, _, _ = timed(lambda: bundle_recognition_usecase(ds1, "pf"), reps=reps)
    sp_single = t1_seq / t1_pf
    emit("fig5_bundle_single", t1_pf * 1e6,
         f"seq_s={t1_seq:.3f};pf_s={t1_pf:.3f};speedup={sp_single:.3f}")

    assert sp_hist < 2.0 and sp_bundle < 2.0
    assert sp_hist > 1.05, f"histogram should benefit: {sp_hist:.3f}"
    assert sp_hist > sp_bundle - 0.1, (
        "data-intensive histogram should gain at least as much as the "
        f"load-then-compute bundle task: hist={sp_hist:.3f} bundle={sp_bundle:.3f}"
    )
    return dict(hist=sp_hist, bundle=sp_bundle, bundle_single=sp_single)


if __name__ == "__main__":
    main()
