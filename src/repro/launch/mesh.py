"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests must keep seeing 1 CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_debug_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many devices exist (CPU tests)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
