"""AdamW in pure JAX (pytree-structured, shard-transparent).

Optimizer state mirrors parameter structure so GSPMD shards moments
identically to parameters (ZeRO-equivalent when params are FSDP-sharded).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array     # scalar int32
    m: dict             # first moments  (same tree as params)
    v: dict             # second moments


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    # Moment storage dtype: "float32" (default) or "bfloat16" — halves
    # optimizer-state memory (8 -> 4 bytes/param beyond the fp32 master);
    # moments are accumulated in fp32 and rounded on store.
    moments_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * prog))
    return cfg.lr * jnp.minimum(warm, 1.0) * jnp.where(step < cfg.warmup_steps, 1.0, cos)


def _moment_dtype(cfg: "AdamWConfig"):
    return jnp.bfloat16 if cfg.moments_dtype == "bfloat16" else jnp.float32


def init_state(params, cfg: "AdamWConfig | None" = None) -> AdamWState:
    mdt = _moment_dtype(cfg) if cfg is not None else jnp.float32
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=zeros,
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def apply_updates(
    cfg: AdamWConfig, params, grads, state: AdamWState
) -> tuple[dict, AdamWState, dict]:
    """One AdamW step; returns (params, state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mdt = _moment_dtype(cfg)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / b1c
        vhat = v32 / b2c
        step_vec = mhat / (jnp.sqrt(vhat) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (
            step_vec + cfg.weight_decay * p.astype(jnp.float32)
        )
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
