"""Unified I/O subsystem: the `PrefetchFS` facade, `IOPolicy` config, the
`Reader` protocol, and the pluggable reader-engine registry.

This is the one construction path for prefetched reads — the S3Fs-shaped
API the paper argues for, extended with policy objects and a backend
registry so new engines (real S3, async, sharded) plug in without touching
call sites::

    from repro.io import IOPolicy, PrefetchFS

    fs = PrefetchFS(store, policy=IOPolicy(engine="rolling", blocksize=1 << 20))
    with fs.open_many(files) as f:      # one logical stream over many objects
        data = f.read()
    print(fs.stats().snapshot())
"""

from repro.io.fs import FSStats, PrefetchFS
from repro.io.policy import IOPolicy
from repro.io.reader import DirectReader, DirectStats, Reader
from repro.io.registry import available_engines, engine_spec, register_reader

__all__ = [
    "FSStats",
    "PrefetchFS",
    "IOPolicy",
    "Reader",
    "DirectReader",
    "DirectStats",
    "available_engines",
    "engine_spec",
    "register_reader",
]
