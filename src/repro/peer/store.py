"""PeerAwareStore: ownership-routed reads over a `PeerGroup`.

The composite store the ``peer://`` URI builds: every range GET is routed
to the block's *home* host first (`PeerGroup.owner_of`, rendezvous over
the alive members):

  * self-owned block → direct backing-store GET (we ARE the home; our
    local `BlockServer` + `CacheIndex` make it resident for siblings);
  * remote-owned block → ``owner=True`` fetch RPC to the home host,
    which serves it from cache or performs the ONE backing GET for the
    whole group (cross-host single-flight);
  * dead home / failed RPC / peer miss → direct backing GET. Degraded,
    never broken: peer faults cost WAN traffic, not correctness.

`PrefetchFS` recognizes the wrapper the same way it recognizes
`HSMStore` — it adopts ``tiers`` + ``index`` but keeps reading THROUGH
the wrapper, because the routing above lives in ``get_range`` /
``get_ranges``. Composes with ``hsm://`` by nesting: a ``backing=`` that
resolves to an `HSMStore` contributes its hierarchy, and the peer layer
routes whatever misses it.
"""

from __future__ import annotations

import threading
from urllib.parse import unquote

from repro.io.integrity import block_digest
from repro.peer.client import PeerClient
from repro.peer.group import PeerGroup, PeerSpec
from repro.peer.protocol import span_block_id
from repro.peer.server import BlockServer
from repro.peer.tier import PeerTier
from repro.store.base import (
    MultipartUpload,
    ObjectMeta,
    ObjectStore,
    StoreError,
)
from repro.store.hsm import (
    HSMIndex,
    HSMStore,
    MEM_LINK,
    parse_size,
)
from repro.store.link import LinkModel, PeerLinkModel
from repro.store.tiers import CacheIndex, CacheTier, MemTier
from repro.utils import get_logger

log = get_logger("peer.store")


class PeerAwareStore(ObjectStore):
    def __init__(
        self,
        inner: ObjectStore,
        group: PeerGroup,
        *,
        tiers: list[CacheTier] | None = None,
        index: CacheIndex | None = None,
        server: BlockServer | None = None,
        owns_hierarchy: bool = False,
    ) -> None:
        if isinstance(inner, PeerAwareStore):
            raise ValueError("peer store cannot wrap another peer store")
        self.inner = inner
        self.group = group
        self.tiers = list(tiers) if tiers is not None else []
        self.index = index
        self.server = server
        self._owns_hierarchy = owns_hierarchy
        self._lock = threading.Lock()
        # Integrity posture for peer-served bytes. The transport already
        # verifies every BLOCK frame against its header digest; "full"
        # additionally cross-checks peer-served bytes against the
        # *backing store's* digest (`inner.digest_range`) — the one
        # authority a self-consistent byzantine sibling cannot forge.
        self.verify = "edges"
        # Telemetry (surfaced as FSStats.peer).
        self.peer_hits = 0             # blocks served by a sibling
        self.peer_misses = 0           # sibling probe came back empty
        self.local_fetches = 0         # self-owned blocks (direct GETs)
        self.dead_peer_fallbacks = 0   # home dead/unreachable -> direct GET
        self.integrity_rejects = 0     # peer bytes failed the cross-check
        self.bytes_from_peers = 0
        self.fallback_bytes = 0

    # -- routed reads --------------------------------------------------------
    def _route(self, key: str, start: int, end: int) -> tuple[PeerClient | None, int]:
        owner = self.group.owner_of(span_block_id(key, start, end))
        if owner == self.group.self_id:
            return None, owner
        return self.group.client_for(owner), owner

    def _fetch_via_peer(self, client: PeerClient, owner: int,
                        key: str, start: int, end: int) -> bytes | None:
        """One routed attempt; None means "use the backing store" (and
        the reason is already counted)."""
        try:
            data = client.fetch(key, start, end, owner=True)
        except StoreError as e:
            # PeerError or a retry-exhausted StoreError: the home is
            # suspect, the read is not.
            self.group.note_failure(owner)
            with self._lock:
                self.dead_peer_fallbacks += 1
            log.warning("peer %d fetch failed (%s); falling back to store",
                        owner, e)
            return None
        if data is None:
            with self._lock:
                self.peer_misses += 1
            return None
        with self._lock:
            self.peer_hits += 1
            self.bytes_from_peers += len(data)
        if self.verify == "full" and not self._cross_check(
                owner, key, start, end, data):
            return None
        return data

    def _cross_check(self, owner: int, key: str, start: int, end: int,
                     data: bytes) -> bool:
        """"full"-mode defense: compare peer-served bytes against the
        backing store's own digest of the range. Honest about its cost —
        the default `digest_range` reads the range from the store — which
        is why only "full" pays it. A failed check demotes the sibling
        (`note_failure`) and sends the caller to the backing store."""
        try:
            ref = self.inner.digest_range(key, start, end)
        except StoreError:
            return True   # no authority reachable; frame digest stands
        if ref == block_digest(data):
            return True
        self.group.note_failure(owner)
        with self._lock:
            self.integrity_rejects += 1
        log.warning(
            "peer %d served bytes for %s[%d:%d] that contradict the "
            "backing store (%s); falling back", owner, key, start, end, ref,
        )
        return False

    def get_range(self, key: str, start: int, end: int) -> bytes:
        client, owner = self._route(key, start, end)
        if client is not None:
            data = self._fetch_via_peer(client, owner, key, start, end)
            if data is not None:
                return data
        with self._lock:
            if client is None and owner == self.group.self_id:
                self.local_fetches += 1
            elif client is None:
                self.dead_peer_fallbacks += 1
            self.fallback_bytes += end - start
        return self.inner.get_range(key, start, end)

    def get_ranges(self, key: str, spans: list[tuple[int, int]]) -> list[bytes]:
        out: list[bytes | None] = [None] * len(spans)
        need: list[int] = []
        for i, (start, end) in enumerate(spans):
            client, owner = self._route(key, start, end)
            if client is not None:
                out[i] = self._fetch_via_peer(client, owner, key, start, end)
            if out[i] is None:
                with self._lock:
                    if client is None and owner == self.group.self_id:
                        self.local_fetches += 1
                    elif client is None:
                        self.dead_peer_fallbacks += 1
                    self.fallback_bytes += end - start
                need.append(i)
        if need:
            # One vectorized backing request for everything unrouted —
            # adjacent self-owned spans still coalesce inside the store.
            datas = self.inner.get_ranges(key, [spans[i] for i in need])
            for i, d in zip(need, datas):
                out[i] = d
        return out  # type: ignore[return-value]

    # -- verified reads ------------------------------------------------------
    # Peer-served bytes arrive frame-verified (PeerClient checked the
    # payload against the sibling's attested digest), so hashing them
    # here re-mints the SAME digest the sibling sent; fallback reads get
    # the backing store's own attestation. Either way the caller holds a
    # digest that covers the exact bytes returned.
    def get_range_verified(self, key: str, start: int,
                           end: int) -> tuple[bytes, str]:
        client, owner = self._route(key, start, end)
        if client is not None:
            data = self._fetch_via_peer(client, owner, key, start, end)
            if data is not None:
                return data, block_digest(data)
        with self._lock:
            if client is None and owner == self.group.self_id:
                self.local_fetches += 1
            elif client is None:
                self.dead_peer_fallbacks += 1
            self.fallback_bytes += end - start
        return self.inner.get_range_verified(key, start, end)

    def get_ranges_verified(
        self, key: str, spans: list[tuple[int, int]],
    ) -> list[tuple[bytes, str]]:
        out: list[tuple[bytes, str] | None] = [None] * len(spans)
        need: list[int] = []
        for i, (start, end) in enumerate(spans):
            client, owner = self._route(key, start, end)
            data = None
            if client is not None:
                data = self._fetch_via_peer(client, owner, key, start, end)
            if data is not None:
                out[i] = (data, block_digest(data))
            else:
                with self._lock:
                    if client is None and owner == self.group.self_id:
                        self.local_fetches += 1
                    elif client is None:
                        self.dead_peer_fallbacks += 1
                    self.fallback_bytes += end - start
                need.append(i)
        if need:
            pairs = self.inner.get_ranges_verified(
                key, [spans[i] for i in need])
            for i, pair in zip(need, pairs):
                out[i] = pair
        return out  # type: ignore[return-value]

    def digest_range(self, key: str, start: int, end: int) -> str:
        # Always the backing store's answer: this is the authoritative
        # reference the "full" cross-check compares peers against, so it
        # must never itself be peer-derived.
        return self.inner.digest_range(key, start, end)

    # -- plain delegation ----------------------------------------------------
    def get(self, key: str) -> bytes:
        # Whole-object reads (manifests, metadata) skip peer routing:
        # they are not block-shaped, so siblings would never have them
        # under a matching id.
        return self.inner.get(key)

    def list_objects(self, prefix: str = "") -> list[ObjectMeta]:
        return self.inner.list_objects(prefix)

    def size(self, key: str) -> int:
        return self.inner.size(key)

    def put(self, key: str, data: bytes) -> None:
        self.inner.put(key, data)

    def delete(self, key: str) -> None:
        self.inner.delete(key)

    def start_multipart(self, key: str) -> MultipartUpload:
        return self.inner.start_multipart(key)

    # -- telemetry / lifecycle ----------------------------------------------
    def peer_snapshot(self) -> dict:
        with self._lock:
            out = dict(
                peer_hits=self.peer_hits,
                peer_misses=self.peer_misses,
                local_fetches=self.local_fetches,
                dead_peer_fallbacks=self.dead_peer_fallbacks,
                integrity_rejects=self.integrity_rejects,
                bytes_from_peers=self.bytes_from_peers,
                fallback_bytes=self.fallback_bytes,
            )
        out["group"] = self.group.snapshot()
        if self.server is not None:
            out["server"] = self.server.snapshot()
        return out

    def close(self) -> None:
        if self.server is not None:
            self.server.close()
        self.group.close()
        if self._owns_hierarchy:
            if isinstance(self.inner, HSMStore):
                self.inner.close()
            else:
                if self.index is not None and hasattr(self.index, "close"):
                    self.index.close()
                for t in self.tiers:
                    t.close()


PEER_URI_PARAMS = {
    "backing", "self", "peers", "serve", "mem", "peer_tier",
    "peer_latency_ms", "peer_bw_mbps", "peer_rps", "heartbeat_ms",
    "verify",
}


def build_peer(uri, open_inner) -> PeerAwareStore:
    """Assemble a `PeerAwareStore` from a parsed ``peer://`` `StoreURI`::

        peer://?self=0&peers=0@127.0.0.1:9100,1@127.0.0.1:9101
              &backing=sims3%3A%2F%2Fbucket%3Flatency_ms%3D40&mem=64MB

    Params: ``self=<id>`` (required) and ``peers=<id>@<host>:<port>,...``
    (the static membership; must include self's serving address unless
    ``serve=0``); ``backing=<uri>`` (required, percent-encode nested
    queries — composing with ``hsm://`` adopts that hierarchy); ``mem``
    (local cache for a non-hsm backing, default 64MB); ``peer_tier=1``
    appends a `PeerTier` below the local tiers so HSM demotions spill to
    siblings instead of the floor; ``peer_latency_ms`` /
    ``peer_bw_mbps`` / ``peer_rps`` shape the LAN `PeerLinkModel`;
    ``heartbeat_ms`` enables liveness probing.

    ``open_inner`` resolves the backing URI (injected by the registry to
    keep this module import-cycle-free of the io layer).
    """
    uri.require_known_params(PEER_URI_PARAMS)
    backing_uri = uri.params.get("backing")
    if not backing_uri:
        raise ValueError("peer:// URI needs backing=<store uri>")
    if "self" not in uri.params:
        raise ValueError("peer:// URI needs self=<host id>")
    self_id = int(uri.params["self"])
    specs = [PeerSpec.parse(unquote(s))
             for s in uri.params.get("peers", "").split(",") if s]

    link = PeerLinkModel(
        latency_s=(uri.float_param("peer_latency_ms",
                                   PeerLinkModel.latency_s * 1e3) or 0.0) / 1e3,
        bandwidth_Bps=(
            uri.float_param("peer_bw_mbps") * 1e6
            if uri.float_param("peer_bw_mbps") is not None
            else PeerLinkModel.bandwidth_Bps
        ),
        rps_limit=(uri.float_param("peer_rps")
                   if uri.float_param("peer_rps") is not None
                   else float("inf")),
    )
    heartbeat_ms = uri.float_param("heartbeat_ms")
    group = PeerGroup(
        self_id, specs, link=link,
        heartbeat_interval_s=(heartbeat_ms / 1e3 if heartbeat_ms else None),
    )

    backing = open_inner(backing_uri)
    if isinstance(backing, HSMStore):
        if uri.params.get("mem") or uri.params.get("peer_tier"):
            raise ValueError(
                "peer:// with an hsm:// backing adopts that hierarchy; "
                "mem=/peer_tier= apply only to plain backings"
            )
        raw, tiers, index = backing.inner, backing.tiers, backing.index
        inner_for_close: ObjectStore = backing
    else:
        raw = backing
        mem_cap = parse_size(uri.params.get("mem", "64MB"))
        tiers = [MemTier(
            mem_cap,
            read_link=LinkModel(name="peer.mem.r", **MEM_LINK),
            write_link=LinkModel(name="peer.mem.w", **MEM_LINK),
            name="peer.mem",
        )]
        if uri.params.get("peer_tier") not in (None, "", "0"):
            tiers.append(PeerTier(group))
        if len(tiers) > 1:
            # Cost-ordered walk + demote-not-evict across mem -> peers.
            index = HSMIndex(tiers, mover_interval_s=None)
        else:
            index = CacheIndex(tiers, keep_cached=True)
        inner_for_close = raw

    server = None
    if uri.params.get("serve", "1") not in ("0", "false"):
        spec = group.specs.get(self_id)
        if spec is None or not spec.host:
            raise ValueError(
                "peer:// needs self's serving address in peers= "
                "(or serve=0 for a client-only member)"
            )
        server = BlockServer(index, raw, host=spec.host, port=spec.port,
                             host_id=self_id)

    store = PeerAwareStore(
        inner_for_close if isinstance(inner_for_close, HSMStore) else raw,
        group, tiers=tiers, index=index, server=server, owns_hierarchy=True,
    )
    verify = uri.params.get("verify")
    if verify is not None:
        if verify not in ("off", "edges", "full"):
            raise ValueError(
                f"peer:// verify= must be off/edges/full, got {verify!r}"
            )
        store.verify = verify
    return store
