"""Sequential-transfer baseline, modeling S3Fs/FSSpec on-demand block cache.

This is the paper's comparison point: data transfer and compute occur in
distinct phases. A ``read()`` that misses the single-block cache fetches
the containing block from the object store synchronously (paying one
request latency + bandwidth), then serves from memory. No background
threads, no overlap.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import TYPE_CHECKING

from repro.core.plan import BlockPlan
from repro.store.base import ObjectMeta, ObjectStore

if TYPE_CHECKING:
    from repro.core.autotune import BlockSizeTuner


@dataclass
class SequentialStats:
    blocks_fetched: int = 0
    bytes_fetched: int = 0
    bytes_read: int = 0
    fetch_s: float = 0.0
    store_requests: int = 0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


@dataclass
class _CacheEntry:
    index: int
    data: bytes


class SequentialFile:
    """fsspec-style read-ahead block cache over the same logical stream the
    Rolling Prefetch file exposes, so both sides of every A/B benchmark
    perform byte-identical application reads."""

    def __init__(
        self,
        store: ObjectStore,
        files: list[ObjectMeta],
        blocksize: int,
        cache_blocks: int = 1,
        tuner: "BlockSizeTuner | None" = None,
    ) -> None:
        self.store = store
        self.plan = BlockPlan(files, blocksize)
        self.cache_blocks = max(1, cache_blocks)
        self.tuner = tuner
        self.stats = SequentialStats()
        self._cache: dict[int, _CacheEntry] = {}
        self._lru: list[int] = []
        self._pos = 0
        self._closed = False

    @property
    def size(self) -> int:
        return self.plan.total_bytes

    @property
    def closed(self) -> bool:
        return self._closed

    def _get_block(self, index: int) -> bytes:
        entry = self._cache.get(index)
        if entry is not None:
            return entry.data
        # Read-ahead: with cache_blocks > 1 the miss fetches the run of
        # adjacent same-file blocks that fills the cache with ONE
        # vectorized request (fsspec's readahead cache, request-efficient
        # via `get_ranges`); cache_blocks == 1 keeps the paper's baseline
        # shape of exactly one request per block.
        run = []
        for b in self.plan.run_from(index, self.cache_blocks):
            if b.index in self._cache:
                break  # keep the request one adjacent span
            run.append(b)
        t0 = time.perf_counter()
        if len(run) == 1:
            datas = [self.store.get_range(run[0].key, run[0].start, run[0].end)]
        else:
            datas = self.store.get_ranges(
                run[0].key, [(b.start, b.end) for b in run]
            )
        dt = time.perf_counter() - t0
        nbytes = sum(len(d) for d in datas)
        self.stats.fetch_s += dt
        self.stats.store_requests += 1
        self.stats.blocks_fetched += len(run)
        self.stats.bytes_fetched += nbytes
        if self.tuner is not None:
            # Synchronous fetches time the store request exactly, so this
            # engine closes the loop too: with autotune on, PrefetchFS
            # retunes the Eq.-4 blocksize from these samples on reopen.
            self.tuner.observe_request(nbytes, dt)
        for b, d in zip(run, datas):
            self._cache[b.index] = _CacheEntry(b.index, d)
            self._lru.append(b.index)
        while len(self._lru) > self.cache_blocks:
            self._cache.pop(self._lru.pop(0), None)
        return self._cache[index].data

    def read(self, n: int = -1) -> bytes:
        if self._closed:
            raise ValueError("read on closed file")
        if n < 0:
            n = self.size - self._pos
        end = min(self._pos + n, self.size)
        out = bytearray()
        while self._pos < end:
            block = self.plan.block_at(self._pos)
            data = self._get_block(block.index)
            lo = self._pos - block.global_start
            hi = min(end, block.global_end) - block.global_start
            out.extend(data[lo:hi])
            self._pos += hi - lo
        self.stats.bytes_read += len(out)
        return bytes(out)

    def seek(self, offset: int, whence: int = 0) -> int:
        if whence == 1:
            offset += self._pos
        elif whence == 2:
            offset += self.size
        if not 0 <= offset <= self.size:
            raise ValueError(f"seek out of range: {offset}")
        self._pos = offset
        return self._pos

    def tell(self) -> int:
        return self._pos

    def close(self) -> None:
        self._closed = True
        self._cache.clear()
        self._lru.clear()

    def __enter__(self) -> "SequentialFile":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
