"""Mamba-2 SSD chunked scan as a fused Pallas TPU kernel.

Grid (batch, heads, chunks) with the chunk dimension sequential
("arbitrary"): the inter-chunk SSM state (head_dim x state) is carried in
fp32 VMEM scratch across grid steps — the whole recurrence runs in one
kernel launch. Intra-chunk work is dense MXU matmuls:

    acs    = cumsum(dt_a)                     (via lower-tri ones matmul)
    L      = exp(acs_i - acs_j) . tril        (1-semiseparable decay)
    y_diag = ((C B^T) * L) X                  (Q,Q)@(Q,P)
    y_off  = (C h_prev^T) * exp(acs)          (Q,N)@(N,P)
    h_new  = exp(acs_Q) h_prev + X^T (B * exp(acs_Q - acs))

Block working set at (Q=256, P=64, N=128): x 64KB, B/C 128KB each, L 256KB
fp32, state 32KB — comfortably inside VMEM, MXU dims all multiples of 64.
Validated in interpret mode against both the chunked jnp path
(repro.models.ssd) and the sequential-recurrence oracle (ref.ssd_ref).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells CompilerParams TPUCompilerParams.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _ssd_kernel(
    x_ref,        # (1, Q, 1, P)
    a_ref,        # (1, Q, 1)
    b_ref,        # (1, Q, 1, N)
    c_ref,        # (1, Q, 1, N)
    init_ref,     # (1, 1, P, N)
    y_ref,        # (1, Q, 1, P) out
    final_ref,    # (1, 1, P, N) out
    h_ref,        # VMEM scratch (P, N) fp32
    *,
    num_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = init_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (Q, P)
    a = a_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    bmat = b_ref[0, :, 0, :].astype(jnp.float32)     # (Q, N)
    cmat = c_ref[0, :, 0, :].astype(jnp.float32)     # (Q, N)
    q = x.shape[0]

    # Inclusive cumsum via lower-triangular ones matmul (MXU-friendly).
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tril_inc = (cols <= rows).astype(jnp.float32)    # includes diagonal
    acs = jax.lax.dot(tril_inc, a[:, None],
                      preferred_element_type=jnp.float32)[:, 0]  # (Q,)

    # Intra-chunk decay matrix.
    seg = acs[:, None] - acs[None, :]
    l_mat = jnp.where(cols <= rows, jnp.exp(seg), 0.0)            # (Q, Q)

    cb = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                              # (Q, Q)
    y_diag = jax.lax.dot(cb * l_mat, x, preferred_element_type=jnp.float32)

    h_prev = h_ref[...]                                            # (P, N)
    y_off = jax.lax.dot_general(
        cmat, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * jnp.exp(acs)[:, None]                                      # (Q, P)

    chunk_decay = jnp.exp(acs[-1])
    decay_states = jnp.exp(acs[-1] - acs)                          # (Q,)
    state_update = jax.lax.dot_general(
        x, bmat * decay_states[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                              # (P, N)
    h_ref[...] = h_prev * chunk_decay + state_update

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    @pl.when(ic == num_chunks - 1)
    def _finish():
        final_ref[0, 0] = h_ref[...].astype(final_ref.dtype)


def ssd_scan(
    x: jax.Array,        # (B, S, H, P) — dt-scaled inputs
    dt_a: jax.Array,     # (B, S, H)
    b_proj: jax.Array,   # (B, S, G, N)
    c_proj: jax.Array,   # (B, S, G, N)
    *,
    chunk: int = 256,
    initial_state: jax.Array | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    bsz, s, h, p = x.shape
    g, n = b_proj.shape[2], b_proj.shape[3]
    assert h % g == 0, (h, g)
    rep = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    kernel = functools.partial(_ssd_kernel, num_chunks=nc)
    grid = (bsz, h, nc)
    y, final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, hh, c: (b, c, hh)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda b, hh, c, rep=rep: (b, c, hh // rep, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda b, hh, c, rep=rep: (b, c, hh // rep, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, hh, c: (b, hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, hh, c: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt_a, b_proj, c_proj, initial_state)
    return y, final
