"""Beyond-paper: Rolling-Prefetch checkpoint restore.

Restoring a sharded checkpoint from the object store is the same
sequential multi-object stream the paper optimizes: fetching leaf k+1..k+d
overlaps with deserialize + device_put of leaf k. Measures sequential vs
rolling vs rolling with fetch depth 4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import restore_checkpoint, save_checkpoint
from repro.io import IOPolicy
from repro.store import LinkModel, MemTier, SimS3Store

from benchmarks.common import emit, timed


def _state(n_leaves: int, leaf_kb: int):
    rng = np.random.default_rng(0)
    return {
        f"layer_{i:03d}": jnp.asarray(
            rng.normal(size=(leaf_kb * 256 // 4, 4)).astype(np.float32)
        )
        for i in range(n_leaves)
    }


def main(quick: bool = False) -> dict:
    n_leaves = 12 if quick else 24
    leaf_kb = 128
    state = _state(n_leaves, leaf_kb)

    def restore(mode: str, depth: int = 1) -> None:
        store = SimS3Store(link=LinkModel(latency_s=0.01, bandwidth_Bps=40e6))
        save_checkpoint(store, "ckpt", 1, state)
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
        )
        restored, _ = restore_checkpoint(
            store, "ckpt", template,
            policy=IOPolicy(engine=mode, blocksize=64 << 10, depth=depth,
                            eviction_interval_s=0.2),
            tiers=[MemTier(8 << 20)],
        )
        jax.block_until_ready(restored)

    reps = 2 if quick else 3
    t_seq, _, _ = timed(lambda: restore("sequential"), reps=reps)
    t_roll, _, _ = timed(lambda: restore("rolling"), reps=reps)
    t_roll4, _, _ = timed(lambda: restore("rolling", depth=4), reps=reps)
    results = dict(sequential=t_seq, rolling=t_roll, rolling_d4=t_roll4)
    for name, t in results.items():
        emit(f"ckpt_restore_{name}", t * 1e6,
             f"leaves={n_leaves};speedup_vs_seq={t_seq / t:.3f}")
    assert t_roll < t_seq * 1.05
    assert t_roll4 <= t_roll * 1.1
    return results


if __name__ == "__main__":
    main()
