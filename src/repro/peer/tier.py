"""PeerTier: sibling hosts' caches as one level of the local hierarchy.

A `CacheTier` whose backing medium is the rest of the `PeerGroup`: reads
are non-owner fetch RPCs to the block's home host (pure cache probes —
a peer tier read NEVER triggers a backing-store GET, so the LAN cost its
`TierCostModel` advertises is honest), writes are push RPCs to the home
host (how an HSM demotes a cooling block out of local memory/disk
without losing it to the WAN), deletes only forget the local view (a
sibling's copy is the sibling's to evict).

Slot it between local disk and the backing store::

    peer  = PeerTier(group)
    index = HSMIndex([mem, disk, peer], store_link=wan)

`TierCostModel.from_tier` seeds the placement cost from the tier's
links, which here are the group's shared `PeerLinkModel` — so the HSM's
cost ordering puts it exactly where a ~0.2 ms / 1.25 GB/s LAN hop
belongs: below local media, far above the WAN.

Transport billing note: `PeerClient` bills every payload to the peer
link, so this tier's `read`/`write` overrides skip `CacheTier`'s own
link charge — one block moved over the LAN is billed once.

Integrity note: every block that crosses the LAN is frame-verified by
the transport — `PeerClient.fetch` checks the payload against the
digest the home host attested in the frame header, and `put` attests
what it pushes (the home host re-verifies before publishing). A frame
that fails the check surfaces here as a `StoreError`, which the index
treats like any lost tier block: invalidate and re-fetch from the next
authority. The tier itself therefore sets ``verifies_reads`` — a read
that returns at all returned digest-checked bytes.
"""

from __future__ import annotations

import threading

from repro.peer.group import PeerGroup
from repro.peer.protocol import PeerError, parse_block_id
from repro.store.base import StoreError
from repro.store.tiers import BlockMeta, CacheTier


class PeerTier(CacheTier):
    #: Nominal capacity: the aggregate of the siblings' caches is not
    #: locally bounded (each sibling enforces its own budgets), so the
    #: tier advertises effectively-infinite space and relies on remote
    #: admission (a push may come back "rejected") for pressure.
    DEFAULT_CAPACITY = 1 << 40

    #: Reads arrive digest-checked by the transport (see module
    #: docstring), so "edges" verification need not re-hash them.
    verifies_reads = True

    def __init__(self, group: PeerGroup, capacity: int = DEFAULT_CAPACITY,
                 *, name: str = "peer") -> None:
        super().__init__(capacity, read_link=group.link,
                         write_link=group.link, name=name)
        self.group = group
        # Local view of what we pushed/observed remotely: block_id -> size.
        # Advisory only — a sibling may evict behind our back, in which
        # case a read raises StoreError and the index invalidates the
        # entry (the same contract as a sibling-evicted DirTier file).
        self._known: dict[str, int] = {}
        self._known_lock = threading.Lock()
        # Telemetry.
        self.remote_reads = 0
        self.remote_writes = 0
        self.lost_blocks = 0   # reads that found the sibling copy gone

    # -- link billing override ----------------------------------------------
    # The transport (PeerClient) bills group.link per payload; billing
    # again here would double-charge the LAN. The links stay attached so
    # TierCostModel.from_tier seeds peer-accurate constants.
    def read(self, block_id: str, start: int = 0, end: int | None = None) -> bytes:
        return self._read(block_id, start, end)

    def write(self, block_id: str, data: bytes, *,
              meta: BlockMeta | None = None, durable: bool = True) -> None:
        prev = self._size_of(block_id)
        self._store_block(block_id, data, meta, durable)
        if prev > 0:
            with self._lock:
                self._used = max(0, self._used - prev)

    # -- backend hooks ------------------------------------------------------
    def _read(self, block_id: str, start: int, end: int | None) -> bytes:
        key, lo, hi = parse_block_id(block_id)
        owner = self.group.owner_of(block_id)
        client = self.group.client_for(owner)
        if client is None:
            # Self-owned or dead home: nothing a *peer* tier can serve.
            raise StoreError(
                f"{self.name}: no live home for {block_id} (owner {owner})"
            )
        try:
            data = client.fetch(key, lo, hi, owner=False)
        except PeerError as e:
            self.group.note_failure(owner)
            raise StoreError(f"{self.name}: {e}") from e
        if data is None:
            with self._known_lock:
                if self._known.pop(block_id, None) is not None:
                    self.lost_blocks += 1
            raise StoreError(
                f"{self.name}: block evicted by sibling {owner}: {block_id}"
            )
        with self._known_lock:
            self.remote_reads += 1
            self._known.setdefault(block_id, len(data))
        return data[start:end if end is not None else len(data)]

    def _store_block(self, block_id: str, data: bytes,
                     meta: BlockMeta | None, durable: bool) -> None:
        key, lo, hi = parse_block_id(block_id)
        owner = self.group.owner_of(block_id)
        client = self.group.client_for(owner)
        if client is None:
            raise StoreError(
                f"{self.name}: no live home to push {block_id} to "
                f"(owner {owner})"
            )
        try:
            stored = client.put(key, lo, hi, bytes(data))
        except PeerError as e:
            self.group.note_failure(owner)
            raise StoreError(f"{self.name}: {e}") from e
        if not stored:
            raise StoreError(
                f"{self.name}: sibling {owner} rejected {block_id}"
            )
        with self._known_lock:
            self.remote_writes += 1
            self._known[block_id] = len(data)

    def _write(self, block_id: str, data: bytes) -> None:
        self._store_block(block_id, data, None, True)

    def _delete(self, block_id: str) -> int:
        # Forget, don't reach across the wire: the copy on the home host
        # belongs to that host's cache (it may be serving other siblings).
        with self._known_lock:
            return self._known.pop(block_id, 0)

    def _contains(self, block_id: str) -> bool:
        with self._known_lock:
            return block_id in self._known

    def _size_of(self, block_id: str) -> int:
        with self._known_lock:
            return self._known.get(block_id, 0)

    def _resident_bytes(self) -> int:
        with self._known_lock:
            return sum(self._known.values())

    # resident_blocks() stays the base-class empty list on purpose: peer
    # residency must not be primed into a fresh CacheIndex (the blocks
    # live on siblings whose own indices already track them).
