"""Property-based tests for the data codecs and the store substrate."""

from __future__ import annotations

import io
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.tokens import (
    TokenShardHeader,
    TokenStreamReader,
    write_token_shard,
)
from repro.data.trk import LazyTrkReader, TrkHeader, write_trk
from repro.store import LinkModel, MemStore, SimS3Store
from repro.store.base import StoreError


class TestTrkProperty:
    @given(
        n_streamlines=st.integers(0, 20),
        n_props=st.integers(0, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_roundtrip(self, n_streamlines, n_props, seed):
        rng = np.random.default_rng(seed)
        sls = [
            (
                rng.normal(size=(int(rng.integers(1, 30)), 3)).astype(np.float32),
                rng.normal(size=n_props).astype(np.float32),
            )
            for _ in range(n_streamlines)
        ]
        raw = write_trk(sls, n_properties=n_props)
        assert len(raw) >= 1000
        reader = LazyTrkReader(io.BytesIO(raw))
        assert reader.header.n_count == n_streamlines
        got = list(reader.streamlines())
        assert len(got) == n_streamlines
        for (pts, props), sl in zip(sls, got):
            np.testing.assert_allclose(sl.points, pts, rtol=1e-5, atol=1e-5)
            np.testing.assert_array_equal(sl.properties, props)

    @given(affine_seed=st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_affine_roundtrips_in_header(self, affine_seed):
        rng = np.random.default_rng(affine_seed)
        affine = np.eye(4, dtype=np.float32)
        affine[:3, :] = rng.normal(size=(3, 4)).astype(np.float32)
        hdr = TrkHeader(n_count=0, n_properties=0, affine=affine)
        back = TrkHeader.from_bytes(hdr.to_bytes())
        np.testing.assert_array_equal(back.affine, affine)

    def test_bad_magic_rejected(self):
        with pytest.raises(ValueError):
            TrkHeader.from_bytes(b"XXXX" + b"\0" * 996)


class TestTokenProperty:
    @given(
        shard_sizes=st.lists(st.integers(1, 500), min_size=1, max_size=5),
        window=st.integers(1, 257),
        dtype=st.sampled_from([np.uint16, np.uint32]),
    )
    @settings(max_examples=25, deadline=None)
    def test_multi_shard_stream_preserves_token_order(self, shard_sizes,
                                                      window, dtype):
        rng = np.random.default_rng(42)
        shards = [
            rng.integers(0, np.iinfo(dtype).max, size=n).astype(dtype)
            for n in shard_sizes
        ]
        blob = b"".join(write_token_shard(s) for s in shards)
        reader = TokenStreamReader(io.BytesIO(blob), len(blob))
        out = []
        while True:
            w = reader.read_window(window)
            if w is None:
                break
            out.append(w)
        all_tokens = np.concatenate([s.astype(np.uint32) for s in shards])
        expect_windows = len(all_tokens) // window
        assert len(out) == expect_windows
        if out:
            got = np.concatenate(out)
            np.testing.assert_array_equal(
                got, all_tokens[: expect_windows * window]
            )

    def test_header_roundtrip(self):
        hdr = TokenShardHeader(count=12345, dtype=np.dtype(np.uint16))
        back = TokenShardHeader.from_bytes(hdr.to_bytes())
        assert back.count == 12345
        assert back.dtype == np.uint16


class TestLinkModel:
    def test_bandwidth_serializes_across_threads(self):
        """The shared link enforces aggregate bandwidth: N concurrent
        transfers take ~N x the single-transfer time."""
        link = LinkModel(latency_s=0.0, bandwidth_Bps=10e6)
        nbytes = 200_000  # 20 ms each at 10 MB/s

        def xfer():
            link.transfer(nbytes)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=xfer) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
        assert elapsed >= 4 * nbytes / 10e6 * 0.8

    def test_latency_overlaps_across_threads(self):
        link = LinkModel(latency_s=0.05, bandwidth_Bps=float("inf"))

        t0 = time.perf_counter()
        threads = [threading.Thread(target=lambda: link.transfer(10))
                   for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
        # Latencies overlap: nowhere near 8 x 50 ms.
        assert elapsed < 0.2

    def test_telemetry(self):
        link = LinkModel(latency_s=0.0, bandwidth_Bps=100e6)
        link.transfer(1000)
        link.transfer(2000)
        assert link.bytes_moved == 3000
        assert link.requests == 2
        assert abs(link.observed_bandwidth() - 100e6) / 100e6 < 0.5


class TestStoreEdgeCases:
    def test_missing_key_raises(self):
        store = SimS3Store()
        with pytest.raises(StoreError):
            store.size("nope")
        with pytest.raises(StoreError):
            store.get_range("nope", 0, 10)

    def test_range_reads(self):
        store = MemStore()
        store.put("k", bytes(range(100)))
        assert store.get_range("k", 10, 20) == bytes(range(10, 20))
        assert store.get_range("k", 90, 200) == bytes(range(90, 100))

    def test_dirstore_key_escape_rejected(self, tmp_path):
        from repro.store.local import DirStore

        store = DirStore(str(tmp_path))
        with pytest.raises(StoreError):
            store.put("../escape", b"x")
