"""Object-store protocol.

All remote data in the framework (training shards, `.trk` streamline files,
checkpoints) flows through this interface so that the simulated S3 store,
the real local-directory store, and any future real S3 binding are
interchangeable.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass


class StoreError(RuntimeError):
    """Permanent store failure (bad key, malformed range)."""


class TransientStoreError(StoreError):
    """Retryable failure (simulated network fault, throttling)."""


@dataclass(frozen=True)
class ObjectMeta:
    key: str
    size: int


class ObjectStore(abc.ABC):
    """Byte-range addressable object store."""

    @abc.abstractmethod
    def list_objects(self, prefix: str = "") -> list[ObjectMeta]:
        ...

    @abc.abstractmethod
    def size(self, key: str) -> int:
        ...

    @abc.abstractmethod
    def get_range(self, key: str, start: int, end: int) -> bytes:
        """Fetch bytes [start, end) of `key`. One call == one request
        (pays one latency)."""

    @abc.abstractmethod
    def put(self, key: str, data: bytes) -> None:
        ...

    @abc.abstractmethod
    def delete(self, key: str) -> None:
        ...

    def get(self, key: str) -> bytes:
        return self.get_range(key, 0, self.size(key))

    def exists(self, key: str) -> bool:
        try:
            self.size(key)
            return True
        except StoreError:
            return False
