"""Shared transformer layers: norms, RoPE, GQA attention, gated MLP.

Design notes for multi-pod scale:

* Attention shards its *KV-sequence* dimension over the tensor axis
  ("kv_seq" rule) rather than heads. Head counts across the assigned
  archs (96, 32, 9, 16, 20, 64, 24, 48) mostly do not divide a 16-way
  axis, while every assigned seq_len does; seq-sharding is uniform,
  always divisible, and keeps the O(S) score tensors distributed.
  Softmax/contractions over the sharded dim lower to LSE-style partial
  reductions + all-reduce under GSPMD (flash-decoding structure).
* Queries are processed in chunks via `lax.scan` (online, bounded memory)
  so 32k prefill and 4k train never materialize full S×S scores.
* All matmuls run in bf16 with fp32 softmax/norm accumulations.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.spec import ParamSpec
from repro.sharding.rules import constrain

COMPUTE_DTYPE = jnp.bfloat16


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def norm_spec(cfg: ModelConfig, dim: int | None = None) -> dict:
    d = dim if dim is not None else cfg.d_model
    if not cfg.parametric_norm:
        return {}
    spec = {"scale": ParamSpec((d,), (None,), "ones")}
    if cfg.norm_type == "layernorm" and cfg.norm_bias:
        spec["bias"] = ParamSpec((d,), (None,), "zeros")
    return spec


def apply_norm(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
    else:  # layernorm
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
    if p.get("scale") is not None:
        y = y * p["scale"].astype(jnp.float32)
    if p.get("bias") is not None:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Rotary position embedding
# --------------------------------------------------------------------------- #
def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D_h); positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int, offset: int = 0) -> jax.Array:
    pos = jnp.arange(offset, offset + seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((seq_len, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# --------------------------------------------------------------------------- #
# Embedding
# --------------------------------------------------------------------------- #
def embedding_spec(cfg: ModelConfig) -> dict:
    v = cfg.padded_vocab()
    spec = {"table": ParamSpec((v, cfg.d_model), ("tp", "fsdp"), ("normal", 0.02))}
    if not cfg.tie_embeddings:
        spec["out_table"] = ParamSpec(
            (v, cfg.d_model), ("tp", "fsdp"), ("normal", 0.02)
        )
    return spec


def embed_tokens(p: dict, cfg: ModelConfig, ids: jax.Array) -> jax.Array:
    x = jnp.take(p["table"], ids, axis=0).astype(COMPUTE_DTYPE)
    return x * jnp.asarray(cfg.embedding_multiplier, COMPUTE_DTYPE)


def output_table(p: dict) -> jax.Array:
    return p.get("out_table", p["table"])


# --------------------------------------------------------------------------- #
# Attention (GQA, RoPE, chunked online computation, KV cache)
# --------------------------------------------------------------------------- #
class KVCache(NamedTuple):
    k: jax.Array      # (B, S_max, H_kv, D_h)
    v: jax.Array
    length: jax.Array  # scalar int32: number of valid positions


def attention_spec(cfg: ModelConfig, cross: bool = False) -> dict:
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    spec = {
        "wq": ParamSpec((d, hq, dh), ("fsdp", "tp", None), ("fan_in", d)),
        "wk": ParamSpec((d, hkv, dh), ("fsdp", "tp", None), ("fan_in", d)),
        "wv": ParamSpec((d, hkv, dh), ("fsdp", "tp", None), ("fan_in", d)),
        "wo": ParamSpec((hq, dh, d), ("tp", None, "fsdp"), ("fan_in", hq * dh)),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamSpec((hq, dh), ("tp", None), "zeros")
        spec["bk"] = ParamSpec((hkv, dh), ("tp", None), "zeros")
        spec["bv"] = ParamSpec((hkv, dh), ("tp", None), "zeros")
    if cfg.out_bias:
        spec["bo"] = ParamSpec((d,), (None,), "zeros")
    if cfg.qk_norm:
        spec["q_norm"] = norm_spec(cfg, dh)
        spec["k_norm"] = norm_spec(cfg, dh)
    return spec


def _head_shardable(hq: int) -> bool:
    """True when the q-head count divides the tensor axis under the ambient
    rules — selects the collective-free head-sharded attention path."""
    from repro.sharding.rules import current_rules

    rules = current_rules()
    return (
        rules is not None
        and rules.mesh is not None
        and rules.resolve_dim("heads", hq) is not None
    )


def _attn_core(
    q: jax.Array,          # (B, S_q, H_q, D_h)
    k: jax.Array,          # (B, S_k, H_kv, D_h)
    v: jax.Array,
    *,
    causal: bool,
    q_offset,              # scalar: global position of q[0]
    kv_valid_len=None,     # scalar: mask kv positions >= this
    q_chunk: int = 512,
    allow_head_shard: bool = True,
) -> jax.Array:
    """Chunked online attention; never materializes S_q x S_k at once.

    Two internal sharding modes (§Perf, command-r train_4k):
      * head-sharded — KV expanded to q-heads by a shard-local gather, flat
        head dim over the tensor axis: zero intra-attention collectives;
      * kv_seq-sharded fallback — score/context tensors sharded along the
        KV sequence; softmax partials + output partial sums all-reduce.
    """
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = dh ** -0.5
    head_mode = allow_head_shard and _head_shardable(hq)
    kv_pos = jnp.arange(sk, dtype=jnp.int32)

    if head_mode:
        # Shard-local expansion: each model shard gathers the kv heads its
        # q-heads read (h // g), so k/v land head-sharded with no collective.
        idx = jnp.arange(hq, dtype=jnp.int32) // g
        k = constrain(jnp.take(k, idx, axis=2), "batch", None, "heads", None)
        v = constrain(jnp.take(v, idx, axis=2), "batch", None, "heads", None)
    else:
        k = constrain(k, "batch", "kv_seq", None, None)
        v = constrain(v, "batch", "kv_seq", None, None)

    def chunk_attn(q_c: jax.Array, offset) -> jax.Array:
        # q_c: (B, C, H_q, D_h); offset: global position of q_c[0]
        c = q_c.shape[1]
        mask = None
        if causal:
            q_pos = offset + jnp.arange(c, dtype=jnp.int32)
            mask = kv_pos[None, :] <= q_pos[:, None]          # (C, S_k)
        if kv_valid_len is not None:
            valid = (kv_pos < kv_valid_len)[None, :]
            mask = valid if mask is None else (mask & valid)

        if head_mode:
            qh = constrain(q_c, "batch", None, "heads", None)
            s = jnp.einsum(
                "bqhd,bshd->bhqs", qh, k, preferred_element_type=jnp.float32
            ) * scale
            s = constrain(s, "batch", "heads", None, None)
            if mask is not None:
                s = jnp.where(mask[None, None], s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum(
                "bhqs,bshd->bqhd", p.astype(v.dtype), v,
                preferred_element_type=v.dtype,
            )
            return o.astype(q.dtype)

        qg = q_c.reshape(b, c, hkv, g, dh)
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32
        ) * scale
        s = constrain(s, "batch", None, None, None, "kv_seq")
        if mask is not None:
            s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        # Output in compute dtype: the kv_seq-sharded contraction produces
        # partial sums that GSPMD all-reduces — emitting bf16 halves the
        # dominant collective payload (softmax itself stays fp32 above).
        o = jnp.einsum(
            "bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
            preferred_element_type=v.dtype,
        )
        return o.reshape(b, c, hq, dh).astype(q.dtype)

    if sq <= q_chunk:
        return chunk_attn(q, q_offset)
    assert sq % q_chunk == 0, (sq, q_chunk)
    n_chunks = sq // q_chunk
    q_chunks = q.reshape(b, n_chunks, q_chunk, hq, dh)

    # Remat each chunk: differentiating the scan would otherwise stash fp32
    # probabilities + masks for every chunk (flash-style recompute instead).
    chunk_attn_ckpt = jax.checkpoint(chunk_attn)

    def body(_, xs):
        q_c, idx = xs
        return None, chunk_attn_ckpt(q_c, q_offset + idx * q_chunk)

    _, out = jax.lax.scan(
        body, None, (q_chunks.swapaxes(0, 1), jnp.arange(n_chunks))
    )
    return out.swapaxes(0, 1).reshape(b, sq, hq, dh)


def attention(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,                  # (B, S, D)
    *,
    positions: jax.Array,          # (S,) or (B, S) global positions of x
    causal: bool = True,
    kv_source: jax.Array | None = None,   # cross-attention source (B, S_kv, D)
    cache: KVCache | None = None,
    update_cache: bool = False,    # decode: write new k/v into cache
    q_chunk: int = 512,
) -> tuple[jax.Array, KVCache | None]:
    cfg_rope = cfg.use_rope and kv_source is None
    b, s, _ = x.shape

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], cfg, q)

    if cache is not None and not update_cache:
        # Read-only cache (cross-attention at decode; precomputed KV).
        k, v, kv_len = cache.k, cache.v, cache.length
        new_cache = cache
    else:
        src = kv_source if kv_source is not None else x
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(x.dtype))
        if "bk" in p:
            k = k + p["bk"].astype(x.dtype)
            v = v + p["bv"].astype(x.dtype)
        if cfg.qk_norm:
            k = apply_norm(p["k_norm"], cfg, k)
        if cfg_rope:
            k = apply_rope(k, positions, cfg.rope_theta)
        if cache is not None:
            # Prefill/decode: append new K/V at cache.length.
            start = cache.length
            k_cache = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, start, 0, 0)
            )
            v_cache = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, start, 0, 0)
            )
            new_cache = KVCache(k_cache, v_cache, cache.length + s)
            k, v, kv_len = k_cache, v_cache, new_cache.length
        else:
            kv_len = None
            new_cache = None

    if cfg_rope:
        q = apply_rope(q, positions, cfg.rope_theta)

    q_offset = positions[0] if positions.ndim == 1 else positions[0, 0]
    out = _attn_core(
        q, k, v,
        causal=causal and kv_source is None,
        q_offset=q_offset,
        kv_valid_len=kv_len,
        q_chunk=q_chunk,
        # Cache-backed paths (prefill/decode) keep the serving KV layout
        # (kv_seq-sharded); the head-sharded mode serves training and
        # encoder/cross attention computed from source activations.
        allow_head_shard=cache is None,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if "bo" in p:
        y = y + p["bo"].astype(x.dtype)
    y = constrain(y, "batch", None, "residual")
    return y, new_cache


def compute_kv(p: dict, cfg: ModelConfig, src: jax.Array) -> KVCache:
    """Precompute a read-only KV cache from `src` (encoder states for
    cross-attention at decode time)."""
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(src.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(src.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(src.dtype)
        v = v + p["bv"].astype(src.dtype)
    if cfg.qk_norm:
        k = apply_norm(p["k_norm"], cfg, k)
    k = constrain(k, "batch", "kv_seq", None, None)
    v = constrain(v, "batch", "kv_seq", None, None)
    return KVCache(k=k, v=v, length=jnp.asarray(src.shape[1], jnp.int32))


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=COMPUTE_DTYPE, length: int = 0) -> KVCache:
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        length=jnp.asarray(length, jnp.int32),
    )


def cache_logical_axes() -> KVCache:
    from repro.models.spec import Ax

    return KVCache(
        k=Ax(("batch", "kv_seq", None, None)),
        v=Ax(("batch", "kv_seq", None, None)),
        length=None,
    )


# --------------------------------------------------------------------------- #
# MLP (gated or plain)
# --------------------------------------------------------------------------- #
def mlp_spec(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    spec = {
        "w_up": ParamSpec((d, f), ("fsdp", "tp"), ("fan_in", d)),
        "w_down": ParamSpec((f, d), ("tp", "fsdp"), ("fan_in", f)),
    }
    if cfg.glu:
        spec["w_gate"] = ParamSpec((d, f), ("fsdp", "tp"), ("fan_in", d))
    if cfg.out_bias:
        spec["b_up"] = ParamSpec((f,), ("tp",), "zeros")
        spec["b_down"] = ParamSpec((d,), (None,), "zeros")
    return spec


def _act(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def mlp(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    if "b_up" in p:
        h = h + p["b_up"].astype(x.dtype)
    if cfg.glu:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = _act(cfg, gate) * h
    else:
        h = _act(cfg, h)
    h = constrain(h, "batch", None, "tp")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    if "b_down" in p:
        y = y + p["b_down"].astype(x.dtype)
    return constrain(y, "batch", None, "residual")
