"""llava-next-mistral-7b — VLM; Mistral-7B backbone, anyres-tiling frontend.

Backbone: 32L, d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 32000.
The modality frontend (CLIP vision tower + anyres tiling + projector) is a
STUB per the assignment: `input_specs()` provides precomputed patch+text
embeddings of shape (batch, seq, d_model); the backbone consumes embeddings
directly (embed_inputs=True). [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        pattern=(BlockDef("attn", "dense"),),
        norm_type="rmsnorm",
        act="silu",
        glu=True,
        rope_theta=1000000.0,
        embed_inputs=True,
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    )
)
