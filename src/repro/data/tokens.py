"""Token-shard format for LM training data on object stores.

Shard = 64-byte header (magic, version, dtype code, token count) + packed
little-endian token payload. Designed for sequential streaming through
Rolling Prefetch: fixed-size records, no random access needed.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass

import numpy as np

HEADER_SIZE = 64
MAGIC = b"TOKS"
_HDR = struct.Struct("<4sIIQ")  # magic, version, dtype code, count
_DTYPES = {1: np.uint16, 2: np.uint32}
_DTYPE_CODES = {np.dtype(np.uint16): 1, np.dtype(np.uint32): 2}


@dataclass
class TokenShardHeader:
    count: int
    dtype: np.dtype
    version: int = 1

    def to_bytes(self) -> bytes:
        buf = bytearray(HEADER_SIZE)
        _HDR.pack_into(buf, 0, MAGIC, self.version,
                       _DTYPE_CODES[np.dtype(self.dtype)], self.count)
        return bytes(buf)

    @classmethod
    def from_bytes(cls, raw: bytes) -> "TokenShardHeader":
        magic, version, code, count = _HDR.unpack_from(raw, 0)
        if magic != MAGIC:
            raise ValueError(f"bad magic {magic!r}")
        return cls(count=count, dtype=np.dtype(_DTYPES[code]), version=version)


def write_token_shard(tokens: np.ndarray) -> bytes:
    tokens = np.asarray(tokens)
    if tokens.dtype not in (np.uint16, np.uint32):
        tokens = tokens.astype(np.uint32)
    hdr = TokenShardHeader(count=tokens.size, dtype=tokens.dtype)
    return hdr.to_bytes() + tokens.astype(tokens.dtype.newbyteorder("<")).tobytes()


def synth_token_shard(rng: np.random.Generator, n_tokens: int,
                      vocab: int = 50000) -> bytes:
    return write_token_shard(
        rng.integers(0, vocab, size=n_tokens, dtype=np.uint32)
    )


class TokenStreamReader:
    """Stream fixed-length (seq_len + 1) token windows from a concatenated
    multi-shard logical stream (each shard has its own header)."""

    def __init__(self, fileobj, total_size: int) -> None:
        self.f = fileobj
        self.total_size = total_size
        self._buf = np.empty(0, np.uint32)

    def _next_shard(self) -> bool:
        if self.f.tell() >= self.total_size:
            return False
        hdr = TokenShardHeader.from_bytes(self.f.read(HEADER_SIZE))
        payload = self.f.read(hdr.count * hdr.dtype.itemsize)
        tokens = np.frombuffer(payload, dtype=hdr.dtype.newbyteorder("<"))
        self._buf = np.concatenate([self._buf, tokens.astype(np.uint32)])
        return True

    def read_window(self, n: int) -> np.ndarray | None:
        while len(self._buf) < n:
            if not self._next_shard():
                return None
        out, self._buf = self._buf[:n], self._buf[n:]
        return out
