"""command-r-plus-104b — Cohere dense GQA transformer.

64L, d_model 12288, 96 q-heads / 8 kv-heads (head_dim 128), d_ff 33792,
vocab 256000. Cohere specifics: parallel attention+FFN block sharing one
input LayerNorm (no bias), no QKV bias, tied embeddings, logit scaling.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="command-r-plus-104b",
        family="dense",
        num_layers=64,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        d_ff=33792,
        vocab_size=256000,
        pattern=(BlockDef("attn", "dense"),),
        norm_type="layernorm",
        norm_bias=False,
        parallel_block=True,
        act="silu",
        glu=True,
        tie_embeddings=True,
        logit_scale=0.0625,
        use_rope=True,
        rope_theta=75000000.0,
        source="hf:CohereForAI/c4ai-command-r-v01",
    )
)
