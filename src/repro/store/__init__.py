from repro.store.base import (
    MultipartUpload,
    ObjectMeta,
    ObjectStore,
    StoreError,
    TransientStoreError,
)
from repro.store.link import LinkModel
from repro.store.sim_s3 import SimS3Store
from repro.store.local import DirStore, MemStore
from repro.store.tiers import (
    BlockMeta,
    CacheFlight,
    CacheIndex,
    CacheTier,
    DirTier,
    MemTier,
)

__all__ = [
    "BlockMeta",
    "CacheFlight",
    "CacheIndex",
    "MultipartUpload",
    "ObjectStore",
    "ObjectMeta",
    "StoreError",
    "TransientStoreError",
    "LinkModel",
    "SimS3Store",
    "DirStore",
    "MemStore",
    "CacheTier",
    "MemTier",
    "DirTier",
]
