"""Unified resilience layer: one RetryPolicy + Hedger for every store call.

At production scale the dominant failure mode of S3-backed workflows is
not hard errors but throttling (503 SlowDown), stalls, and partial
responses. Before this module the stack carried three hand-rolled retry
loops with unjittered ``2 ** attempt`` backoff — which *synchronizes*
concurrent streams: N streams tripped by the same transient fault all
sleep the same duration and re-collide at the same instant, a classic
retry storm. Every production call site (rolling + sequential engines,
write-behind `Writer`, checkpoint metadata) now resolves through this
single implementation:

  * `RetryPolicy` — frozen configuration: attempt cap, *full-jitter*
    exponential backoff (AWS architecture-blog recipe: sleep
    ``uniform(0, min(cap, base * 2**attempt))``), an optional per-reader
    retry *budget*, and an optional per-call wall-clock *deadline*;
  * `Retrier` — a thread-safe per-reader/per-writer executor of one
    policy. On `ThrottleError` it invokes ``on_throttle`` — the rolling
    engine wires that into its AIMD depth controller, closing the loop
    between backend pushback and prefetch concurrency;
  * `Hedger` — straggler hedging (duplicate a request that exceeds
    ``timeout_s``) with a max-hedges-in-flight cap, replacing the two
    copy-pasted ``_*_maybe_hedged`` implementations.

Retry and hedging compose as ``retrier.call(lambda: hedger.call(fn))``:
each retry attempt is independently hedged, and a hedged attempt's
timing is withheld from the autotuner (racing duplicates contaminate
the sample).
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable

from repro.store.base import (
    IntegrityError,
    StoreError,
    ThrottleError,
    TransientStoreError,
)


@dataclass(frozen=True)
class RetryPolicy:
    """How one logical store operation survives transient faults.

    ``max_retries`` bounds retries *per call* (``max_retries + 1``
    attempts); ``budget`` bounds retries across a `Retrier`'s lifetime —
    a reader drowning in faults stops burning time on retries once its
    budget is spent, instead of paying the full per-call cap on every
    block. ``deadline_s`` caps one call's wall clock: a retry whose
    backoff would land past the deadline is not taken.

    ``jitter="full"`` (the default) sleeps ``uniform(0, d)`` where
    ``d = min(backoff_cap_s, backoff_s * 2**attempt)``; ``"none"``
    sleeps exactly ``d`` — kept only for A/B benchmarks of the retry
    storms it causes.
    """

    max_retries: int = 3
    backoff_s: float = 0.05
    backoff_cap_s: float = 5.0
    jitter: str = "full"
    budget: int | None = None
    deadline_s: float | None = None

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}"
            )
        if self.backoff_s < 0 or self.backoff_cap_s < 0:
            raise ValueError("backoff durations must be >= 0")
        if self.jitter not in ("full", "none"):
            raise ValueError(
                f"jitter must be 'full' or 'none', got {self.jitter!r}"
            )
        if self.budget is not None and self.budget < 0:
            raise ValueError(f"budget must be >= 0, got {self.budget}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(
                f"deadline_s must be positive, got {self.deadline_s}"
            )

    def backoff(self, attempt: int, rng: random.Random) -> float:
        """Sleep before retry number ``attempt`` (0-based)."""
        cap = min(self.backoff_cap_s, self.backoff_s * (2 ** attempt))
        if self.jitter == "none":
            return cap
        return rng.uniform(0.0, cap)


class Retrier:
    """Thread-safe executor of a `RetryPolicy` for one reader/writer.

    Several streams of the same reader may call :meth:`call`
    concurrently; the shared state (jitter rng, remaining budget,
    telemetry counters) is lock-protected, everything else is per-call.

    ``on_retry(attempt, exc, pause_s)`` fires before each backoff sleep
    (stat counters); ``on_throttle()`` fires on every `ThrottleError` —
    including one the final attempt raises — so backend pushback reaches
    the depth controller even when no retry follows.
    """

    def __init__(
        self,
        policy: RetryPolicy | None = None,
        *,
        seed: int | None = None,
        on_retry: Callable[[int, Exception, float], None] | None = None,
        on_throttle: Callable[[], None] | None = None,
        sleep: Callable[[float], None] = time.sleep,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.policy = policy if policy is not None else RetryPolicy()
        self.on_retry = on_retry
        self.on_throttle = on_throttle
        self._sleep = sleep
        self._clock = clock
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._budget_left = self.policy.budget
        # Telemetry.
        self.retries = 0
        self.throttles = 0

    @property
    def budget_left(self) -> int | None:
        with self._lock:
            return self._budget_left

    def _next_backoff(self, attempt: int) -> float:
        with self._lock:
            return self.policy.backoff(attempt, self._rng)

    def _spend_budget(self) -> bool:
        with self._lock:
            if self._budget_left is None:
                return True
            if self._budget_left <= 0:
                return False
            self._budget_left -= 1
            return True

    def call(self, fn: Callable[[], Any], *, label: str = "store request"):
        """Run ``fn`` to completion under the policy. `TransientStoreError`
        (including `ThrottleError`) retries with backoff; anything else
        propagates untouched. On exhaustion raises `StoreError` chained
        from the last transient fault."""
        pol = self.policy
        deadline = (self._clock() + pol.deadline_s
                    if pol.deadline_s is not None else None)
        last: Exception | None = None
        reason = "gave up"
        for attempt in range(pol.max_retries + 1):
            try:
                return fn()
            except TransientStoreError as e:
                last = e
                if isinstance(e, ThrottleError):
                    with self._lock:
                        self.throttles += 1
                    if self.on_throttle is not None:
                        self.on_throttle()
                if attempt >= pol.max_retries:
                    reason = f"exhausted {pol.max_retries + 1} attempts"
                    break
                pause = self._next_backoff(attempt)
                if deadline is not None and self._clock() + pause > deadline:
                    reason = f"deadline {pol.deadline_s:g}s exceeded"
                    break
                if not self._spend_budget():
                    reason = f"retry budget ({pol.budget}) exhausted"
                    break
                with self._lock:
                    self.retries += 1
                if self.on_retry is not None:
                    self.on_retry(attempt, e, pause)
                self._sleep(pause)
        # Typed exhaustion: when the LAST fault was an integrity failure,
        # every authority we could reach handed back bytes that do not
        # match their digest — re-raise as IntegrityError so callers can
        # distinguish "the data is bad" from ordinary unavailability.
        err_cls = IntegrityError if isinstance(last, IntegrityError) else StoreError
        raise err_cls(f"{label}: {reason}") from last


class Hedger:
    """Straggler hedging around one request function, with a cap on
    concurrent hedges.

    :meth:`call` runs ``fn`` and, if it has not reported within
    ``timeout_s``, races a duplicate attempt and takes the first result
    that lands (requests are idempotent: range GETs, same-index part
    puts). At most ``max_in_flight`` hedge duplicates exist at any
    moment across all concurrent calls — past the cap a straggling
    primary is simply waited out, so a systemic slowdown (e.g. a
    throttled backend) cannot amplify itself with a thundering herd of
    duplicates. ``timeout_s=None`` disables hedging: ``fn`` runs inline
    with no extra thread.

    A failure propagates only once every launched attempt has reported,
    so a still-in-flight duplicate can rescue the call and no attempt
    thread outlives the raise.
    """

    def __init__(
        self,
        timeout_s: float | None,
        *,
        max_in_flight: int = 4,
        on_hedge: Callable[[], None] | None = None,
    ) -> None:
        if max_in_flight < 1:
            raise ValueError(
                f"max_in_flight must be >= 1, got {max_in_flight}"
            )
        self.timeout_s = timeout_s
        self.max_in_flight = max_in_flight
        self.on_hedge = on_hedge
        self._lock = threading.Lock()
        self._in_flight = 0
        # Telemetry (asserted by the chaos tests: hedges stay bounded).
        self.hedges = 0
        self.peak_in_flight = 0

    def _try_acquire(self) -> bool:
        with self._lock:
            if self._in_flight >= self.max_in_flight:
                return False
            self._in_flight += 1
            self.hedges += 1
            self.peak_in_flight = max(self.peak_in_flight, self._in_flight)
            return True

    def _release(self) -> None:
        with self._lock:
            self._in_flight -= 1

    def call(self, fn: Callable[[], Any]) -> tuple[Any, float | None]:
        """Returns ``(result, seconds)``. Seconds is the request's wall
        time when exactly one attempt ran, and ``None`` when a hedge
        fired — racing duplicates contaminate the timing, so hedged
        samples must never reach the autotuner."""
        if self.timeout_s is None:
            t0 = time.perf_counter()
            return fn(), time.perf_counter() - t0
        cond = threading.Condition()
        results: list[Any] = []
        errors: list[Exception] = []

        def attempt(hedge: bool) -> None:
            try:
                r = fn()
            except Exception as e:  # repro: allow[RP005] — propagated below
                with cond:
                    errors.append(e)
                    cond.notify_all()
            else:
                with cond:
                    results.append(r)
                    cond.notify_all()
            finally:
                if hedge:
                    self._release()

        # repro: allow[RP006] — attempts are daemons; call() returns only
        # after every launched attempt reported, so none outlives the raise.
        threading.Thread(target=attempt, args=(False,), daemon=True,
                         name="hedge-primary").start()
        launched = 1
        t0 = time.perf_counter()
        with cond:
            cond.wait_for(lambda: results or errors, timeout=self.timeout_s)
            want_hedge = not results and not errors
        if want_hedge and self._try_acquire():
            if self.on_hedge is not None:
                self.on_hedge()
            # repro: allow[RP006] — same lifecycle as the primary attempt.
            threading.Thread(target=attempt, args=(True,), daemon=True,
                             name="hedge-secondary").start()
            launched = 2
        with cond:
            # A success wins immediately; a failure only propagates once
            # every launched attempt has reported.
            cond.wait_for(lambda: results or len(errors) >= launched)
        if results:
            store_s = None if launched > 1 else time.perf_counter() - t0
            return results[0], store_s
        raise errors[0]
