from repro.serve.engine import Request, RequestResult, ServeEngine, ServeStats

__all__ = ["Request", "RequestResult", "ServeEngine", "ServeStats"]
