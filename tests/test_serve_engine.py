"""Serving-engine tests: wave batching, early retirement, correctness vs
single-request decoding."""

from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config
from repro.models import make_model
from repro.serve import Request, ServeEngine


def _setup(max_batch=4):
    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params, ServeEngine(model, params, max_batch=max_batch)


def test_batched_matches_single_request():
    """A wave of identical-length requests must produce the same tokens as
    serving each request alone."""
    cfg, model, params, engine = _setup(max_batch=3)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(3)]
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    batched = {r.rid: r.tokens for r in engine.run()}

    for i, p in enumerate(prompts):
        solo_engine = ServeEngine(model, params, max_batch=1)
        solo_engine.submit(Request(rid=0, prompt=p, max_new_tokens=6))
        solo = solo_engine.run()[0].tokens
        np.testing.assert_array_equal(batched[i], solo,
                                      err_msg=f"request {i} diverges in batch")


def test_length_bucketing_separates_waves():
    cfg, model, params, engine = _setup(max_batch=8)
    rng = np.random.default_rng(1)
    for i, n in enumerate([8, 8, 12, 8, 12]):
        engine.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, n).astype(np.int32), max_new_tokens=3))
    results = engine.run()
    assert len(results) == 5
    assert engine.stats.waves == 2  # one 8-length wave, one 12-length wave
    assert engine.stats.requests == 5


def test_eos_retires_early():
    cfg, model, params, engine = _setup(max_batch=2)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    # Find the greedy first token, then use it as EOS for one request.
    probe = ServeEngine(model, params, max_batch=1)
    probe.submit(Request(rid=0, prompt=prompt, max_new_tokens=1))
    first = probe.run()[0].tokens[0]

    engine.submit(Request(rid=0, prompt=prompt, max_new_tokens=10,
                          eos_id=int(first)))
    engine.submit(Request(rid=1, prompt=prompt, max_new_tokens=4))
    results = {r.rid: r for r in engine.run()}
    assert len(results[0].tokens) == 1          # stopped at EOS immediately
    assert len(results[1].tokens) == 4          # ran its full budget


def test_queue_drains_across_waves():
    cfg, model, params, engine = _setup(max_batch=2)
    rng = np.random.default_rng(3)
    for i in range(5):
        engine.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=2))
    results = engine.run()
    assert len(results) == 5
    assert engine.stats.waves == 3  # 2 + 2 + 1
    assert engine.stats.generated_tokens == sum(len(r.tokens) for r in results)
    assert engine.stats.tokens_per_s() > 0
