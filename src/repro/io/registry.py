"""Reader-engine registry: new engines plug in without touching call sites.

An engine is a factory ``(store, files, tiers, policy) -> Reader`` bound to
a name with ``@register_reader("name")``. `PrefetchFS` dispatches
``IOPolicy.engine`` through this table, so a real-S3, async, or sharded
engine lands by registering itself — loader, checkpoint restore, serving,
and benchmarks pick it up through the same `fs.open` they already call.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

# Factory signature: (store, files, tiers, policy) -> Reader
ReaderFactory = Callable[..., object]


@dataclass(frozen=True)
class EngineSpec:
    name: str
    factory: ReaderFactory
    needs_tiers: bool = False   # whether the FS must supply cache tiers
    accepts_tuner: bool = False  # factory takes a tuner= kwarg (closed loop)
    accepts_index: bool = False  # factory takes an index= kwarg (shared cache)


_REGISTRY: dict[str, EngineSpec] = {}


def register_reader(name: str, *, needs_tiers: bool = False,
                    accepts_tuner: bool = False,
                    accepts_index: bool = False):
    """Class/function decorator registering a reader engine factory.

    ``accepts_tuner`` engines receive the filesystem's `BlockSizeTuner`
    as a ``tuner=`` keyword and are expected to feed it observed request
    timings / compute gaps — that is the closed autotune loop.

    ``accepts_index`` engines receive the filesystem's shared `CacheIndex`
    as an ``index=`` keyword (None when the FS has no tiers): single-flight
    fetches, refcounted eviction, and warm cross-open/-restart reuse.
    """

    def deco(factory: ReaderFactory) -> ReaderFactory:
        if name in _REGISTRY:
            raise ValueError(f"reader engine {name!r} already registered")
        _REGISTRY[name] = EngineSpec(name=name, factory=factory,
                                     needs_tiers=needs_tiers,
                                     accepts_tuner=accepts_tuner,
                                     accepts_index=accepts_index)
        return factory

    return deco


def engine_spec(name: str) -> EngineSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown reader engine {name!r}; "
            f"available: {', '.join(available_engines())}"
        ) from None


def available_engines() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
