"""jamba-1.5-large-398b — hybrid Mamba+attention MoE.

72L, d_model 8192, 64 q-heads / 8 kv-heads on attention layers, d_ff 24576,
vocab 65536, MoE 16 experts top-2. Structure: 1 attention layer per 8
(1:7 attn:mamba interleave), MoE on every other layer.

Pattern period (8 blocks, repeated 9x) preserves both ratios exactly:
  [attn+moe, mamba, mamba+moe, mamba, mamba+moe, mamba, mamba+moe, mamba+dense... ]
Concretely: MoE on even in-period indices (4/8 = every other layer), the
single attention block leads each period (Jamba places it mid-period; the
ratio and adjacency structure are preserved, position within the period is
a documented simplification for scan-ability).

TPU adaptation note (DESIGN.md): Jamba uses Mamba-1 selective-scan blocks;
we use the Mamba-2 SSD formulation, whose chunked matmul structure maps to
the MXU (the published successor formulation — same state-space class).
[arXiv:2403.19887; hf]
"""

from repro.configs.base import BlockDef, ModelConfig, register

_PERIOD = (
    BlockDef("attn", "moe"),
    BlockDef("mamba", "dense"),
    BlockDef("mamba", "moe"),
    BlockDef("mamba", "dense"),
    BlockDef("mamba", "moe"),
    BlockDef("mamba", "dense"),
    BlockDef("mamba", "moe"),
    BlockDef("mamba", "dense"),
)

CONFIG = register(
    ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        pattern=_PERIOD,
        norm_type="rmsnorm",
        act="silu",
        glu=True,
        use_rope=False,  # Jamba uses no positional encoding on attn layers
        moe_num_experts=16,
        moe_top_k=2,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_ngroups=1,
        ssm_chunk=256,
        ssm_conv_kernel=4,
        source="arXiv:2403.19887",
    )
)
