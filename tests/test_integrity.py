"""End-to-end block integrity (PR tentpole + satellites).

Covers: the digest helpers (`repro.io.integrity`), verified store reads
(the wrapper stores attest authoritative bytes, not mangled ones),
`DirTier` steady-state rot detection (the post-recovery regression),
`CacheIndex.quarantine` semantics, engine self-healing under corruption
chaos for both engines and all three ``IOPolicy.verify`` modes,
checkpoint manifest digests, and the acceptance scenario: simultaneous
store-read corruption, at-rest tier rot, and peer-frame corruption with
byte-identical reads and zero `IntegrityError`s surfaced."""

from __future__ import annotations

import zlib

import pytest

from repro.core.rolling import RollingPrefetcher, RollingPrefetchFile
from repro.core.sequential import SequentialFile
from repro.io import IOPolicy, PrefetchFS
from repro.io.integrity import (
    IntegrityError,
    block_digest,
    check_block,
    crc_digest,
    digest_matches,
)
from repro.io.retry import RetryPolicy
from repro.store import (
    BlockMeta,
    CacheIndex,
    DirTier,
    FaultSchedule,
    FaultyStore,
    MemStore,
    MemTier,
)
from repro.store.base import ObjectMeta, StoreError

RETRY = RetryPolicy(max_retries=10, backoff_s=0.001, backoff_cap_s=0.01)


def payload(n: int, seed: int = 0) -> bytes:
    return bytes((i * 31 + seed * 7) % 256 for i in range(n))


def make_store(objects: dict[str, bytes]) -> MemStore:
    store = MemStore()
    for k, v in objects.items():
        store.put(k, v)
    return store


def metas(store) -> list[ObjectMeta]:
    inner = getattr(store, "inner", store)
    return inner.list_objects()


# --------------------------------------------------------------------------- #
# digest helpers
# --------------------------------------------------------------------------- #
class TestDigestHelpers:
    def test_crc32_format_matches_zlib(self):
        data = payload(1000)
        assert block_digest(data) == f"crc32:{zlib.crc32(data) & 0xFFFFFFFF:08x}"

    def test_crc_digest_agrees_with_block_digest(self):
        data = payload(333, seed=4)
        assert crc_digest(zlib.crc32(data)) == block_digest(data)

    def test_blake2_format(self):
        d = block_digest(b"hello", algo="blake2")
        assert d.startswith("blake2:") and len(d.split(":", 1)[1]) == 32

    def test_unknown_algo_rejected(self):
        with pytest.raises(ValueError):
            block_digest(b"x", algo="md5")

    def test_check_block_none_digest_is_noop(self):
        check_block(b"anything", None)   # pre-digest producers verify nothing

    def test_check_block_mismatch_raises_with_context(self):
        good = payload(64)
        dig = block_digest(good)
        with pytest.raises(IntegrityError, match="blk@0-64"):
            check_block(good[:-1] + b"\x00", dig, what="blk@0-64")

    def test_digest_matches_fails_closed_on_garbage(self):
        data = b"abc"
        assert digest_matches(data, block_digest(data))
        for junk in ("", "crc32", "crc32:zzzz", "sha9000:00", "crc32:"):
            assert not digest_matches(data, junk)

    def test_memoryview_accepted(self):
        data = payload(128)
        assert block_digest(memoryview(data)) == block_digest(data)
        check_block(memoryview(data), block_digest(data))


# --------------------------------------------------------------------------- #
# verified store reads
# --------------------------------------------------------------------------- #
class TestVerifiedReads:
    def test_default_verified_reads_attest_returned_bytes(self):
        store = make_store({"k": payload(4096)})
        data, dig = store.get_range_verified("k", 100, 600)
        assert data == payload(4096)[100:600]
        check_block(data, dig)
        pairs = store.get_ranges_verified("k", [(0, 10), (10, 50)])
        for d, g in pairs:
            check_block(d, g)

    def test_digest_range_matches_block_digest(self):
        store = make_store({"k": payload(2048)})
        assert store.digest_range("k", 64, 512) == block_digest(
            payload(2048)[64:512])

    def test_faulty_store_digest_attests_inner_bytes(self):
        """THE detection contract: a corrupting wrapper must hand out the
        digest of the authoritative bytes, so the mangled payload fails
        its own attestation instead of sailing through."""
        store = FaultyStore(
            make_store({"k": payload(4096)}),
            FaultSchedule(seed=3).corrupt(ops=("get_range",), times=1))
        data, dig = store.get_range_verified("k", 0, 4096)
        assert data != payload(4096)           # the fault landed...
        assert dig == block_digest(payload(4096))   # ...the digest did not
        with pytest.raises(IntegrityError):
            check_block(data, dig)
        # The next read is clean and self-consistent.
        data, dig = store.get_range_verified("k", 0, 4096)
        check_block(data, dig)

    def test_faulty_store_vectorized_corruption_detected(self):
        store = FaultyStore(
            make_store({"k": payload(8192)}),
            FaultSchedule(seed=5).corrupt(ops=("get_ranges",), times=1))
        pairs = store.get_ranges_verified("k", [(0, 4096), (4096, 8192)])
        bad = [not digest_matches(d, g) for d, g in pairs]
        assert any(bad)                        # last span got mangled
        assert not all(bad)                    # earlier spans stayed honest


# --------------------------------------------------------------------------- #
# DirTier at-rest rot (satellite: post-recovery reads were unchecked)
# --------------------------------------------------------------------------- #
class TestDirTierRot:
    def _flip_on_disk(self, tier: DirTier, bid: str) -> None:
        path = tier._path(bid)
        with open(path, "r+b") as f:
            raw = bytearray(f.read())
            raw[len(raw) // 2] ^= 0xFF
            f.seek(0)
            f.write(raw)

    def test_rot_after_write_raises_integrity_error(self, tmp_path):
        tier = DirTier(1 << 20, root=str(tmp_path / "t"))
        data = payload(512)
        tier.write("blk@0-512", data, meta=BlockMeta(key="blk", offset=0))
        assert tier.read("blk@0-512") == data
        self._flip_on_disk(tier, "blk@0-512")
        with pytest.raises(IntegrityError):
            tier.read("blk@0-512")

    def test_rot_after_recovery_regression(self, tmp_path):
        """Regression: recovery has always crc-checked blocks, but a
        block that rotted AFTER recovery was served as-is for the life of
        the process. Steady-state reads now recompute the journal crc."""
        root = str(tmp_path / "t")
        tier = DirTier(1 << 20, root=root)
        data = payload(1024, seed=2)
        tier.write("k@0-1024", data, meta=BlockMeta(key="k", offset=0))
        tier.close()

        tier2 = DirTier(1 << 20, root=root)
        assert tier2.recovered_blocks == 1
        assert tier2.read("k@0-1024") == data   # recovered AND clean
        self._flip_on_disk(tier2, "k@0-1024")
        with pytest.raises(IntegrityError):
            tier2.read("k@0-1024")              # rotted post-recovery

    def test_partial_reads_not_coverable_by_journal_crc(self, tmp_path):
        # The journal crc covers the full block; a sliced read cannot be
        # checked against it, which is why engines under verify promote
        # backward-seek hits to full-block reads.
        tier = DirTier(1 << 20, root=str(tmp_path / "t"))
        data = payload(512)
        tier.write("b@0-512", data, meta=BlockMeta(key="b", offset=0))
        self._flip_on_disk(tier, "b@0-512")
        assert len(tier.read("b@0-512", 0, 10)) == 10   # served unchecked
        with pytest.raises(IntegrityError):
            tier.read("b@0-512")                        # full read: caught

    def test_verify_reads_off_serves_rot(self, tmp_path):
        tier = DirTier(1 << 20, root=str(tmp_path / "t"), verify_reads=False)
        data = payload(256)
        tier.write("b@0-256", data, meta=BlockMeta(key="b", offset=0))
        self._flip_on_disk(tier, "b@0-256")
        assert tier.read("b@0-256") != data   # the documented escape hatch

    def test_flip_at_rest_fault_hook(self, tmp_path):
        tier = DirTier(1 << 20, root=str(tmp_path / "t"),
                       faults=FaultSchedule(seed=7).flip_at_rest(times=1))
        data = payload(512, seed=3)
        tier.write("b@0-512", data, meta=BlockMeta(key="b", offset=0))
        with pytest.raises(IntegrityError):
            tier.read("b@0-512")
        # The rule fired once; after quarantine+rewrite the block is fine.
        tier.delete("b@0-512")
        tier.write("b@0-512", data, meta=BlockMeta(key="b", offset=0))
        assert tier.read("b@0-512") == data

    def test_digest_of_matches_helper(self, tmp_path):
        tier = DirTier(1 << 20, root=str(tmp_path / "t"))
        data = payload(300)
        tier.write("b@0-300", data, meta=BlockMeta(key="b", offset=0))
        assert tier.digest_of("b@0-300") == block_digest(data)


# --------------------------------------------------------------------------- #
# quarantine semantics
# --------------------------------------------------------------------------- #
class TestQuarantine:
    def test_quarantine_evicts_and_counts(self):
        tiers = [MemTier(1 << 20)]
        index = CacheIndex(tiers, keep_cached=True)
        kind, fl = index.acquire("b@0-4")
        assert kind == "leader"
        tiers[0].write("b@0-4", b"data")
        index.publish(fl, tiers[0], 4, digest=block_digest(b"data"))
        assert index.contains("b@0-4")
        assert index.digest_of("b@0-4") == block_digest(b"data")

        assert index.quarantine("b@0-4")
        assert not index.contains("b@0-4")
        assert not tiers[0].contains("b@0-4")   # tier copy deleted too
        assert index.snapshot()["quarantined"] == 1
        assert not index.quarantine("b@0-4")    # second call: nothing left

    def test_quarantine_ignores_pins(self):
        tiers = [MemTier(1 << 20)]
        index = CacheIndex(tiers, keep_cached=True)
        kind, fl = index.acquire("b@0-4")
        assert kind == "leader"
        tiers[0].write("b@0-4", b"data")
        index.publish(fl, tiers[0], 4)
        # publish leaves the leader pin; quarantine must not wait on it —
        # every pinned reader would read the same corrupt bytes.
        assert index.quarantine("b@0-4")
        index.unpin("b@0-4")                    # late unpin is a no-op

    def test_recovered_dir_tier_primes_digests(self, tmp_path):
        root = str(tmp_path / "t")
        tier = DirTier(1 << 20, root=root)
        data = payload(400)
        tier.write("k@0-400", data, meta=BlockMeta(key="k", offset=0))
        tier.close()
        tier2 = DirTier(1 << 20, root=root)
        index = CacheIndex([tier2], keep_cached=True)
        assert index.contains("k@0-400")
        assert index.digest_of("k@0-400") == block_digest(data)


# --------------------------------------------------------------------------- #
# engine healing under corruption chaos
# --------------------------------------------------------------------------- #
class TestEngineHealing:
    def _objects(self):
        return {f"f{i}": payload(20_000, seed=i) for i in range(3)}

    @pytest.mark.parametrize("verify", ["edges", "full"])
    def test_rolling_heals_store_corruption(self, verify):
        objects = self._objects()
        store = FaultyStore(
            make_store(objects),
            FaultSchedule(seed=11).corrupt(
                ops=("get_range", "get_ranges"), prob=0.1))
        want = b"".join(objects[m.key] for m in metas(store))
        pf = RollingPrefetcher(store, metas(store), [MemTier(1 << 20)],
                               blocksize=4096, retry=RETRY,
                               eviction_interval_s=0.01, verify=verify)
        f = RollingPrefetchFile(pf)
        assert f.read() == want            # byte-identical, zero errors
        f.close()
        assert pf.stats.integrity_failures > 0   # chaos landed + detected
        assert pf.stats.retries > 0              # healed by re-fetch
        assert pf.stats.blocks_verified > 0

    def test_sequential_heals_store_corruption(self):
        objects = self._objects()
        store = FaultyStore(
            make_store(objects),
            FaultSchedule(seed=13).corrupt(
                ops=("get_range", "get_ranges"), prob=0.25))
        want = b"".join(objects[m.key] for m in metas(store))
        f = SequentialFile(store, metas(store), blocksize=4096, retry=RETRY)
        assert f.read() == want
        assert f.stats.integrity_failures > 0
        f.close()

    def test_verify_off_trusts_the_wire(self):
        """The zero-overhead baseline stays selectable — and therefore
        stays vulnerable, which is the A/B the benchmark quantifies."""
        objects = {"a": payload(8192)}
        store = FaultyStore(
            make_store(objects),
            FaultSchedule(seed=3).corrupt(ops=("get_range", "get_ranges"),
                                          times=1))
        f = SequentialFile(store, metas(store), blocksize=8192,
                           retry=RETRY, verify="off")
        assert f.read() != objects["a"]    # corruption sailed through
        assert f.stats.integrity_failures == 0
        f.close()

    def test_rolling_heals_at_rest_rot_on_cached_read(self, tmp_path):
        """A cached block rots in the DirTier between reads: the re-read
        detects (journal crc), quarantines, and transparently re-fetches
        from the store."""
        objects = {"a": payload(32_768)}
        store = make_store(objects)
        tier = DirTier(1 << 20, root=str(tmp_path / "t"),
                       faults=FaultSchedule(seed=17).flip_at_rest(prob=0.3))
        pf = RollingPrefetcher(store, metas(store), [tier], blocksize=4096,
                               retry=RETRY, eviction_interval_s=10.0,
                               verify="edges")
        f = RollingPrefetchFile(pf)
        assert f.read() == objects["a"]    # populate the cache
        for _ in range(4):                 # rot fires on later reads
            f.seek(0)
            assert f.read() == objects["a"]
        f.close()
        assert pf.stats.integrity_failures > 0
        assert pf.index.snapshot()["quarantined"] > 0

    def test_unhealable_corruption_raises_typed_error(self):
        """EVERY store response corrupt: retries exhaust and the caller
        gets the typed IntegrityError, not a silent wrong read."""
        objects = {"a": payload(4096)}
        store = FaultyStore(
            make_store(objects),
            FaultSchedule(seed=19).corrupt(ops=("get_range", "get_ranges"),
                                           prob=1.0))
        f = SequentialFile(store, metas(store), blocksize=4096,
                           retry=RetryPolicy(max_retries=2, backoff_s=0.0))
        with pytest.raises(IntegrityError):
            f.read()
        f.close()

    def test_unhealable_corruption_stays_typed_in_rolling(self):
        """Same guarantee through the rolling reader: the scheduler-side
        failure must not be re-wrapped into a generic StoreError."""
        objects = {"a": payload(4096)}
        store = FaultyStore(
            make_store(objects),
            FaultSchedule(seed=19).corrupt(ops=("get_range", "get_ranges"),
                                           prob=1.0))
        pf = RollingPrefetcher(store, metas(store), [MemTier(1 << 20)],
                               blocksize=4096,
                               retry=RetryPolicy(max_retries=2, backoff_s=0.0),
                               eviction_interval_s=10.0, verify="edges")
        f = RollingPrefetchFile(pf)
        with pytest.raises(IntegrityError):
            f.read()
        f.close()

    def test_policy_verify_reaches_engines(self):
        with pytest.raises(ValueError):
            IOPolicy(verify="paranoid")
        objects = {"a": payload(4096)}
        store = FaultyStore(
            make_store(objects),
            FaultSchedule(seed=3).corrupt(ops=("get_range", "get_ranges"),
                                          times=1))
        fs = PrefetchFS(store, policy=IOPolicy(
            engine="rolling", blocksize=2048, retry=RETRY,
            eviction_interval_s=0.01, verify="edges"))
        with fs:
            with fs.open_many(metas(store)) as f:
                assert f.read() == objects["a"]
            snap = fs.stats().snapshot()
        assert snap["integrity"]["failures"] > 0
        assert snap["integrity"]["blocks_verified"] > 0


# --------------------------------------------------------------------------- #
# checkpoint manifest digests
# --------------------------------------------------------------------------- #
class TestCheckpointDigests:
    def _state(self):
        import numpy as np

        return {"w": np.arange(4096, dtype=np.float32).reshape(64, 64),
                "b": np.ones((513,), dtype=np.float32)}

    def test_manifest_carries_per_leaf_digests(self):
        import json

        from repro.ckpt.manager import save_checkpoint

        store = MemStore()
        save_checkpoint(store, "ckpt", 1, self._state(),
                        policy=IOPolicy(blocksize=4096))
        manifests = [m.key for m in store.list_objects()
                     if m.key.endswith("MANIFEST.json")]
        assert manifests
        manifest = json.loads(store.get(manifests[0]))
        assert manifest["leaves"]
        for entry in manifest["leaves"]:
            assert entry["digest"].startswith("crc32:")

    def test_restore_detects_rotted_leaf(self):
        import numpy as np

        from repro.ckpt.manager import restore_checkpoint, save_checkpoint

        store = MemStore()
        state = self._state()
        save_checkpoint(store, "ckpt", 2, state,
                        policy=IOPolicy(blocksize=4096))
        # Rot one leaf object at rest, self-consistently: the store now
        # honestly serves wrong bytes, so only the manifest digest — the
        # attestation minted at save time — can catch it.
        leaf = next(m.key for m in store.list_objects()
                    if m.key.endswith(".raw"))
        raw = bytearray(store.get(leaf))
        raw[len(raw) // 2] ^= 0xFF
        store.put(leaf, bytes(raw))
        with pytest.raises(IntegrityError, match="checkpoint leaf"):
            restore_checkpoint(store, "ckpt", state,
                               policy=IOPolicy(blocksize=4096))
        # verify="off" restores the rot without complaint (the baseline).
        restored, _ = restore_checkpoint(store, "ckpt", state,
                                         policy=IOPolicy(blocksize=4096,
                                                         verify="off"))
        assert any(
            not np.array_equal(np.asarray(restored[k]), state[k])
            for k in state)

    def test_roundtrip_under_transit_corruption(self):
        import numpy as np

        from repro.ckpt.manager import restore_checkpoint, save_checkpoint

        store = FaultyStore(
            MemStore(),
            FaultSchedule(seed=23).corrupt(
                ops=("get_range", "get_ranges"), prob=0.1))
        state = self._state()
        pol = IOPolicy(blocksize=4096, retry=RETRY)
        save_checkpoint(store, "ckpt", 3, state, policy=pol)
        restored, manifest = restore_checkpoint(store, "ckpt", state,
                                                policy=pol)
        assert manifest["step"] == 3
        for k in state:
            np.testing.assert_array_equal(np.asarray(restored[k]), state[k])


# --------------------------------------------------------------------------- #
# acceptance: simultaneous corruption on every path
# --------------------------------------------------------------------------- #
class TestAcceptanceChaos:
    def test_all_paths_corrupting_at_once_single_host(self, tmp_path):
        """Store reads corrupt at ~5%, the local DirTier rots blocks at
        rest, and the read + checkpoint round trips stay byte-identical
        with ZERO IntegrityErrors surfaced to callers."""
        import numpy as np

        from repro.ckpt.manager import restore_checkpoint, save_checkpoint

        objects = {f"s{i}": payload(24_576, seed=i) for i in range(3)}
        store = FaultyStore(
            make_store(objects),
            FaultSchedule(seed=29).corrupt(
                ops=("get_range", "get_ranges", "get"), prob=0.05))
        want = b"".join(objects[m.key] for m in metas(store))
        tier = DirTier(4 << 20, root=str(tmp_path / "t"),
                       faults=FaultSchedule(seed=31).flip_at_rest(prob=0.05))
        pf = RollingPrefetcher(store, metas(store), [tier], blocksize=4096,
                               retry=RETRY, eviction_interval_s=10.0,
                               verify="edges")
        f = RollingPrefetchFile(pf)
        assert f.read() == want
        f.seek(0)
        assert f.read() == want            # cached pass, with at-rest rot
        f.close()
        assert pf.stats.integrity_failures > 0

        state = {"w": np.arange(8192, dtype=np.float32).reshape(128, 64)}
        pol = IOPolicy(blocksize=4096, retry=RETRY)
        save_checkpoint(store, "ckpt", 9, state, policy=pol)
        restored, manifest = restore_checkpoint(store, "ckpt", state,
                                                policy=pol)
        assert manifest["step"] == 9
        np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])

    def test_peer_frame_corruption_heals_cluster_wide(self):
        """Peer BLOCK frames corrupt in transit AND the backing store
        corrupts reads: every host's bytes stay exact."""
        import threading

        from repro.peer.sim import SimCluster

        objects = {f"p{i}": payload(16_384, seed=i) for i in range(3)}
        backing = FaultyStore(
            make_store(objects),
            FaultSchedule(seed=37).corrupt(
                ops=("get_range", "get_ranges"), prob=0.05))
        peer_faults = FaultSchedule(seed=41).corrupt(ops=("peer_fetch",),
                                                     prob=0.25)
        cluster = SimCluster(3, backing, faults=peer_faults)
        try:
            want = b"".join(objects[k] for k in sorted(objects))
            outs, errors = {}, []

            def run(h):
                try:
                    host = cluster.host(h)
                    fs = host.open_fs(IOPolicy(
                        engine="rolling", blocksize=4096, depth=2,
                        keep_cached=True, retry=RETRY,
                        eviction_interval_s=0.05))
                    files = sorted(host.store.list_objects(),
                                   key=lambda m: m.key)
                    with fs.open_many(files) as f:
                        outs[h] = f.read()
                except BaseException as e:  # repro: allow[RP005] — stashed; asserted after join
                    errors.append((h, e))

            threads = [threading.Thread(target=run, args=(h,))
                       for h in range(3)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not errors, errors
            for h in range(3):
                assert outs[h] == want, f"host {h} diverged"
            # The chaos was DETECTED (frame digests at clients, store
            # attestation at owner-fetching servers), not just absent.
            detected = sum(
                c.integrity_failures
                for h in range(3)
                for c in cluster.host(h).group._clients.values())
            detected += sum(cluster.host(h).server.integrity_failures
                            for h in range(3))
            assert detected > 0
            assert peer_faults.total_fired() > 0
        finally:
            cluster.close()
