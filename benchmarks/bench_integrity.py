"""Integrity A/B benchmarks: what verify-on-read costs, and what healing
under live corruption costs — on the scaled-Table-I simulated S3 store.

Two experiments:

  * ``overhead`` — the rolling engine streams the bandwidth-bound sims3
    scenario at each ``IOPolicy.verify`` level (off / edges / full),
    interleaved repetitions, median wall time. Acceptance (full run):
    "edges" — the default — costs < 5% read throughput vs "off"; the
    digests are crc32 over bytes the engine already holds, so the link's
    latency and bandwidth dominate.
  * ``healing`` — the same read with a `FaultSchedule` corrupting ~1% of
    store responses, verify="edges". Every corruption is detected at the
    fetch boundary and healed by the retry layer. Acceptance: bytes are
    identical to the clean run, zero `IntegrityError`s surface, and the
    healing premium (wall-time delta vs clean at the same verify level,
    divided by the number of detections) is reported as the per-repair
    latency.

Emits ``name,us_per_call,derived`` CSV rows and writes the full record
to ``BENCH_integrity.json`` so CI tracks the verify tax over time.

  PYTHONPATH=src python -m benchmarks.bench_integrity [--smoke]
"""

from __future__ import annotations

import argparse
import json
import time

from benchmarks.common import (
    S3_BW,
    S3_LATENCY,
    emit,
    fresh_store,
    make_trk_dataset,
)
from repro.io import IOPolicy, PrefetchFS, RetryPolicy
from repro.store import FaultSchedule, FaultyStore

RETRY = RetryPolicy(max_retries=10, backoff_s=0.002, backoff_cap_s=0.05)


def _read_once(ds, want: bytes, verify: str, *, blocksize: int,
               faults: FaultSchedule | None = None) -> dict:
    store = fresh_store(ds)
    if faults is not None:
        store = FaultyStore(store, faults)
    policy = IOPolicy(engine="rolling", blocksize=blocksize, depth=2,
                      retry=RETRY, eviction_interval_s=0.05, verify=verify)
    t0 = time.perf_counter()
    with PrefetchFS(store, policy=policy) as fs:
        f = fs.open_many(ds.metas())
        data = f.read()
        f.close()
        snap = fs.stats().snapshot()
    dt = time.perf_counter() - t0
    assert data == want, f"verify={verify}: bytes differ"
    return dict(
        wall_s=dt,
        goodput_MBps=ds.total_bytes / dt / 1e6,
        verified=snap["integrity"]["blocks_verified"],
        failures=snap["integrity"]["failures"],
        retries=snap["totals"].get("retries", 0),
    )


# --------------------------------------------------------------------------- #
# experiment 1: the verify tax (off vs edges vs full)
# --------------------------------------------------------------------------- #
def bench_overhead(n_files: int, blocksize: int, reps: int) -> dict:
    ds = make_trk_dataset(n_files)
    want = b"".join(v for _, v in sorted(ds.objects.items()))
    modes = ("off", "edges", "full")
    # Interleaved repetitions + median: back-to-back reps of one arm are
    # hostage to machine-load drift on a shared box.
    samples: dict[str, list[dict]] = {m: [] for m in modes}
    for _ in range(reps):
        for m in modes:
            samples[m].append(_read_once(ds, want, m, blocksize=blocksize))

    def median(mode: str) -> dict:
        runs = sorted(samples[mode], key=lambda r: r["wall_s"])
        med = dict(runs[len(runs) // 2])
        med["reps"] = [r["wall_s"] for r in runs]
        return med

    out = {m: median(m) for m in modes}
    base = out["off"]["wall_s"]
    for m in modes:
        overhead = out[m]["wall_s"] / base - 1.0
        out[m]["overhead_vs_off"] = overhead
        emit(f"integrity_verify_{m}", out[m]["wall_s"] * 1e6,
             f"goodput={out[m]['goodput_MBps']:.1f}MBps;"
             f"overhead={overhead * 100:+.1f}%;"
             f"verified={out[m]['verified']}")
    return dict(modes=out,
                params=dict(n_files=n_files, blocksize=blocksize,
                            dataset_bytes=ds.total_bytes, reps=reps))


# --------------------------------------------------------------------------- #
# experiment 2: healing latency under ~1% corruption
# --------------------------------------------------------------------------- #
def bench_healing(n_files: int, blocksize: int, rate: float) -> dict:
    ds = make_trk_dataset(n_files)
    want = b"".join(v for _, v in sorted(ds.objects.items()))
    clean = _read_once(ds, want, "edges", blocksize=blocksize)
    chaotic = _read_once(
        ds, want, "edges", blocksize=blocksize,
        faults=FaultSchedule(seed=17).corrupt(
            ops=("get_range", "get_ranges"), prob=rate))
    healed = chaotic["failures"]
    premium_s = max(0.0, chaotic["wall_s"] - clean["wall_s"])
    per_repair_ms = premium_s / healed * 1e3 if healed else 0.0
    emit("integrity_healing", chaotic["wall_s"] * 1e6,
         f"healed={healed};per_repair_ms={per_repair_ms:.2f};"
         f"goodput={chaotic['goodput_MBps']:.1f}MBps")
    # Detection is binary: a corrupt response NEVER reaches the caller
    # (the byte-identity assert in _read_once), and each detection is
    # matched by at least one retry.
    assert chaotic["retries"] >= healed
    return dict(clean=clean, chaotic=chaotic, healed=healed,
                per_repair_ms=per_repair_ms,
                params=dict(n_files=n_files, blocksize=blocksize,
                            corrupt_rate=rate,
                            dataset_bytes=ds.total_bytes))


def main(quick: bool = False, out: str = "BENCH_integrity.json") -> None:
    if quick:
        overhead = bench_overhead(n_files=2, blocksize=32 << 10, reps=1)
        healing = bench_healing(n_files=2, blocksize=32 << 10, rate=0.05)
    else:
        overhead = bench_overhead(n_files=6, blocksize=64 << 10, reps=3)
        healing = bench_healing(n_files=6, blocksize=64 << 10, rate=0.01)
        # Full-run acceptance: the default posture is effectively free on
        # the bandwidth-bound scenario — "edges" within 5% of "off".
        assert overhead["modes"]["edges"]["overhead_vs_off"] < 0.05, overhead

    record = dict(
        overhead=overhead,
        healing=healing,
        link=dict(latency_s=S3_LATENCY, bandwidth_Bps=S3_BW),
        smoke=bool(quick),
    )
    with open(out, "w") as f:
        json.dump(record, f, indent=2)
    print(
        f"wrote {out}: edges overhead "
        f"{overhead['modes']['edges']['overhead_vs_off'] * 100:+.1f}% vs off, "
        f"full {overhead['modes']['full']['overhead_vs_off'] * 100:+.1f}%, "
        f"healed {healing['healed']} corruptions at "
        f"{healing['per_repair_ms']:.2f} ms each"
    )


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_integrity.json")
    args = ap.parse_args()
    main(quick=args.smoke, out=args.out)


if __name__ == "__main__":
    _cli()
