"""End-to-end training driver: object-store data -> Rolling Prefetch ->
device feed -> pjit train step -> async checkpoints -> crash-safe resume.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 100 --batch 8 --seq-len 256 --mode rolling

On this CPU container the default is the reduced config; pass --full to
train the assigned full architecture (mesh sharding engages when multiple
devices exist). Every substrate here is the production path — the same
loader, checkpoint manager, and restart logic the multi-pod job uses.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.ckpt.manager import CheckpointManager, latest_step, restore_checkpoint
from repro.configs import get_config
from repro.data import DataCursor, LoaderConfig, PrefetchingDataLoader, synth_token_shard
from repro.data.loader import DeviceFeeder
from repro.io import IOPolicy, open_store
from repro.launch.mesh import mesh_host_shard
from repro.models import make_model
from repro.store import LinkModel, MemTier, SimS3Store
from repro.train import (
    AdamWConfig,
    StepConfig,
    build_train_step,
    init_train_state,
)
from repro.utils import get_logger

log = get_logger("launch.train")


def build_data_store(n_shards: int, tokens_per_shard: int, vocab: int,
                     latency_s: float, bandwidth_Bps: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    store = SimS3Store(
        link=LinkModel(latency_s=latency_s, bandwidth_Bps=bandwidth_Bps)
    )
    for i in range(n_shards):
        store.backing.put(
            f"data/tok{i:04d}.bin", synth_token_shard(rng, tokens_per_shard, vocab)
        )
    return store


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--mode", default="rolling",
                    choices=["rolling", "sequential"])
    ap.add_argument("--prefetch-depth", type=int, default=2)
    ap.add_argument("--blocksize", type=int, default=256 << 10)
    ap.add_argument("--ckpt-interval", type=int, default=20)
    ap.add_argument("--ckpt-store", default=None,
                    help="checkpoint store URI (mem://, local:///path, "
                         "sims3://bucket?latency_ms=...); default builds a "
                         "sims3:// URI from --s3-latency/--s3-bandwidth")
    ap.add_argument("--write-depth", type=int, default=2,
                    help="concurrent write-behind part uploads for saves")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--s3-latency", type=float, default=0.01)
    ap.add_argument("--s3-bandwidth", type=float, default=50e6)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = make_model(cfg)
    log.warning("arch=%s params=%.2fM devices=%d", cfg.name,
                model.param_count() / 1e6, jax.device_count())

    # --- data ---------------------------------------------------------------
    data_store = build_data_store(
        n_shards=8,
        tokens_per_shard=max(200_000, args.batch * (args.seq_len + 1) * 16),
        vocab=cfg.vocab_size,
        latency_s=args.s3_latency,
        bandwidth_Bps=args.s3_bandwidth,
    )
    # Checkpoints address their store by URI through the registry; any
    # registered backend works without touching this driver.
    ckpt_uri = args.ckpt_store or (
        f"sims3://ckpt?latency_ms={args.s3_latency * 1e3:g}"
        f"&bw_mbps={args.s3_bandwidth / 1e6:g}"
    )
    ckpt_store = open_store(ckpt_uri)
    write_policy = IOPolicy(write_depth=args.write_depth,
                            blocksize=args.blocksize)

    # --- resume or init ------------------------------------------------------
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 20))
    step_cfg = StepConfig(
        microbatches=args.microbatches,
        q_chunk=min(512, args.seq_len),
        loss_chunk=min(512, args.seq_len),
    )
    train_step = jax.jit(build_train_step(model, opt_cfg, step_cfg),
                         donate_argnums=(0,))

    state = init_train_state(model, jax.random.key(0))
    start_step, cursor = 0, DataCursor()
    resume = latest_step(ckpt_store, "ckpt")
    if resume is not None:
        # Multi-process mesh: each host prefetch-warms only its
        # rendezvous-owned slice of the checkpoint stream (a peer://
        # ckpt store serves the rest over the LAN). Single process:
        # shard=None, the plain full restore.
        host_id, num_hosts = mesh_host_shard()
        state, manifest = restore_checkpoint(
            ckpt_store, "ckpt", state,
            shard=(host_id, num_hosts) if num_hosts > 1 else None,
        )
        start_step = manifest["step"]
        cursor = DataCursor.from_dict(manifest["extra"].get("cursor", cursor.to_dict()))
        log.warning("resumed from step %d", start_step)

    loader = PrefetchingDataLoader(
        data_store,
        data_store.list_objects("data/"),
        [MemTier(8 << 20)],
        LoaderConfig(
            seq_len=args.seq_len,
            batch_size=args.batch,
            policy=IOPolicy(
                engine=args.mode,
                blocksize=args.blocksize,
                depth=args.prefetch_depth,
                eviction_interval_s=0.2,
                autotune=True,
            ),
        ),
        cursor=cursor,
    )
    ckpt = CheckpointManager(ckpt_store, "ckpt",
                             interval_steps=args.ckpt_interval,
                             policy=write_policy)

    # --- loop ----------------------------------------------------------------
    feeder = DeviceFeeder(loader.batches(), depth=2)
    it = iter(feeder)
    t0 = time.time()
    tokens = 0
    for step in range(start_step, args.steps):
        inputs, labels = next(it)
        state, metrics = train_step(state, {"inputs": inputs, "labels": labels})
        tokens += inputs.shape[0] * inputs.shape[1]
        if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            print(
                f"step={step + 1} loss={float(metrics['loss']):.4f} "
                f"grad_norm={float(metrics['grad_norm']):.3f} "
                f"lr={float(metrics['lr']):.2e} tok/s={tokens / dt:.0f}"
            )
        ckpt.maybe_save(step + 1, state,
                        extra={"cursor": loader.cursor.to_dict()})
    ckpt.maybe_save(args.steps, state, force=True,
                    extra={"cursor": loader.cursor.to_dict()})
    ckpt.wait()
    loader.close()
    print("loader fs stats:", loader.fs_stats().snapshot())
    print(f"done: {args.steps} steps, {tokens} tokens, "
          f"{time.time() - t0:.1f}s wall")


if __name__ == "__main__":
    main()
