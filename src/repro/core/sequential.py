"""Sequential-transfer baseline, modeling S3Fs/FSSpec on-demand block cache.

This is the paper's comparison point: data transfer and compute occur in
distinct phases. A ``read()`` that misses the single-block cache fetches
the containing block from the object store synchronously (paying one
request latency + bandwidth), then serves from memory. No background
threads, no overlap.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core.plan import BlockPlan
from repro.store.base import ObjectMeta, ObjectStore


@dataclass
class SequentialStats:
    blocks_fetched: int = 0
    bytes_fetched: int = 0
    bytes_read: int = 0
    fetch_s: float = 0.0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


@dataclass
class _CacheEntry:
    index: int
    data: bytes


class SequentialFile:
    """fsspec-style read-ahead block cache over the same logical stream the
    Rolling Prefetch file exposes, so both sides of every A/B benchmark
    perform byte-identical application reads."""

    def __init__(
        self,
        store: ObjectStore,
        files: list[ObjectMeta],
        blocksize: int,
        cache_blocks: int = 1,
    ) -> None:
        self.store = store
        self.plan = BlockPlan(files, blocksize)
        self.cache_blocks = max(1, cache_blocks)
        self.stats = SequentialStats()
        self._cache: dict[int, _CacheEntry] = {}
        self._lru: list[int] = []
        self._pos = 0
        self._closed = False

    @property
    def size(self) -> int:
        return self.plan.total_bytes

    @property
    def closed(self) -> bool:
        return self._closed

    def _get_block(self, index: int) -> bytes:
        entry = self._cache.get(index)
        if entry is not None:
            return entry.data
        block = self.plan.blocks[index]
        t0 = time.perf_counter()
        data = self.store.get_range(block.key, block.start, block.end)
        self.stats.fetch_s += time.perf_counter() - t0
        self.stats.blocks_fetched += 1
        self.stats.bytes_fetched += len(data)
        self._cache[index] = _CacheEntry(index, data)
        self._lru.append(index)
        while len(self._lru) > self.cache_blocks:
            self._cache.pop(self._lru.pop(0), None)
        return data

    def read(self, n: int = -1) -> bytes:
        if self._closed:
            raise ValueError("read on closed file")
        if n < 0:
            n = self.size - self._pos
        end = min(self._pos + n, self.size)
        out = bytearray()
        while self._pos < end:
            block = self.plan.block_at(self._pos)
            data = self._get_block(block.index)
            lo = self._pos - block.global_start
            hi = min(end, block.global_end) - block.global_start
            out.extend(data[lo:hi])
            self._pos += hi - lo
        self.stats.bytes_read += len(out)
        return bytes(out)

    def seek(self, offset: int, whence: int = 0) -> int:
        if whence == 1:
            offset += self._pos
        elif whence == 2:
            offset += self.size
        if not 0 <= offset <= self.size:
            raise ValueError(f"seek out of range: {offset}")
        self._pos = offset
        return self._pos

    def tell(self) -> int:
        return self._pos

    def close(self) -> None:
        self._closed = True
        self._cache.clear()
        self._lru.clear()

    def __enter__(self) -> "SequentialFile":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
