"""Shared test setup.

Installs a deterministic fallback for the small `hypothesis` subset the
suite uses (``given`` / ``settings`` / ``strategies.integers|floats|lists|
sampled_from``) when the real package is not importable, so the tier-1
suite runs in hermetic containers with no package installs. With real
hypothesis present this module is a no-op.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types


def _install_hypothesis_fallback() -> None:
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw  # draw(rng) -> value

    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def sampled_from(options) -> _Strategy:
        options = list(options)
        return _Strategy(lambda rng: options[rng.randrange(len(options))])

    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int | None = None) -> _Strategy:
        hi = max_size if max_size is not None else min_size + 10

        def draw(rng):
            return [elements.draw(rng) for _ in range(rng.randint(min_size, hi))]

        return _Strategy(draw)

    def settings(**kw):
        def deco(fn):
            fn._fallback_settings = dict(kw)
            return fn

        return deco

    def given(**strategy_kw):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                cfg = getattr(wrapper, "_fallback_settings", None) or getattr(
                    fn, "_fallback_settings", {}
                )
                n = int(cfg.get("max_examples", 25))
                # Seeded per test so example sequences are reproducible.
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategy_kw.items()}
                    fn(*args, **kwargs, **drawn)

            # Hide the drawn parameters from pytest's fixture resolution.
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategy_kw
            ])
            wrapper.hypothesis_fallback = True
            return wrapper

        return deco

    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.lists = lists
    st.sampled_from = sampled_from
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_fallback()
