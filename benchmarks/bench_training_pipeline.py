"""Beyond-paper: Rolling Prefetch as the training input pipeline.

Measures steps/sec of a real (tiny) JAX train loop whose token shards live
on the simulated object store, comparing:
  * sequential   — S3Fs-style baseline loader;
  * rolling      — the paper's technique;
  * rolling+d4   — beyond-paper: 4 concurrent prefetch streams.

In the input-bound regime the paper's pipeline law applies directly:
step time -> max(T_cloud_per_batch, T_step).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data import LoaderConfig, PrefetchingDataLoader, synth_token_shard
from repro.io import IOPolicy
from repro.models import make_model
from repro.train import AdamWConfig, StepConfig, build_train_step, init_train_state
from repro.store import LinkModel, MemTier, SimS3Store

from benchmarks.common import emit


def _dataset(n_shards=6, tokens=60_000):
    rng = np.random.default_rng(5)
    return {
        f"tok{i:03d}.bin": synth_token_shard(rng, tokens, vocab=500)
        for i in range(n_shards)
    }


def _store(objects):
    store = SimS3Store(link=LinkModel(latency_s=0.01, bandwidth_Bps=30e6))
    for k, v in objects.items():
        store.backing.put(k, v)
    return store


def main(quick: bool = False) -> dict:
    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    train_step = jax.jit(
        build_train_step(model, AdamWConfig(),
                         StepConfig(q_chunk=64, loss_chunk=64))
    )

    seq_len, batch = 128, 8
    steps = 6 if quick else 12
    objects = _dataset()

    def run(mode: str, depth: int = 1) -> float:
        store = _store(objects)
        loader = PrefetchingDataLoader(
            store, store.backing.list_objects(),
            [MemTier(2 << 20)],
            LoaderConfig(seq_len=seq_len, batch_size=batch,
                         policy=IOPolicy(engine=mode, blocksize=128 << 10,
                                         depth=depth,
                                         eviction_interval_s=0.2)),
        )
        s = state
        # Warm the jit cache outside the timed region.
        it = loader.batches()
        inputs, labels = next(it)
        s, _ = train_step(s, {"inputs": inputs, "labels": labels})
        jax.block_until_ready(s.params)
        t0 = time.perf_counter()
        for _ in range(steps):
            inputs, labels = next(it)
            s, m = train_step(s, {"inputs": inputs, "labels": labels})
        jax.block_until_ready(s.params)
        elapsed = time.perf_counter() - t0
        loader.close()
        return elapsed

    t_seq = run("sequential")
    t_roll = run("rolling")
    t_roll4 = run("rolling", depth=4)
    tok_per_step = seq_len * batch
    results = dict(sequential=t_seq, rolling=t_roll, rolling_d4=t_roll4)
    for name, t in results.items():
        emit(
            f"train_pipeline_{name}",
            t / steps * 1e6,
            f"steps={steps};tokens_per_s={steps * tok_per_step / t:.0f};"
            f"speedup_vs_seq={t_seq / t:.3f}",
        )
    assert t_roll < t_seq * 1.05, (t_roll, t_seq)
    return results


if __name__ == "__main__":
    main()
