"""Static-analyzer benchmark: how long the tier-1 gate itself takes.

The analyzer runs in CI before the test stage, so its wall time is part
of every developer's feedback loop. This benchmark times a full
``analyze(src, tests)`` pass plus the lock-graph build and asserts the
gate's own invariants hold:

  * zero unsuppressed findings over the real tree,
  * an acyclic lock graph with the engine lock outermost,
  * the whole pass stays under a CI-scale wall-time budget.

Emits ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.bench_analysis [--smoke]
"""

from __future__ import annotations

import argparse
import os
import time

from benchmarks.common import emit
from repro.analysis import analyze, build_lock_graph, load_project

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))
# Generous CI-machine bound; the point is catching an accidental
# complexity blow-up (the call-graph fixpoints are the risky part), not
# micro-timing.
FULL_PASS_BUDGET_S = 60.0


def main(quick: bool = False) -> None:
    paths = [os.path.join(REPO_ROOT, "src")]
    if not quick:
        paths.append(os.path.join(REPO_ROOT, "tests"))

    t0 = time.perf_counter()
    project, findings = analyze(paths)
    t_analyze = time.perf_counter() - t0

    t0 = time.perf_counter()
    graph = build_lock_graph(project)
    t_graph = time.perf_counter() - t0

    n_files = len(project.modules)
    new = [f for f in findings if not f.suppressed]
    emit("analysis_full_pass", t_analyze * 1e6,
         f"files={n_files};findings={len(findings)};new={len(new)}")
    emit("analysis_lock_graph", t_graph * 1e6,
         f"locks={len(graph.nodes)};edges={len(graph.edges)}")

    assert new == [], [f.location() for f in new]
    assert graph.cycles() == [], graph.cycles()
    order = graph.topo_order()
    assert order is not None
    assert t_analyze + t_graph < FULL_PASS_BUDGET_S, (
        f"analysis pass took {t_analyze + t_graph:.1f}s"
    )

    # Parse cost alone (project load, no rules) for the breakdown.
    t0 = time.perf_counter()
    load_project(paths)
    t_load = time.perf_counter() - t0
    emit("analysis_parse_only", t_load * 1e6, f"files={n_files}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="src only (the CI-sized quick pass)")
    args = ap.parse_args()
    main(quick=args.smoke)
