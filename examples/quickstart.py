"""Quickstart: Rolling Prefetch through the PrefetchFS facade in ~60 lines.

Creates a simulated S3 bucket of tractography shards, reads them through
the S3Fs-style sequential baseline and through Rolling Prefetch — both via
the same ``PrefetchFS.open_many`` call, differing only in
``IOPolicy(engine=...)`` — and compares the measured speed-up against the
paper's analytical model (Eq. 1-4).

  PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

from repro.core import cost_model
from repro.data.trk import iter_streamlines_multi, synth_trk
from repro.io import IOPolicy, PrefetchFS, open_store
from repro.store import MemTier

# --- 1. a bucket of .trk shards behind a simulated S3 link ------------------
LATENCY, BANDWIDTH = 0.02, 45e6           # scaled Table I constants
BLOCK = 256 << 10
BUCKET = f"sims3://hydi?latency_ms={LATENCY * 1e3:g}&bw_mbps={BANDWIDTH / 1e6:g}"

rng = np.random.default_rng(0)
objects = {f"hydi/shard{i}.trk": synth_trk(rng, 4000, mean_points=15)
           for i in range(4)}


def fresh_store():
    # fresh=True: each A/B arm gets its own link so neither inherits the
    # other's bandwidth-reservation state.
    store = open_store(BUCKET, fresh=True)
    for k, v in objects.items():
        store.backing.put(k, v)   # seed the substrate (no simulated cost)
    return store


def consume(f):
    """The application: lazily parse every streamline (affine applied on
    read — compute happens during reading, as in the paper)."""
    n = sum(1 for _ in iter_streamlines_multi(f, f.size))
    f.close()
    return n


# --- 2. sequential (S3Fs-style) baseline -------------------------------------
store = fresh_store()
fs = PrefetchFS(store, policy=IOPolicy(engine="sequential", blocksize=BLOCK))
t0 = time.perf_counter()
n = consume(fs.open_many(store.backing.list_objects()))
t_seq = time.perf_counter() - t0
print(f"sequential: {t_seq:.2f}s ({n} streamlines)")

# --- 3. Rolling Prefetch: same open, different policy -------------------------
store = fresh_store()
tier = MemTier(capacity=4 << 20)  # bounded cache: dataset streams through
fs = PrefetchFS(
    store,
    policy=IOPolicy(engine="rolling", blocksize=BLOCK, eviction_interval_s=0.05),
    tiers=[tier],
)
t0 = time.perf_counter()
n = consume(fs.open_many(store.backing.list_objects()))
t_pf = time.perf_counter() - t0
print(f"rolling prefetch: {t_pf:.2f}s ({n} streamlines)")
print(f"measured speed-up: {t_seq / t_pf:.2f}x  (paper bound: < 2x)")
print("fs stats:", fs.stats().snapshot()["totals"])

# --- 4. compare with the paper's model (Eq. 1-3) -----------------------------
total = sum(len(v) for v in objects.values())
n_b = total / BLOCK
c = max(0.0, (t_seq - n_b * LATENCY - total / BANDWIDTH)) / total  # fit c
p = cost_model.CostParams(f=total, n_b=int(n_b), l_c=LATENCY,
                          b_cr=BANDWIDTH, c=c)
print(f"model-predicted speed-up (Eq. 3): {cost_model.speedup(p):.2f}x")
print(f"optimal block size (Eq. 4): "
      f"{cost_model.optimal_blocksize(total, c, LATENCY) / 1024:.0f} KiB "
      f"(this run used {BLOCK / 1024:.0f} KiB)")
