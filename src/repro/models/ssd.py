"""Mamba-2 block via SSD (state-space duality), TPU-adapted.

The chunked SSD algorithm decomposes the selective-state-space recurrence
into dense per-chunk matmuls (MXU-friendly) plus a short `lax.scan` over
chunk states — this is the published TPU/accelerator-native formulation of
the Mamba recurrence [arXiv:2405.21060]. The intra-chunk computation is
also implemented as a Pallas kernel (repro.kernels.ssd_scan); this module
is the pure-jnp path used by smoke tests and the dry-run, and doubles as
the kernel's oracle.

Sharding: d_inner (and therefore SSM heads, which tile d_inner in
head_dim-sized groups) shards over the tensor axis; B/C group projections
are small and replicate; sequence stays unsharded inside a block (chunk
scan is sequential anyway).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.spec import ParamSpec
from repro.sharding.rules import constrain


# --------------------------------------------------------------------------- #
# Chunked SSD scan (pure jnp; fp32 state math)
# --------------------------------------------------------------------------- #
def segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] for
    j < i, -inf above the diagonal. Produces the 1-semiseparable log-decay
    matrix."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jax.Array,        # (B, S, H, P) — inputs, already dt-scaled
    dt_a: jax.Array,     # (B, S, H)   — dt * A (negative)
    b_proj: jax.Array,   # (B, S, G, N)
    c_proj: jax.Array,   # (B, S, G, N)
    chunk: int,
    initial_state: jax.Array | None = None,  # (B, H, P, N) fp32
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    g, n = b_proj.shape[2], b_proj.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g  # heads per B/C group

    xc = x.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    ac = dt_a.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bc = b_proj.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    cc = c_proj.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    # Broadcast groups to heads: head i belongs to group i // rep.
    bh = jnp.repeat(bc, rep, axis=3)  # (B, NC, L, H, N)
    ch = jnp.repeat(cc, rep, axis=3)

    a_perm = ac.transpose(0, 3, 1, 2)             # (B, H, NC, L)
    a_cumsum = jnp.cumsum(a_perm, axis=-1)        # (B, H, NC, L)

    # 1) Intra-chunk (diagonal blocks).
    l_mat = jnp.exp(segsum(a_perm))               # (B, H, NC, L, L)
    y_diag = jnp.einsum(
        "bclhn,bcshn,bhcls,bcshp->bclhp", ch, bh, l_mat, xc
    )

    # 2) Per-chunk end states.
    decay_states = jnp.exp(a_cumsum[..., -1:] - a_cumsum)  # (B, H, NC, L)
    states = jnp.einsum(
        "bclhn,bhcl,bclhp->bchpn", bh, decay_states, xc
    )                                              # (B, NC, H, P, N)

    # 3) Inter-chunk recurrence over chunk states (lax.scan).
    chunk_decay = jnp.exp(a_cumsum[..., -1])       # (B, H, NC)
    init = (
        jnp.zeros((bsz, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(carry, inp):
        state_c, decay_c = inp                    # (B,H,P,N), (B,H)
        new = carry * decay_c[..., None, None] + state_c
        return new, carry                          # emit the *entering* state

    final_state, entering = jax.lax.scan(
        step,
        init,
        (states.swapaxes(0, 1), chunk_decay.transpose(2, 0, 1)),
    )
    entering = entering.swapaxes(0, 1)             # (B, NC, H, P, N)

    # 4) Inter-chunk output contribution.
    state_decay_out = jnp.exp(a_cumsum)            # (B, H, NC, L)
    y_off = jnp.einsum(
        "bclhn,bchpn,bhcl->bclhp", ch, entering, state_decay_out
    )

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_step(
    state: jax.Array,    # (B, H, P, N) fp32
    x_t: jax.Array,      # (B, H, P) — dt-scaled input
    dt_a_t: jax.Array,   # (B, H)
    b_t: jax.Array,      # (B, G, N)
    c_t: jax.Array,      # (B, G, N)
) -> tuple[jax.Array, jax.Array]:
    """One recurrent step: h' = exp(dt·A) h + B x ; y = C h'."""
    bsz, h, p = x_t.shape
    g = b_t.shape[1]
    rep = h // g
    bh = jnp.repeat(b_t, rep, axis=1).astype(jnp.float32)   # (B, H, N)
    ch = jnp.repeat(c_t, rep, axis=1).astype(jnp.float32)
    decay = jnp.exp(dt_a_t.astype(jnp.float32))             # (B, H)
    new_state = (
        state * decay[..., None, None]
        + jnp.einsum("bhp,bhn->bhpn", x_t.astype(jnp.float32), bh)
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch)
    return y.astype(x_t.dtype), new_state


# --------------------------------------------------------------------------- #
# Causal depthwise conv (shift-and-add; K is tiny)
# --------------------------------------------------------------------------- #
def causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """x: (B, S, C); w: (K, C). Returns (y (B,S,C), new_state (B,K-1,C)).
    `state` carries the last K-1 inputs for decode continuity."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)     # (B, S+K-1, C)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    new_state = xp[:, -(k - 1):, :] if k > 1 else state
    return y.astype(x.dtype), new_state


# --------------------------------------------------------------------------- #
# Mamba-2 block
# --------------------------------------------------------------------------- #
class MambaCache(NamedTuple):
    conv_x: jax.Array    # (B, K-1, d_inner)
    conv_b: jax.Array    # (B, K-1, G*N)
    conv_c: jax.Array    # (B, K-1, G*N)
    ssm: jax.Array       # (B, H, P, N) fp32


def mamba_cache_logical_axes() -> MambaCache:
    from repro.models.spec import Ax

    return MambaCache(
        conv_x=Ax(("batch", None, "tp")),
        conv_b=Ax(("batch", None, None)),
        conv_c=Ax(("batch", None, None)),
        ssm=Ax(("batch", "tp", None, None)),
    )


def mamba_spec(cfg: ModelConfig) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    g, n, h, k = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_conv_kernel
    return {
        "w_z": ParamSpec((d, din), ("fsdp", "tp"), ("fan_in", d)),
        "w_x": ParamSpec((d, din), ("fsdp", "tp"), ("fan_in", d)),
        "w_b": ParamSpec((d, g * n), ("fsdp", None), ("fan_in", d)),
        "w_c": ParamSpec((d, g * n), ("fsdp", None), ("fan_in", d)),
        "w_dt": ParamSpec((d, h), ("fsdp", "tp"), ("fan_in", d)),
        "conv_x": ParamSpec((k, din), (None, "tp"), ("fan_in", k)),
        "conv_b": ParamSpec((k, g * n), (None, None), ("fan_in", k)),
        "conv_c": ParamSpec((k, g * n), (None, None), ("fan_in", k)),
        "dt_bias": ParamSpec((h,), ("tp",), "dt_bias"),
        "a_log": ParamSpec((h,), ("tp",), "a_log"),
        "d_skip": ParamSpec((h,), ("tp",), "ones"),
        "norm_scale": ParamSpec((din,), ("tp",), "ones"),
        "w_out": ParamSpec((din, d), ("tp", "fsdp"), ("fan_in", din)),
    }


def make_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> MambaCache:
    g, n, h, k = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_conv_kernel
    p = cfg.ssm_head_dim
    return MambaCache(
        conv_x=jnp.zeros((batch, k - 1, cfg.d_inner), dtype),
        conv_b=jnp.zeros((batch, k - 1, g * n), dtype),
        conv_c=jnp.zeros((batch, k - 1, g * n), dtype),
        ssm=jnp.zeros((batch, h, p, n), jnp.float32),
    )


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array,
                   eps: float) -> jax.Array:
    yf = (y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)).astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def mamba_block(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,                       # (B, S, D)
    *,
    cache: MambaCache | None = None,
    update_cache: bool = False,
) -> tuple[jax.Array, MambaCache | None]:
    bsz, s, _ = x.shape
    h, pdim, g, n = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_ngroups, cfg.ssm_state
    dt = x.dtype

    z = jnp.einsum("bsd,de->bse", x, p["w_z"].astype(dt))
    xs = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(dt))
    bp = jnp.einsum("bsd,de->bse", x, p["w_b"].astype(dt))
    cp = jnp.einsum("bsd,de->bse", x, p["w_c"].astype(dt))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(dt))
    xs = constrain(xs, "batch", None, "tp")
    z = constrain(z, "batch", None, "tp")

    conv_state = (cache.conv_x, cache.conv_b, cache.conv_c) if cache else (None,) * 3
    xs, st_x = causal_conv(xs, p["conv_x"].astype(dt), conv_state[0])
    bp, st_b = causal_conv(bp, p["conv_b"].astype(dt), conv_state[1])
    cp, st_c = causal_conv(cp, p["conv_c"].astype(dt), conv_state[2])
    xs, bp, cp = jax.nn.silu(xs), jax.nn.silu(bp), jax.nn.silu(cp)

    dt_val = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                      # (B, S, H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))           # (H,)
    dt_a = dt_val * a                                      # (B, S, H)

    xh = xs.reshape(bsz, s, h, pdim)
    x_scaled = xh.astype(jnp.float32) * dt_val[..., None]  # dt-discretized input
    bg = bp.reshape(bsz, s, g, n)
    cg = cp.reshape(bsz, s, g, n)

    if s == 1 and cache is not None:
        y_t, new_ssm = ssd_decode_step(
            cache.ssm,
            x_scaled[:, 0].astype(dt),
            dt_a[:, 0],
            bg[:, 0],
            cg[:, 0],
        )
        y = y_t[:, None]
    else:
        init = cache.ssm if cache is not None else None
        pad = (-s) % cfg.ssm_chunk
        if pad:
            x_scaled = jnp.pad(x_scaled, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_a = jnp.pad(dt_a, ((0, 0), (0, pad), (0, 0)))
            bg = jnp.pad(bg, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cg = jnp.pad(cg, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y_full, new_ssm = ssd_chunked(
            x_scaled.astype(dt), dt_a, bg, cg, cfg.ssm_chunk, initial_state=init
        )
        y = y_full[:, :s]

    y = y + xh * p["d_skip"].astype(dt)[None, None, :, None]
    y = y.reshape(bsz, s, cfg.d_inner)
    y = _gated_rmsnorm(y, z, p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt))
    out = constrain(out, "batch", None, "residual")

    new_cache = None
    if update_cache or cache is not None:
        new_cache = MambaCache(conv_x=st_x, conv_b=st_b, conv_c=st_c, ssm=new_ssm)
    return out, new_cache
