from repro.train.optimizer import AdamWConfig, AdamWState, apply_updates, init_state, schedule
from repro.train.train_step import (
    StepConfig,
    TrainState,
    abstract_train_state,
    build_train_step,
    init_train_state,
)

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "apply_updates",
    "init_state",
    "schedule",
    "StepConfig",
    "TrainState",
    "abstract_train_state",
    "build_train_step",
    "init_train_state",
]
