"""In-process multi-host simulation harness for the peer layer.

`SimCluster` stands up N complete hosts inside one process: each gets
its own cache tiers, `CacheIndex`, `BlockServer` on a loopback socket
(port 0 — the OS assigns, and the group specs are built AFTER every
server is bound, so membership carries real addresses), a `PeerGroup`
with its own egress `PeerLinkModel`, and a `PeerAwareStore`. All hosts
share ONE backing store — and therefore one backing `LinkModel`, which
is the physics of the experiment: the WAN is the contended resource, so
N hosts that each fetch everything divide one link's bandwidth by N,
while peer-routed hosts fetch once and fan out over N independent LAN
hops.

The backing store is wrapped in `CountingStore`, so tests and benchmarks
assert the headline number directly: ``cluster.backing_fetches`` is the
count of block GETs the whole cluster issued — ~1x the unique blocks
with peers working, ~Nx without.

``cluster.kill(i)`` closes host *i*'s server and group mid-run: siblings
observe connection errors, mark the peer dead, re-own its blocks
(rendezvous reassigns only the dead host's blocks), and degrade to
direct GETs — the host-death experiment of the issue, with zero read
errors expected throughout.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

from repro.io import IOPolicy, PrefetchFS
from repro.peer.group import PeerGroup, PeerSpec
from repro.peer.server import BlockServer
from repro.peer.store import PeerAwareStore
from repro.store.base import MultipartUpload, ObjectMeta, ObjectStore
from repro.store.hsm import MEM_LINK
from repro.store.link import LinkModel, PeerLinkModel
from repro.store.tiers import CacheIndex, CacheTier, MemTier


class CountingStore(ObjectStore):
    """Transparent wrapper counting block fetches against the backing
    store (one `get_range` = one fetch; a vectorized `get_ranges` counts
    one fetch per span — spans are blocks, and block GETs are what the
    amplification claim is about)."""

    def __init__(self, inner: ObjectStore) -> None:
        self.inner = inner
        self._lock = threading.Lock()
        self.fetches = 0        # block-shaped range reads
        self.requests = 0       # store round trips carrying them
        self.bytes_fetched = 0

    def snapshot(self) -> dict:
        with self._lock:
            return dict(fetches=self.fetches, requests=self.requests,
                        bytes_fetched=self.bytes_fetched)

    def get_range(self, key: str, start: int, end: int) -> bytes:
        data = self.inner.get_range(key, start, end)
        with self._lock:
            self.fetches += 1
            self.requests += 1
            self.bytes_fetched += len(data)
        return data

    def get_ranges(self, key: str, spans: list[tuple[int, int]]) -> list[bytes]:
        datas = self.inner.get_ranges(key, spans)
        with self._lock:
            self.fetches += len(spans)
            self.requests += 1
            self.bytes_fetched += sum(len(d) for d in datas)
        return datas

    # Verified reads MUST delegate (not fall back to the base-class
    # hash-what-you-got default): when the inner store is a FaultyStore,
    # the digest has to attest the authoritative bytes, not whatever the
    # chaos layer mangled on the way out.
    def get_range_verified(self, key: str, start: int,
                           end: int) -> tuple[bytes, str]:
        data, digest = self.inner.get_range_verified(key, start, end)
        with self._lock:
            self.fetches += 1
            self.requests += 1
            self.bytes_fetched += len(data)
        return data, digest

    def get_ranges_verified(
        self, key: str, spans: list[tuple[int, int]],
    ) -> list[tuple[bytes, str]]:
        pairs = self.inner.get_ranges_verified(key, spans)
        with self._lock:
            self.fetches += len(spans)
            self.requests += 1
            self.bytes_fetched += sum(len(d) for d, _ in pairs)
        return pairs

    def digest_range(self, key: str, start: int, end: int) -> str:
        # The reference digest costs a real store read (the default
        # implementation fetches the range) — bill it like one, so
        # amplification claims stay honest under verify="full".
        digest = self.inner.digest_range(key, start, end)
        with self._lock:
            self.fetches += 1
            self.requests += 1
            self.bytes_fetched += end - start
        return digest

    def get(self, key: str) -> bytes:
        return self.inner.get(key)

    def list_objects(self, prefix: str = "") -> list[ObjectMeta]:
        return self.inner.list_objects(prefix)

    def size(self, key: str) -> int:
        return self.inner.size(key)

    def put(self, key: str, data: bytes) -> None:
        self.inner.put(key, data)

    def delete(self, key: str) -> None:
        self.inner.delete(key)

    def start_multipart(self, key: str) -> MultipartUpload:
        return self.inner.start_multipart(key)


@dataclass
class SimHost:
    host_id: int
    tiers: list[CacheTier]
    index: CacheIndex
    server: BlockServer
    group: PeerGroup
    store: PeerAwareStore
    alive: bool = True
    _fss: list[PrefetchFS] = field(default_factory=list)

    def open_fs(self, policy: IOPolicy | None = None, **kw) -> PrefetchFS:
        """A `PrefetchFS` over this host's peer store (it adopts the
        host's tiers + index; reads route through the peer layer)."""
        fs = PrefetchFS(self.store, policy, **kw)
        self._fss.append(fs)
        return fs


class SimCluster:
    def __init__(
        self,
        n_hosts: int,
        backing: ObjectStore,
        *,
        mem_bytes: int = 256 << 20,
        peer_latency_s: float = 2e-4,
        peer_bandwidth_Bps: float = 1.25e9,
        heartbeat_interval_s: float | None = None,
        miss_limit: int = 2,
        faults=None,
    ) -> None:
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
        self.backing = CountingStore(backing)
        self.hosts: list[SimHost] = []
        servers: list[tuple[list[CacheTier], CacheIndex, BlockServer]] = []
        # Bind every server first (port 0 -> kernel-assigned), THEN build
        # the groups: membership needs the full address map.
        for i in range(n_hosts):
            tiers: list[CacheTier] = [MemTier(
                mem_bytes,
                read_link=LinkModel(name=f"h{i}.mem.r", **MEM_LINK),
                write_link=LinkModel(name=f"h{i}.mem.w", **MEM_LINK),
                name=f"h{i}.mem",
            )]
            index = CacheIndex(tiers, keep_cached=True)
            server = BlockServer(index, self.backing, host="127.0.0.1",
                                 port=0, host_id=i)
            servers.append((tiers, index, server))
        specs = [PeerSpec(i, srv.address[0], srv.address[1])
                 for i, (_, _, srv) in enumerate(servers)]
        for i, (tiers, index, server) in enumerate(servers):
            group = PeerGroup(
                i, specs,
                link=PeerLinkModel(latency_s=peer_latency_s,
                                   bandwidth_Bps=peer_bandwidth_Bps,
                                   name=f"h{i}.peer"),
                heartbeat_interval_s=heartbeat_interval_s,
                miss_limit=miss_limit,
                faults=faults,
            )
            store = PeerAwareStore(self.backing, group, tiers=tiers,
                                   index=index, server=server)
            self.hosts.append(SimHost(i, tiers, index, server, group, store))

    # -- observability -------------------------------------------------------
    @property
    def backing_fetches(self) -> int:
        return self.backing.fetches

    def host(self, i: int) -> SimHost:
        return self.hosts[i]

    def snapshot(self) -> dict:
        return dict(
            backing=self.backing.snapshot(),
            hosts={h.host_id: h.store.peer_snapshot()
                   for h in self.hosts if h.alive},
        )

    # -- chaos ---------------------------------------------------------------
    def kill(self, i: int) -> None:
        """Hard-kill host `i` mid-run: its server stops answering and its
        own group goes away. Survivors detect the death through failed
        RPCs/heartbeats; nothing is announced — that is the point."""
        h = self.hosts[i]
        if not h.alive:
            return
        h.alive = False
        for fs in h._fss:
            try:
                fs.close()
            except Exception:   # repro: allow[RP005] — a dying host dies messy
                pass
        h.server.close()
        h.group.close()

    def close(self) -> None:
        for h in self.hosts:
            if h.alive:
                for fs in h._fss:
                    try:
                        fs.close()
                    except Exception:   # repro: allow[RP005] — shutdown close is best-effort
                        pass
                h.store.close()
                h.alive = False
