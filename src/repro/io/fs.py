"""PrefetchFS: one filesystem-style facade for reads AND writes.

Following the S3Fs idiom the paper extends, applications hold a filesystem
object and open file-like readers and writers from it::

    fs = PrefetchFS("sims3://bucket?latency_ms=40",       # URI or ObjectStore
                    policy=IOPolicy(engine="rolling", blocksize=8 << 20))
    with fs:
        f = fs.open("bucket/key")              # one object
        g = fs.open_many(metas, depth=4)       # multi-object logical stream,
                                               # per-open policy override
        w = fs.open_write("out/key")           # write-behind upload pipeline
        w.write(data); w.close()               # close() = durable publish
        print(fs.stats().snapshot())           # aggregated across all opens

The facade owns cache-tier lifecycle (builds a bounded MemTier on demand
when an engine needs one and none was supplied), resolves store URIs
through the store registry (``repro.io.open_store``), dispatches
``IOPolicy.engine`` through the reader registry, runs one shared
`UploadPool` for every write-behind `Writer`, and aggregates per-handle
statistics into one `FSStats` view (writers fold in under the
``"write-behind"`` engine name). Training data loading, checkpoint
save/restore, serving cold-start, and every A/B benchmark construct their
I/O exclusively through this API.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Iterable, Sequence

from repro.core.autotune import BlockSizeTuner
from repro.io.policy import IOPolicy
from repro.io.registry import available_engines, engine_spec
from repro.io.stores import open_store
from repro.io.write import UploadPool, Writer
from repro.store.base import ObjectMeta, ObjectStore
from repro.store.hsm import HSMStore
from repro.store.tiers import CacheIndex, CacheTier, MemTier

# Importing the engines module populates the registry with the built-ins.
import repro.io.engines  # noqa: F401  (side-effect import)

WRITE_ENGINE = "write-behind"   # per_engine stats bucket for writers

# Coalesce-width ceiling applied when autotune is on but the caller left
# `IOPolicy.coalesce` unset (None) — autotune alone should be able to
# engage coalesced fetches, and the cost model holds the width at 1
# anyway while the link looks bandwidth-bound.
AUTOTUNE_COALESCE_CAP = 16

# The facade's tuner accepts block sizes well below the paper-scale 1 MiB
# floor: scaled benchmarks and tests run with KiB-range blocks.
TUNER_MIN_BLOCKSIZE = 4 << 10


@dataclass
class FSStats:
    """Aggregated I/O statistics across every reader and writer a
    PrefetchFS opened.

    ``totals`` sums every numeric counter that any engine reports
    (bytes_read, bytes_fetched, bytes_uploaded, retries, hedges, ...);
    ``per_engine`` keeps the same sums split by engine name, with writers
    under ``"write-behind"``.
    """

    opens: int = 0
    totals: dict = field(default_factory=dict)
    per_engine: dict = field(default_factory=dict)
    # Closed-loop tuner estimates (latency_s, bandwidth_Bps,
    # compute_s_per_byte, requests_observed); None when autotune is off.
    tuner: dict | None = None
    # Shared cache-index counters (hits, misses, joins, evictions,
    # recovered, resident_blocks/bytes); None until the fs has tiers.
    cache: dict | None = None
    # HSM placement counters (promotions, demotions, per-tier and
    # per-class hits, residency per tier, cost-model estimates); None
    # unless the fs index is an `HSMIndex`.
    hsm: dict | None = None
    # Distributed-prefetch counters (peer hits/misses, bytes from peers,
    # dead-peer fallbacks, plus nested group/server views); None unless
    # the fs store is a `PeerAwareStore`.
    peer: dict | None = None
    # End-to-end integrity counters (repro.io.integrity):
    # ``blocks_verified`` digest checks that passed, ``failures`` digest
    # mismatches the engines detected (each one healed by a re-fetch, or
    # surfaced as a typed IntegrityError on exhaustion), ``quarantined``
    # cache entries evicted + tombstoned for failing verification. All
    # zeros under ``verify="off"``.
    integrity: dict = field(default_factory=dict)

    def snapshot(self) -> dict:
        return {
            "opens": self.opens,
            "totals": dict(self.totals),
            "per_engine": {k: dict(v) for k, v in self.per_engine.items()},
            "tuner": dict(self.tuner) if self.tuner is not None else None,
            "cache": dict(self.cache) if self.cache is not None else None,
            "hsm": dict(self.hsm) if self.hsm is not None else None,
            "peer": dict(self.peer) if self.peer is not None else None,
            "integrity": dict(self.integrity),
        }


class PrefetchFS:
    """Filesystem facade over an `ObjectStore` with pluggable prefetching."""

    def __init__(
        self,
        store: ObjectStore | str,
        policy: IOPolicy | None = None,
        tiers: Sequence[CacheTier] | None = None,
        index: CacheIndex | None = None,
    ) -> None:
        # `store` may be a URI ("mem://", "local:///path", "sims3://bucket")
        # resolved through the store registry; same URI -> same instance.
        self.store = open_store(store)
        # An `hsm://` composite store carries its whole hierarchy: adopt
        # its tiers and `HSMIndex` (unless the caller overrides them) and
        # read through the backing store — every engine then places blocks
        # via HSM admission/promotion with no call-site changes. Two
        # filesystems opened on the same hsm URI share one hierarchy.
        if isinstance(self.store, HSMStore):
            if tiers is None:
                tiers = self.store.tiers
            if index is None:
                index = self.store.index
            self.store = self.store.inner
        # A `peer://` composite store likewise carries a hierarchy —
        # adopt it — but unlike HSM the store itself stays in place:
        # ownership routing (home-host fetch vs direct GET) lives in the
        # wrapper's get_range/get_ranges, so engines must keep reading
        # through it. Imported lazily: repro.peer depends on
        # repro.io.retry, so an eager import here would close the cycle
        # for whichever package is imported first.
        from repro.peer.store import PeerAwareStore
        self._peer_store: PeerAwareStore | None = None
        if isinstance(self.store, PeerAwareStore):
            self._peer_store = self.store
            if tiers is None and self.store.tiers:
                tiers = self.store.tiers
            if index is None and self.store.index is not None:
                index = self.store.index
        self.policy = policy if policy is not None else IOPolicy()
        self._tiers: list[CacheTier] | None = (
            list(tiers) if tiers is not None else None
        )
        # One CacheIndex per distinct tier list: every reader this fs opens
        # over the same tiers shares residency, refcounts, and in-flight
        # fetch registration. An explicit `index` (e.g. handed to several
        # fs instances) extends that sharing across filesystems; its tiers
        # become the fs tiers unless `tiers` overrides them.
        self._indexes: dict[tuple[int, ...], CacheIndex] = {}
        if index is not None:
            if self._tiers is None:
                self._tiers = list(index.tiers)
            self._indexes[tuple(id(t) for t in index.tiers)] = index
        self._lock = threading.RLock()
        # Open readers AND writers, as (engine-name, handle) pairs.
        self._handles: list[tuple[str, object]] = []
        # Stats of already-closed handles, folded per engine so a loader
        # that reopens a stream every epoch doesn't accumulate dead reader
        # objects (see _prune_closed).
        self._folded: dict[str, dict] = {}
        self._pool: UploadPool | None = None
        self._closed = False
        # One tuner per filesystem: every autotuned open shares (and
        # feeds) the same link/compute estimates.
        self._tuner: BlockSizeTuner | None = (
            BlockSizeTuner(min_blocksize=TUNER_MIN_BLOCKSIZE)
            if self.policy.autotune else None
        )

    # ------------------------------------------------------------------ #
    # opening readers
    # ------------------------------------------------------------------ #
    def open(self, key, *, policy: IOPolicy | None = None,
             tiers: Sequence[CacheTier] | None = None, **overrides):
        """Open one object (or a list of them) as a `Reader`.

        ``key`` is an object key string, an `ObjectMeta`, or a list of
        either (lists delegate to :meth:`open_many`). Keyword overrides
        (``engine=``, ``blocksize=``, ``depth=``, ...) apply on top of the
        filesystem policy for this open only.
        """
        if isinstance(key, (list, tuple)):
            return self.open_many(key, policy=policy, tiers=tiers, **overrides)
        return self.open_many([key], policy=policy, tiers=tiers, **overrides)

    def open_many(self, keys: Iterable, *, policy: IOPolicy | None = None,
                  tiers: Sequence[CacheTier] | None = None, **overrides):
        """Open a list of objects as ONE logical sequential stream — the
        paper's multi-file case ("treating a list of files as a single
        file"). Returns a `Reader`."""
        pol = policy if policy is not None else self.policy
        if overrides:
            pol = pol.replace(**overrides)
        spec = engine_spec(pol.engine)
        # Flag check BEFORE any store metadata round-trip, so an open on a
        # closed (or closing) filesystem short-circuits without issuing
        # store requests. Resolution itself stays outside the lock —
        # holding it across store.size() would serialize every open and
        # block stats()/close() behind simulated network latency.
        with self._lock:
            if self._closed:
                raise ValueError("open on closed PrefetchFS")
        files = [self._resolve(k) for k in keys]
        # Re-check + factory call + registration under one lock: an open
        # racing with close() either lands in close()'s sweep or observes
        # the closed flag — never an orphaned reader.
        with self._lock:
            if self._closed:
                raise ValueError("open on closed PrefetchFS")
            if pol.autotune:
                pol = self._retune(pol, files, tiers)
            if tiers is not None:
                use_tiers = list(tiers)
            elif spec.needs_tiers:
                use_tiers = self._ensure_tiers(pol)
            else:
                # Engines that merely *accept* an index still share the fs
                # tiers when the fs already has them (sequential warm
                # reads); none are created just for them.
                use_tiers = list(self._tiers) if self._tiers else []
            kw: dict = {}
            if spec.accepts_tuner:
                kw["tuner"] = self._tuner
            if spec.accepts_index:
                kw["index"] = self._index_for(use_tiers, pol)
            reader = spec.factory(self.store, files, use_tiers, pol, **kw)
            self._prune_closed()
            self._handles.append((pol.engine, reader))
        return reader

    def _index_for(self, tiers: Sequence[CacheTier],
                   pol: IOPolicy) -> CacheIndex | None:
        """Shared `CacheIndex` for a tier list (created on first use, one
        per distinct list, primed from persistent tiers' recovered
        blocks). An open asking for ``keep_cached`` upgrades an existing
        index to retention — the reverse never downgrades, since other
        readers may rely on warm blocks. Caller holds `_lock`."""
        if not tiers:
            return None
        key = tuple(id(t) for t in tiers)
        idx = self._indexes.get(key)
        if idx is None:
            idx = CacheIndex(list(tiers), keep_cached=pol.keep_cached)
            self._indexes[key] = idx
        elif pol.keep_cached and not idx.keep_cached:
            idx.set_keep_cached(True)
        return idx

    def _retune(self, pol: IOPolicy, files: list[ObjectMeta],
                tiers: Sequence[CacheTier] | None) -> IOPolicy:
        """Closed-loop per-open retuning: pick the Eq.-4 blocksize from
        the tuner's current link/compute estimates (falling back to the
        policy blocksize while unobserved) and open the coalesce-width
        ceiling so the engine's cost model can amortize request latency.
        Caller holds `_lock`."""
        tuner = self._ensure_tuner()
        total = sum(m.size for m in files)
        use_tiers = list(tiers) if tiers is not None else self._tiers
        budget = (sum(t.capacity for t in use_tiers) if use_tiers
                  else pol.default_tier_capacity())
        blocksize = tuner.suggest_blocksize(
            total, cache_budget=budget, default=pol.blocksize
        )
        # Open the ceiling only when the caller left coalesce unset: an
        # explicit IOPolicy.coalesce — including 1, i.e. coalescing off —
        # bounds the payload one request may carry (memory per GET,
        # tier-fit granularity) and is not the tuner's to override.
        coalesce = (pol.coalesce if pol.coalesce is not None
                    else AUTOTUNE_COALESCE_CAP)
        return pol.replace(blocksize=blocksize, coalesce=coalesce)

    def _ensure_tuner(self) -> BlockSizeTuner:
        if self._tuner is None:
            self._tuner = BlockSizeTuner(min_blocksize=TUNER_MIN_BLOCKSIZE)
        return self._tuner

    @property
    def tuner(self) -> BlockSizeTuner | None:
        """The filesystem's closed-loop tuner (None until an autotuned
        policy is seen)."""
        with self._lock:
            return self._tuner

    def open_write(self, key, *, policy: IOPolicy | None = None,
                   tiers: Sequence[CacheTier] | None = None,
                   **overrides) -> Writer:
        """Open `key` for writing through the write-behind pipeline.

        Returns a `Writer`: ``write()`` buffers into part-sized chunks
        staged in the cache tiers, a shared pool of ``write_depth``
        threads uploads parts in the background, ``flush()`` is a
        durability barrier, and ``close()`` atomically publishes the
        object. Keyword overrides (``blocksize=``, ``write_depth=``,
        ``hedge_timeout_s=``, ...) apply to this writer only.
        """
        pol = policy if policy is not None else self.policy
        if overrides:
            pol = pol.replace(**overrides)
        with self._lock:
            if self._closed:
                raise ValueError("open_write on closed PrefetchFS")
            use_tiers = list(tiers) if tiers is not None \
                else self._ensure_tiers(pol)
            if self._pool is None:
                self._pool = UploadPool()
            self._pool.ensure(pol.write_depth)
            writer = Writer(self.store, str(key), pol, use_tiers, self._pool,
                            index=self._index_for(use_tiers, pol))
            self._prune_closed()
            self._handles.append((WRITE_ENGINE, writer))
        return writer

    def _resolve(self, key) -> ObjectMeta:
        if isinstance(key, ObjectMeta):
            return key
        key = str(key)
        return ObjectMeta(key, self.store.size(key))

    def _ensure_tiers(self, policy: IOPolicy) -> list[CacheTier]:
        with self._lock:
            if self._tiers is None:
                self._tiers = [
                    MemTier(policy.default_tier_capacity(), name="prefetchfs.mem")
                ]
            return self._tiers

    # ------------------------------------------------------------------ #
    # introspection
    # ------------------------------------------------------------------ #
    def ls(self, prefix: str = "") -> list[ObjectMeta]:
        """List objects under a prefix (one store metadata request)."""
        return self.store.list_objects(prefix)

    def engines(self) -> tuple[str, ...]:
        return available_engines()

    @property
    def tiers(self) -> list[CacheTier]:
        """The cache tiers this filesystem manages (empty until an engine
        that needs them is opened, unless tiers were supplied)."""
        with self._lock:
            return list(self._tiers or [])

    @property
    def cache_index(self) -> CacheIndex | None:
        """The shared `CacheIndex` over the fs-level tiers (None until a
        reader over them has been opened)."""
        with self._lock:
            if not self._tiers:
                return None
            return self._indexes.get(tuple(id(t) for t in self._tiers))

    @staticmethod
    def _fold_snapshot(bucket: dict, reader) -> None:
        bucket["opens"] = bucket.get("opens", 0) + 1
        stats_obj = getattr(reader, "stats", None)
        snap = stats_obj.snapshot() if stats_obj is not None else {}
        for k, v in snap.items():
            if not isinstance(v, (int, float)):
                continue
            if k == "depth_peak":
                # A high-water mark, not a counter: folding across
                # reopened readers keeps the peak, not the sum of peaks.
                bucket[k] = max(bucket.get(k, 0), v)
            else:
                bucket[k] = bucket.get(k, 0) + v

    def _prune_closed(self) -> None:
        """Fold the stats of closed readers/writers into `_folded` and drop
        the handle objects, so per-epoch reopen loops stay O(1) memory.
        Caller holds `_lock`."""
        live = []
        for engine, handle in self._handles:
            if getattr(handle, "closed", False):
                self._fold_snapshot(self._folded.setdefault(engine, {}), handle)
            else:
                live.append((engine, handle))
        self._handles = live

    def stats(self) -> FSStats:
        """Aggregate statistics across every reader and writer opened so
        far (open or closed); closed handles' stats persist in the folded
        totals (writers appear under the ``"write-behind"`` engine)."""
        with self._lock:
            per_engine = {k: dict(v) for k, v in self._folded.items()}
            handles = list(self._handles)
            tuner = self._tuner
            index = None
            if self._tiers:
                index = self._indexes.get(tuple(id(t) for t in self._tiers))
        for engine, handle in handles:
            self._fold_snapshot(per_engine.setdefault(engine, {}), handle)
        out = FSStats(per_engine=per_engine)
        if tuner is not None:
            out.tuner = tuner.estimates()
        if index is not None:
            out.cache = index.snapshot()
            hsm_snap = getattr(index, "hsm_snapshot", None)
            if hsm_snap is not None:
                out.hsm = hsm_snap()
        if self._peer_store is not None:
            out.peer = self._peer_store.peer_snapshot()
        for bucket in per_engine.values():
            out.opens += bucket.get("opens", 0)
            for k, v in bucket.items():
                if k == "opens":
                    continue
                if k == "depth_peak":
                    out.totals[k] = max(out.totals.get(k, 0), v)
                else:
                    out.totals[k] = out.totals.get(k, 0) + v
        out.integrity = dict(
            blocks_verified=out.totals.get("blocks_verified", 0),
            failures=out.totals.get("integrity_failures", 0),
            quarantined=(out.cache or {}).get("quarantined", 0),
        )
        return out

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def close(self) -> None:
        """Close every reader and writer this filesystem opened (engines
        run their final eviction sweep so owned tiers end empty — unless
        ``IOPolicy.keep_cached`` retains consumed blocks warm for the next
        open or a restarted job; writers flush and publish), then shut
        down the upload pool. The first writer-close failure is re-raised
        after everything is closed."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            handles = list(self._handles)
            pool = self._pool
        # Closing outside the lock: rolling close joins worker threads and
        # writer close blocks on its upload barrier.
        first_err: Exception | None = None
        for _, handle in handles:
            try:
                handle.close()
            except Exception as e:   # repro: allow[RP005] — re-raised below
                if first_err is None:
                    first_err = e
        if pool is not None:
            pool.close()
        if first_err is not None:
            raise first_err

    def __enter__(self) -> "PrefetchFS":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
