from repro.ft.restart import RestartManager, TrainLoopResult, run_with_restarts
from repro.ft.elastic import reshard_tree, snapshot_resharded

__all__ = [
    "RestartManager",
    "TrainLoopResult",
    "run_with_restarts",
    "reshard_tree",
    "snapshot_resharded",
]
