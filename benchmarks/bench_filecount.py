"""Paper Fig. 2: runtime of lazily reading N .trk files into the
nibabel-like reader, S3Fs-style sequential vs Rolling Prefetch.

Claims validated:
  * speed-up grows with dataset size (more blocks to mask);
  * Rolling Prefetch never falls meaningfully below sequential (worst case
    ~= S3Fs per the paper);
  * all speed-ups < 2 (Eq. 3 bound).
"""

from __future__ import annotations

from repro.data.trk import iter_streamlines_multi

from benchmarks.common import (
    emit,
    fresh_store,
    fresh_tiers,
    make_trk_dataset,
    open_reader,
    timed,
)


def _consume(stream, size) -> int:
    n = 0
    for sl in iter_streamlines_multi(stream, size):
        n += sl.points.shape[0]
    return n


def run_sequential(ds) -> float:
    store = fresh_store(ds)
    f = open_reader(store, ds.metas(), "sequential")
    _consume(f, f.size)
    f.close()
    return 0.0


def run_rolling(ds) -> float:
    store = fresh_store(ds)
    f = open_reader(store, ds.metas(), "rolling", tiers=fresh_tiers())
    _consume(f, f.size)
    f.close()
    return 0.0


def main(quick: bool = False) -> dict:
    counts = [1, 2, 4] if quick else [1, 2, 4, 8]
    reps = 2 if quick else 3
    results = {}
    for n in counts:
        ds = make_trk_dataset(n, seed=n)
        t_seq, _, _ = timed(lambda: run_sequential(ds), reps=reps)
        t_pf, _, _ = timed(lambda: run_rolling(ds), reps=reps)
        speedup = t_seq / t_pf
        results[n] = (t_seq, t_pf, speedup)
        emit(
            f"fig2_filecount_n{n}",
            t_pf * 1e6,
            f"seq_s={t_seq:.3f};pf_s={t_pf:.3f};speedup={speedup:.3f};"
            f"bytes={ds.total_bytes}",
        )
    # Claims.
    sp = [results[n][2] for n in counts]
    assert all(s < 2.0 for s in sp), f"Eq.3 bound violated: {sp}"
    assert sp[-1] > sp[0] - 0.05, f"speedup should grow with size: {sp}"
    assert all(s > 0.9 for s in sp), f"worst case should be ~sequential: {sp}"
    emit("fig2_speedup_trend", 0.0,
         ";".join(f"n{n}={results[n][2]:.3f}" for n in counts))
    return results


if __name__ == "__main__":
    main()
