"""Rolling Prefetch — the paper's primary contribution, as a composable
library: block planning, the three-thread prefetch/read/evict engine over
bounded cache tiers, the S3Fs-like sequential baseline it is benchmarked
against, the Eq. 1-4 analytical cost model, and the online autotuner that
closes the paper's optimal-block-size loop.

Applications should not construct these engines directly: open readers
through the `repro.io.PrefetchFS` facade (`IOPolicy(engine="rolling")`
et al.), which owns tier lifecycle and engine dispatch. The classes here
are the engine layer that facade drives."""

from repro.core.plan import Block, BlockPlan
from repro.core.rolling import (
    BlockState,
    PrefetchStats,
    RollingPrefetcher,
    RollingPrefetchFile,
)
from repro.core.sequential import SequentialFile, SequentialStats
from repro.core import cost_model
from repro.core.autotune import AimdDepthController, BlockSizeTuner

__all__ = [
    "AimdDepthController",
    "Block",
    "BlockPlan",
    "BlockState",
    "PrefetchStats",
    "RollingPrefetcher",
    "RollingPrefetchFile",
    "SequentialFile",
    "SequentialStats",
    "cost_model",
    "BlockSizeTuner",
]
