"""IOPolicy: the single configuration object for every reader engine.

The paper's extension of S3Fs keeps prefetch configuration out of the
application: callers open files and the filesystem carries the policy
(block size, cache tiers, concurrency). `IOPolicy` plays that role here —
one frozen value object covering every knob any engine understands, built
from keyword arguments, another config object (`from_config`), or an
existing policy plus per-open overrides (`replace`). Engines read only the
fields they care about; unknown-engine validation happens in the registry.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Mapping

from repro.io.retry import RetryPolicy


@dataclass(frozen=True)
class IOPolicy:
    """Reader *and* writer configuration shared by all engines.

    Fields consumed per engine:
      * ``rolling``    — blocksize, depth, max_depth, coalesce,
        readahead_blocks, eviction_interval_s, retry (or
        max_retries/retry_backoff_s), hedge_timeout_s, max_hedges,
        throttle_aimd, autotune, tier_capacity;
      * ``sequential`` — blocksize, cache_blocks, retry;
      * ``direct``     — none (pass-through range reads);
      * write-behind `Writer` (``PrefetchFS.open_write``) — blocksize (the
        part size), write_depth, retry (or max_retries/retry_backoff_s),
        hedge_timeout_s, max_hedges, tier_capacity (staging budget).

    The adaptive-scheduling knobs:
      * ``coalesce`` — max adjacent blocks one store request may carry;
        >1 turns on coalesced ``get_ranges`` fetches (the engine holds the
        width at 1 while the link looks bandwidth-bound). The default
        ``None`` means "unset": the engine fetches block-at-a-time, but
        ``autotune`` may open the ceiling. An explicit value — including
        1, i.e. coalescing off — is a hard bound autotune respects;
      * ``readahead_blocks`` — fetch-window horizon ahead of the reader
        position (None = race to end-of-plan, the paper's behaviour);
      * ``max_depth`` — upper bound for the AIMD stream controller; None
        pins concurrency at ``depth``;
      * ``autotune`` — `PrefetchFS` owns a `BlockSizeTuner` fed by the
        engine's observed request timings and compute gaps, and retunes
        ``blocksize`` and ``coalesce`` on every open.
    """

    engine: str = "rolling"
    blocksize: int = 8 << 20
    depth: int = 1                      # concurrent prefetch streams
    max_depth: int | None = None        # AIMD stream ceiling (None = fixed depth)
    coalesce: int | None = None         # max blocks per range GET (None=unset)
    readahead_blocks: int | None = None  # fetch horizon ahead of the reader
    write_depth: int = 2                # concurrent write-behind part uploads
    eviction_interval_s: float = 5.0
    max_retries: int = 3
    retry_backoff_s: float = 0.05
    # The full resilience configuration. None (the default) builds a
    # `RetryPolicy` from the legacy `max_retries`/`retry_backoff_s`
    # knobs (full-jitter backoff); pass an explicit `RetryPolicy` for
    # the budget/deadline/jitter knobs. See :meth:`retry_policy`.
    retry: RetryPolicy | None = None
    hedge_timeout_s: float | None = None
    max_hedges: int = 4                 # hedge duplicates in flight, per handle
    # Throttle→depth feedback: a `ThrottleError` from the store halves
    # the AIMD stream target immediately (rolling engine, max_depth set).
    # False keeps the throttle-oblivious behaviour — retries back off but
    # concurrency stays up (the A/B baseline in bench_resilience).
    throttle_aimd: bool = True
    cache_blocks: int = 1               # sequential engine read-ahead cache
    autotune: bool = False              # retune blocksize/coalesce per open
    tier_capacity: int | None = None    # default cache budget when the FS owns tiers
    # Shared-cache retention: with True, fully-consumed blocks stay
    # resident in the tiers after a reader (or the whole fs) closes —
    # LRU-evicted only under capacity pressure — so per-epoch reopens,
    # other readers of the same keys, and (with a persistent DirTier)
    # restarted jobs start warm. False keeps the paper's
    # evict-when-consumed behaviour.
    keep_cached: bool = False
    # End-to-end block integrity (repro.io.integrity). "off": no digests,
    # the zero-overhead baseline. "edges" (default): digests are minted
    # at the store fetch (verified against the store-attested digest),
    # carried in the CacheIndex, and re-checked whenever a block crosses
    # a tier/peer/store boundary — self-verifying tiers (DirTier's
    # journal crc) are trusted and not double-hashed. "full": edges plus
    # recomputation on EVERY cached read (even self-verifying tiers),
    # write-behind staging read-back verification, and an authoritative
    # backing-store cross-check of peer-served bytes (catches a
    # byzantine sibling whose frames are self-consistent). Mismatches
    # quarantine the block and heal through the shared Retrier.
    verify: str = "edges"
    # Workload class carried to the cache layer (HSM admission): "loader"
    # (bulk epoch sweeps: disk-level entry, scan-resistant), "ckpt"
    # (restore streams: top-tier entry), "serve" (latency-critical
    # restores: top-tier entry, protected from displacement by other
    # classes), or "default". A flat CacheIndex ignores it; the loader,
    # checkpoint, and serve call sites stamp their class when the caller
    # left this at "default".
    io_class: str = "default"

    def __post_init__(self) -> None:
        if self.blocksize <= 0:
            raise ValueError(f"blocksize must be positive, got {self.blocksize}")
        if self.depth < 1:
            raise ValueError(f"depth must be >= 1, got {self.depth}")
        if self.max_depth is not None and self.max_depth < self.depth:
            raise ValueError(
                f"max_depth ({self.max_depth}) must be >= depth ({self.depth})"
            )
        if self.coalesce is not None and self.coalesce < 1:
            raise ValueError(f"coalesce must be >= 1, got {self.coalesce}")
        if self.readahead_blocks is not None and self.readahead_blocks < 1:
            raise ValueError(
                f"readahead_blocks must be >= 1, got {self.readahead_blocks}"
            )
        if self.write_depth < 1:
            raise ValueError(
                f"write_depth must be >= 1, got {self.write_depth}"
            )
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.max_hedges < 1:
            raise ValueError(f"max_hedges must be >= 1, got {self.max_hedges}")
        if not self.io_class or not isinstance(self.io_class, str):
            raise ValueError(
                f"io_class must be a non-empty string, got {self.io_class!r}"
            )
        if self.verify not in ("off", "edges", "full"):
            raise ValueError(
                f"verify must be 'off', 'edges', or 'full', got {self.verify!r}"
            )

    def retry_policy(self) -> RetryPolicy:
        """The effective `RetryPolicy`: the explicit ``retry`` object
        when given, else one built from the legacy scalar knobs (with
        full-jitter backoff — the unjittered ``2 ** attempt`` loops this
        replaces synchronized concurrent streams into retry storms)."""
        if self.retry is not None:
            return self.retry
        return RetryPolicy(max_retries=self.max_retries,
                           backoff_s=self.retry_backoff_s)

    def replace(self, **overrides: Any) -> "IOPolicy":
        """A copy with the given fields overridden (per-open tweaks)."""
        return dataclasses.replace(self, **overrides)

    @classmethod
    def from_config(cls, src: Mapping[str, Any] | Any = None,
                    **overrides: Any) -> "IOPolicy":
        """Build a policy from a mapping or any object whose attribute
        names match `IOPolicy` field names exactly; unknown keys are
        ignored, explicit keyword overrides win. Configs with their own
        reader-knob spellings need an explicit mapping instead (e.g.
        `LoaderConfig.reader_policy()` maps `prefetch_depth` -> `depth`)."""
        names = {f.name for f in dataclasses.fields(cls)}
        kw: dict[str, Any] = {}
        if src is not None:
            if isinstance(src, Mapping):
                kw.update((k, v) for k, v in src.items() if k in names)
            else:
                kw.update((n, getattr(src, n)) for n in names if hasattr(src, n))
        kw.update(overrides)
        return cls(**kw)

    def default_tier_capacity(self) -> int:
        """Cache budget used when the filesystem builds its own tier: at
        least four in-flight blocks so the pipeline can roll."""
        if self.tier_capacity is not None:
            return self.tier_capacity
        return max(4 * self.blocksize, 64 << 20)
