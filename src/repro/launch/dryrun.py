import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
production meshes and extract roofline terms.

The two lines above MUST precede any other import (jax locks the device
count at first init); do not set this flag globally.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--both-meshes]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out reports/dryrun
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import SHAPES, all_configs, get_config, shapes_for
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models import layers as L
from repro.models.api import make_model
from repro.roofline.analysis import analyze_compiled
from repro.sharding.rules import DECODE_RULES, TRAIN_RULES, ShardingRules, use_rules
from repro.train import AdamWConfig, StepConfig, abstract_train_state, build_train_step


def _mesh_and_rules(shape_kind: str, multi_pod: bool):
    mesh = make_production_mesh(multi_pod=multi_pod)
    table = DECODE_RULES if shape_kind == "decode" else TRAIN_RULES
    return mesh, ShardingRules(mesh, dict(table))


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    microbatches: int = 1,
    q_chunk: int = 512,
    moments_dtype: str = "float32",
    quant: str | None = None,   # "int8": TP-only weight-only-quant decode
    verbose: bool = True,
):
    """Lower + compile one cell; returns (report dict, compiled)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = make_model(cfg)
    mesh, rules = _mesh_and_rules(shape.kind, multi_pod)
    chips = mesh.devices.size
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"

    t0 = time.time()
    with mesh, use_rules(rules):
        if shape.kind == "train":
            step_cfg = StepConfig(microbatches=microbatches, q_chunk=q_chunk)
            opt_cfg = AdamWConfig(moments_dtype=moments_dtype)
            train_step = build_train_step(model, opt_cfg, step_cfg)
            state = abstract_train_state(model, rules, opt_cfg=opt_cfg)
            batch = input_specs(model, shape, rules)
            lowered = jax.jit(train_step, donate_argnums=(0,)).lower(state, batch)
            tokens = shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            params = model.abstract_params(rules, param_dtype=L.COMPUTE_DTYPE)

            def prefill_fn(p, batch):
                return model.prefill(p, batch, q_chunk=q_chunk)

            batch = input_specs(model, shape, rules)
            lowered = jax.jit(prefill_fn).lower(params, batch)
            tokens = shape.global_batch * shape.seq_len
        else:  # decode
            if quant == "int8":
                from repro.models.quant import abstract_quantized_params
                from repro.sharding.rules import DECODE_TP_RULES

                params = abstract_quantized_params(
                    model.spec(), ShardingRules(mesh, dict(DECODE_TP_RULES))
                )
            else:
                params = model.abstract_params(rules, param_dtype=L.COMPUTE_DTYPE)
            spec = input_specs(model, shape, rules)

            def decode_fn(p, inputs, caches, position):
                return model.decode_step(p, inputs, caches, position)

            lowered = jax.jit(decode_fn, donate_argnums=(2,)).lower(
                params, spec["inputs"], spec["caches"], spec["position"]
            )
            tokens = shape.global_batch  # one new token per sequence
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    report = analyze_compiled(
        compiled,
        arch=arch,
        shape=shape_name,
        kind=shape.kind,
        mesh_name=mesh_name,
        chips=chips,
        n_active_params=model.active_param_count(),
        tokens=tokens,
    )
    out = report.to_dict()
    out["lower_s"] = round(t_lower, 2)
    out["compile_s"] = round(t_compile, 2)
    out["microbatches"] = microbatches
    out["param_count"] = model.param_count()
    out["active_param_count"] = model.active_param_count()
    if verbose:
        print(compiled.memory_analysis())
        print({k: v for k, v in (compiled.cost_analysis() or {}).items()
               if k in ("flops", "bytes accessed", "transcendentals")})
        print(report.summary_line())
    return out, compiled


def run_cells(cells, *, meshes=("pod16x16", "pod2x16x16"), out_dir=None,
              microbatches=1, stop_on_error=False):
    results = []
    for arch, shape_name in cells:
        cfg = get_config(arch)
        applicable = {s.name for s in shapes_for(cfg)}
        for mesh_name in meshes:
            multi_pod = mesh_name == "pod2x16x16"
            key = f"{arch}__{shape_name}__{mesh_name}"
            if shape_name not in applicable:
                rec = {
                    "arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "status": "skipped",
                    "reason": "long_500k requires sub-quadratic sequence mixing "
                              "(full-attention arch); see DESIGN.md",
                }
                results.append(rec)
                print(f"SKIP  {key}: {rec['reason']}")
                _write(out_dir, key, rec)
                continue
            try:
                t0 = time.time()
                rec, _ = lower_cell(
                    arch, shape_name, multi_pod=multi_pod,
                    microbatches=microbatches, verbose=False,
                )
                rec["status"] = "ok"
                rec["wall_s"] = round(time.time() - t0, 2)
                print(
                    f"OK    {key}: compile={rec['compile_s']}s "
                    f"dom={rec['dominant']} tc={rec['t_compute']:.2e} "
                    f"tm={rec['t_memory']:.2e} tcoll={rec['t_collective']:.2e}"
                )
            except Exception as e:  # repro: allow[RP005] — recorded per cell; re-raised when stop_on_error
                rec = {
                    "arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:],
                }
                print(f"FAIL  {key}: {rec['error'][:300]}")
                if stop_on_error:
                    raise
            results.append(rec)
            _write(out_dir, key, rec)
    return results


def _write(out_dir, key, rec) -> None:
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, key + ".json"), "w") as f:
        json.dump(rec, f, indent=2, default=str)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true",
                    help="all (arch x shape) cells")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh (single-cell mode)")
    ap.add_argument("--meshes", default="pod16x16,pod2x16x16",
                    help="comma list for --all mode")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moments-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="optimizer moment storage (train cells)")
    ap.add_argument("--quant", choices=["int8"], default=None,
                    help="weight-only int8 TP-only layout (decode cells)")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--stop-on-error", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = [
            (arch, shape_name)
            for arch in sorted(all_configs())
            for shape_name in SHAPES
        ]
        results = run_cells(
            cells,
            meshes=tuple(args.meshes.split(",")),
            out_dir=args.out,
            microbatches=args.microbatches,
            stop_on_error=args.stop_on_error,
        )
        ok = sum(r.get("status") == "ok" for r in results)
        skip = sum(r.get("status") == "skipped" for r in results)
        err = sum(r.get("status") == "error" for r in results)
        print(f"\n== dry-run complete: {ok} ok, {skip} skipped, {err} failed ==")
        if err:
            raise SystemExit(1)
        return

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    rec, _ = lower_cell(
        args.arch, args.shape,
        multi_pod=args.multi_pod,
        microbatches=args.microbatches,
        moments_dtype=args.moments_dtype,
        quant=args.quant,
    )
    rec["status"] = "ok"
    if args.microbatches != 1 or args.quant or args.moments_dtype != "float32":
        # Non-default knobs: don't clobber the baseline artifact.
        args.out = args.out.rstrip("/") + "_variants"
    _write(args.out, f"{args.arch}__{args.shape}__"
           f"{'pod2x16x16' if args.multi_pod else 'pod16x16'}", rec)


if __name__ == "__main__":
    main()
