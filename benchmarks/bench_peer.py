"""Distributed-prefetch A/B: store-GET amplification and aggregate
restore bandwidth versus host count over an in-process `SimCluster`.

Three experiments against the scaled Table-I WAN link (the contended
resource — all simulated hosts share ONE backing `LinkModel`, so N
hosts that each fetch everything divide one link by N):

  * **amplification** — N hosts each stream the WHOLE dataset. With the
    peer layer, each block's home host performs the one WAN GET and
    siblings pull over the LAN: backing GETs ~= 1x the unique blocks
    (asserted <= 1.2x). The control arm (N independent single-member
    groups) pays ~Nx.
  * **sharded restore** — every host of an n-host mesh restores the full
    checkpoint with ``restore_checkpoint(shard=(h, n))``: each host
    warms only its rendezvous-owned slice from the WAN and fills the
    rest from siblings. Aggregate restore bandwidth (n x state bytes /
    wall) must scale >= 2x from 1 -> 4 hosts (asserted).
  * **kill one peer** — a host dies mid-run; survivors degrade its
    blocks to direct GETs with ZERO read errors (asserted).

Emits ``name,us_per_call,derived`` CSV rows and writes
``BENCH_peer.json``.

  PYTHONPATH=src python -m benchmarks.bench_peer [--smoke]
"""

from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from benchmarks.common import S3_BW, S3_LATENCY, emit
from repro.ckpt.manager import restore_checkpoint, save_checkpoint
from repro.io import IOPolicy
from repro.peer.sim import SimCluster
from repro.store import LinkModel, MemStore, SimS3Store


def payload(n: int, seed: int = 0) -> bytes:
    return bytes((i * 31 + seed * 7) % 256 for i in range(n))


def make_backing(objects: dict[str, bytes]) -> SimS3Store:
    store = SimS3Store(
        link=LinkModel(latency_s=S3_LATENCY, bandwidth_Bps=S3_BW,
                       name="bench-peer-wan"))
    for k, v in objects.items():
        store.backing.put(k, v)
    return store


def _stream_all(cluster: SimCluster, hosts, blocksize: int,
                want: bytes) -> float:
    """Every listed host reads the full dataset; returns wall seconds
    (first start to last finish). Raises on any error or byte mismatch."""
    errors: list = []
    start = threading.Barrier(len(list(hosts)) + 1)

    def run(h):
        try:
            host = cluster.host(h)
            fs = host.open_fs(IOPolicy(
                engine="rolling", blocksize=blocksize, depth=4,
                keep_cached=True, eviction_interval_s=0.05))
            files = sorted(host.store.list_objects(), key=lambda m: m.key)
            start.wait(timeout=60)
            f = fs.open_many(files)
            try:
                got = f.read()
            finally:
                f.close()
            assert got == want, f"host {h} bytes diverged"
        except BaseException as e:  # repro: allow[RP005] — stashed; asserted after join
            errors.append((h, e))

    threads = [threading.Thread(target=run, args=(h,)) for h in hosts]
    for t in threads:
        t.start()
    start.wait(timeout=60)
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    assert not errors, errors
    return wall


def bench_amplification(n_hosts: int, n_files: int, file_bytes: int,
                        blocksize: int) -> dict:
    objects = {f"shard{i:03d}": payload(file_bytes, seed=i)
               for i in range(n_files)}
    want = b"".join(objects[k] for k in sorted(objects))
    n_blocks = sum(-(-len(v) // blocksize) for v in objects.values())

    # Peer arm: one group, N hosts, every host reads everything.
    cluster = SimCluster(n_hosts, make_backing(objects))
    try:
        peer_wall = _stream_all(cluster, range(n_hosts), blocksize, want)
        peer_fetches = cluster.backing_fetches
        peer_hits = sum(cluster.host(h).store.peer_snapshot()["peer_hits"]
                        for h in range(n_hosts))
    finally:
        cluster.close()

    # Control arm: N single-member groups over ONE shared WAN link —
    # every host fetches everything itself.
    backing = make_backing(objects)
    solos = [SimCluster(1, backing) for _ in range(n_hosts)]
    try:
        errors: list = []
        start = threading.Barrier(n_hosts + 1)

        def run(c):
            try:
                start.wait(timeout=60)
                _stream_all(c, [0], blocksize, want)
            except BaseException as e:  # repro: allow[RP005] — stashed; asserted after join
                errors.append(e)

        threads = [threading.Thread(target=run, args=(c,)) for c in solos]
        for t in threads:
            t.start()
        start.wait(timeout=60)
        t0 = time.perf_counter()
        for t in threads:
            t.join()
        solo_wall = time.perf_counter() - t0
        assert not errors, errors
        solo_fetches = sum(c.backing_fetches for c in solos)
    finally:
        for c in solos:
            c.close()

    peer_amp = peer_fetches / n_blocks
    solo_amp = solo_fetches / n_blocks
    # The headline acceptance: ~1x with peers, ~Nx without.
    assert peer_amp <= 1.2, (
        f"peer-routed amplification {peer_amp:.2f}x exceeds 1.2x "
        f"({peer_fetches} GETs for {n_blocks} blocks, {n_hosts} hosts)"
    )
    assert solo_amp >= 0.9 * n_hosts, (
        f"control arm amplification {solo_amp:.2f}x is not ~{n_hosts}x — "
        f"the A/B is not measuring contention"
    )
    emit("peer_amplification", peer_wall * 1e6,
         f"gets={peer_fetches};blocks={n_blocks};amp={peer_amp:.2f}x;"
         f"hosts={n_hosts};peer_hits={peer_hits}")
    emit("solo_amplification", solo_wall * 1e6,
         f"gets={solo_fetches};blocks={n_blocks};amp={solo_amp:.2f}x;"
         f"hosts={n_hosts}")
    return dict(
        n_hosts=n_hosts, n_blocks=n_blocks,
        peer=dict(backing_gets=peer_fetches, amplification=peer_amp,
                  wall_s=peer_wall, peer_hits=peer_hits),
        solo=dict(backing_gets=solo_fetches, amplification=solo_amp,
                  wall_s=solo_wall),
    )


def _make_checkpoint(leaf_kb: int, n_leaves: int):
    rng = np.random.default_rng(0)
    state = {f"layer{i}": rng.standard_normal(
        (leaf_kb * 256,)).astype(np.float32) for i in range(n_leaves)}
    staging = MemStore()
    save_checkpoint(staging, "ckpt", 1, state,
                    policy=IOPolicy(blocksize=64 << 10))
    objects = {m.key: staging.get(m.key) for m in staging.list_objects()}
    total = sum(len(v) for k, v in objects.items() if k.endswith(".raw"))
    return state, objects, total


def _restore_all(cluster: SimCluster, n_hosts: int, state,
                 blocksize: int) -> float:
    """Every host restores the full checkpoint, sharded; returns wall
    seconds from common start to last finish."""
    errors: list = []
    start = threading.Barrier(n_hosts + 1)
    pol = IOPolicy(engine="rolling", blocksize=blocksize, depth=4,
                   eviction_interval_s=0.05)

    def run(h):
        try:
            host = cluster.host(h)
            start.wait(timeout=120)
            restored, manifest = restore_checkpoint(
                host.store, "ckpt", state, policy=pol, tiers=host.tiers,
                shard=(h, n_hosts) if n_hosts > 1 else None)
            assert manifest["step"] == 1
            for k in state:
                np.testing.assert_array_equal(np.asarray(restored[k]),
                                              state[k])
        except BaseException as e:  # repro: allow[RP005] — stashed; asserted after join
            errors.append((h, e))

    threads = [threading.Thread(target=run, args=(h,))
               for h in range(n_hosts)]
    for t in threads:
        t.start()
    start.wait(timeout=120)
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    assert not errors, errors
    return wall


def bench_sharded_restore(hosts_sweep, leaf_kb: int, n_leaves: int,
                          blocksize: int) -> dict:
    state, objects, total_bytes = _make_checkpoint(leaf_kb, n_leaves)
    points = {}
    for n in hosts_sweep:
        cluster = SimCluster(n, make_backing(objects))
        try:
            wall = _restore_all(cluster, n, state, blocksize)
            gets = cluster.backing_fetches
        finally:
            cluster.close()
        agg_bw = n * total_bytes / wall
        points[n] = dict(wall_s=wall, aggregate_Bps=agg_bw,
                         backing_gets=gets)
        emit(f"sharded_restore_{n}hosts", wall * 1e6,
             f"agg_bw_MBps={agg_bw / 1e6:.1f};gets={gets};"
             f"state_MB={total_bytes / 1e6:.1f}")
    lo, hi = min(hosts_sweep), max(hosts_sweep)
    scaling = points[hi]["aggregate_Bps"] / points[lo]["aggregate_Bps"]
    assert scaling >= 2.0, (
        f"aggregate restore bandwidth scaled {scaling:.2f}x from {lo} to "
        f"{hi} hosts (needs >= 2x): every host re-reading the WAN?"
    )
    emit("sharded_restore_scaling", 0.0,
         f"scaling={scaling:.2f}x;from={lo};to={hi}")
    return dict(state_bytes=total_bytes, points=points, scaling=scaling)


def bench_kill_one(n_hosts: int, n_files: int, file_bytes: int,
                   blocksize: int) -> dict:
    """A host dies halfway through the epoch; every survivor must finish
    byte-identical with zero read errors."""
    objects = {f"shard{i:03d}": payload(file_bytes, seed=i)
               for i in range(n_files)}
    want = b"".join(objects[k] for k in sorted(objects))
    half = len(want) // 2
    cluster = SimCluster(n_hosts, make_backing(objects), miss_limit=1)
    survivors = list(range(n_hosts - 1))
    errors: list = []
    reached_half = threading.Barrier(len(survivors) + 1)
    killed = threading.Barrier(len(survivors) + 1)

    def run(h):
        try:
            host = cluster.host(h)
            fs = host.open_fs(IOPolicy(
                engine="sequential", blocksize=blocksize, keep_cached=True))
            files = sorted(host.store.list_objects(), key=lambda m: m.key)
            f = fs.open_many(files)
            try:
                first = f.read(half)
                reached_half.wait(timeout=120)
                killed.wait(timeout=120)
                rest = f.read()
            finally:
                f.close()
            assert first + rest == want, f"survivor {h} bytes diverged"
        except BaseException as e:  # repro: allow[RP005] — stashed; asserted after join
            errors.append((h, e))

    threads = [threading.Thread(target=run, args=(h,)) for h in survivors]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    reached_half.wait(timeout=120)
    cluster.kill(n_hosts - 1)
    killed.wait(timeout=120)
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    try:
        assert not errors, f"reads errored after peer death: {errors}"
        fallbacks = sum(
            cluster.host(h).store.peer_snapshot()["dead_peer_fallbacks"]
            for h in survivors)
        deaths = sum(
            cluster.host(h).store.peer_snapshot()["group"]["deaths"]
            for h in survivors)
        emit("peer_kill_one", wall * 1e6,
             f"read_errors=0;dead_peer_fallbacks={fallbacks};"
             f"deaths_observed={deaths};survivors={len(survivors)}")
        return dict(wall_s=wall, read_errors=0,
                    dead_peer_fallbacks=fallbacks, deaths_observed=deaths)
    finally:
        cluster.close()


def main(quick: bool = False, out: str = "BENCH_peer.json") -> None:
    if quick:
        amp = bench_amplification(n_hosts=4, n_files=4, file_bytes=64 << 10,
                                  blocksize=16 << 10)
        restore = bench_sharded_restore((1, 4), leaf_kb=64, n_leaves=4,
                                        blocksize=32 << 10)
        kill = bench_kill_one(n_hosts=4, n_files=4, file_bytes=64 << 10,
                              blocksize=16 << 10)
    else:
        amp = bench_amplification(n_hosts=4, n_files=8, file_bytes=256 << 10,
                                  blocksize=32 << 10)
        restore = bench_sharded_restore((1, 2, 4), leaf_kb=256, n_leaves=4,
                                        blocksize=64 << 10)
        kill = bench_kill_one(n_hosts=4, n_files=8, file_bytes=256 << 10,
                              blocksize=32 << 10)
    record = dict(
        amplification=amp,
        sharded_restore=restore,
        kill_one=kill,
        link=dict(latency_s=S3_LATENCY, bandwidth_Bps=S3_BW),
        smoke=bool(quick),
    )
    with open(out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {out}: amplification {amp['peer']['amplification']:.2f}x "
          f"with {amp['n_hosts']} hosts (control "
          f"{amp['solo']['amplification']:.2f}x), restore bandwidth scaling "
          f"{restore['scaling']:.2f}x, kill-one read errors "
          f"{kill['read_errors']}")


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_peer.json")
    args = ap.parse_args()
    main(quick=args.smoke, out=args.out)


if __name__ == "__main__":
    _cli()
