"""Tests of the paper's analytical model (Eq. 1-4) and the autotuner."""

from __future__ import annotations

import math

from hypothesis import given, settings, strategies as st

from repro.core import cost_model as cm
from repro.core.autotune import BlockSizeTuner

# Paper Table I constants.
L_C, B_CR = 0.1, 91e6


def params(f=1e9, n_b=16, c=1e-9, **kw):
    return cm.CostParams(f=f, n_b=n_b, l_c=kw.pop("l_c", L_C), b_cr=kw.pop("b_cr", B_CR), c=c, **kw)


class TestEquations:
    def test_eq1_components(self):
        p = params(f=1e9, n_b=10, c=2e-9)
        expected = 10 * L_C + 1e9 / B_CR + 2e-9 * 1e9
        assert math.isclose(cm.t_seq(p), expected)

    def test_eq2_pipeline_law(self):
        p = params(n_b=8)
        tc, tp = cm.t_cloud(p), cm.t_comp(p)
        assert math.isclose(cm.t_pf(p), tc + 7 * max(tc, tp) + tp)

    def test_seq_equals_pf_plus_min_term_when_local_free(self):
        """T_seq = T_pf + (n_b-1) min(T_cloud, T_comp) with free local I/O."""
        p = params(n_b=12, c=3e-9)
        lhs = cm.t_seq(p)
        rhs = cm.t_pf(p) + (p.n_b - 1) * min(cm.t_cloud(p), cm.t_comp(p))
        assert math.isclose(lhs, rhs, rel_tol=1e-12)

    @given(
        f=st.floats(1e6, 1e12),
        n_b=st.integers(1, 10000),
        c=st.floats(0.0, 1e-6),
        l_c=st.floats(1e-4, 1.0),
        b_cr=st.floats(1e6, 1e10),
    )
    @settings(max_examples=200, deadline=None)
    def test_speedup_strictly_below_two(self, f, n_b, c, l_c, b_cr):
        """Eq. 3: S < 2 for all parameters (free local storage)."""
        p = cm.CostParams(f=f, n_b=n_b, l_c=l_c, b_cr=b_cr, c=c)
        assert cm.speedup(p) < 2.0
        assert cm.speedup(p) >= 1.0 - 1e-9

    def test_speedup_approaches_two_when_balanced(self):
        """S -> 2 as T_cloud ~= T_comp and n_b grows."""
        # Choose c so compute time per byte == transfer time per byte.
        c = 1.0 / B_CR + L_C * 1000 / 1e9  # roughly balances with latency
        p = params(f=1e9, n_b=1000, c=c)
        assert cm.speedup(p) > 1.8

    def test_no_compute_no_speedup(self):
        p = params(c=0.0, n_b=64)
        # With zero compute, prefetch cannot mask anything: S ~= 1.
        assert cm.speedup(p) < 1.05

    def test_optimal_blocks_matches_grid_search(self):
        """Eq. 4 n̂_b = sqrt(cf/l_c) minimizes T_pf over n_b (l_l=0)."""
        f, c = 5e9, 4e-9
        nb_hat = cm.optimal_num_blocks(f, c, L_C)
        t_hat = cm.t_pf(params(f=f, n_b=max(1, round(nb_hat)), c=c))
        for nb in range(1, 2000, 7):
            t = cm.t_pf(params(f=f, n_b=nb, c=c))
            assert t_hat <= t * 1.01, f"n_b={nb} beats n̂_b={nb_hat:.1f}"

    def test_asymptote_parallel_lines(self):
        """As n_b -> inf, T_seq -> n_b l_c and T_pf -> n_b (l_c + l_l)."""
        f, c, l_l = 1e9, 1e-9, 1e-3
        for nb in (10**5, 10**6):
            p = cm.CostParams(f=f, n_b=nb, l_c=L_C, b_cr=B_CR, c=c, l_l=l_l,
                              b_lw=2221e6, b_lr=2221e6)
            assert math.isclose(cm.t_seq(p), nb * L_C, rel_tol=0.05)
            assert math.isclose(cm.t_pf(p), nb * (L_C + 2 * l_l) + nb * L_C, rel_tol=0.6)


class TestAutotuner:
    def test_converges_to_true_constants(self):
        tuner = BlockSizeTuner()
        true_bw, true_lat, true_c = 91e6, 0.1, 2e-9
        for _ in range(100):
            nbytes = 64 << 20
            tuner.observe_latency(true_lat)
            tuner.observe_bandwidth(true_bw)
            tuner.observe_compute(nbytes, true_c * nbytes)
        assert math.isclose(tuner.latency_s, true_lat, rel_tol=0.01)
        assert math.isclose(tuner.bandwidth_Bps, true_bw, rel_tol=0.01)
        assert math.isclose(tuner.compute_s_per_byte, true_c, rel_tol=0.01)

    def test_suggestion_tracks_eq4(self):
        tuner = BlockSizeTuner(min_blocksize=1, max_blocksize=1 << 40)
        f, c, lat = 10e9, 5e-9, 0.1
        tuner.observe_latency(lat)
        tuner.observe_bandwidth(91e6)
        tuner.observe_compute(1 << 20, c * (1 << 20))
        suggested = tuner.suggest_blocksize(int(f))
        want = cm.optimal_blocksize(f, c, lat)
        assert 0.5 * want <= suggested <= 2.0 * want

    def test_default_without_observations_is_paper_default(self):
        tuner = BlockSizeTuner()
        assert tuner.suggest_blocksize(1 << 30) == 64 << 20

    def test_cache_budget_clamps(self):
        tuner = BlockSizeTuner()
        assert tuner.suggest_blocksize(1 << 30, cache_budget=16 << 20) <= 8 << 20

    def test_predicted_speedup_in_bounds(self):
        tuner = BlockSizeTuner()
        tuner.observe_latency(0.1)
        tuner.observe_bandwidth(91e6)
        tuner.observe_compute(1 << 20, 1e-2)
        s = tuner.predicted_speedup(1 << 30, 64 << 20)
        assert s is not None and 1.0 <= s < 2.0
