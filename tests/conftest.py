"""Shared test setup.

Installs a deterministic fallback for the small `hypothesis` subset the
suite uses (``given`` / ``settings`` / ``strategies.integers|floats|lists|
sampled_from``) when the real package is not importable, so the tier-1
suite runs in hermetic containers with no package installs. With real
hypothesis present this module is a no-op.

Also provides the opt-in ``traced_locks`` fixture: it swaps
``threading.Lock/RLock/Condition`` for recording wrappers so a test's
*actual* lock acquisition order is captured, then (teardown) asserts
every observed nesting is consistent with the static lock-order graph
that ``repro.analysis`` extracts from the source — i.e. that adding the
observed edges to the static graph introduces no cycle. Set
``REPRO_LOCK_ORDER=1`` to apply it automatically to the concurrency
suites (test_cache_tiers, test_peer).
"""

from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import threading
import types

import pytest


def _install_hypothesis_fallback() -> None:
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw  # draw(rng) -> value

    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def sampled_from(options) -> _Strategy:
        options = list(options)
        return _Strategy(lambda rng: options[rng.randrange(len(options))])

    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int | None = None) -> _Strategy:
        hi = max_size if max_size is not None else min_size + 10

        def draw(rng):
            return [elements.draw(rng) for _ in range(rng.randint(min_size, hi))]

        return _Strategy(draw)

    def settings(**kw):
        def deco(fn):
            fn._fallback_settings = dict(kw)
            return fn

        return deco

    def given(**strategy_kw):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                cfg = getattr(wrapper, "_fallback_settings", None) or getattr(
                    fn, "_fallback_settings", {}
                )
                n = int(cfg.get("max_examples", 25))
                # Seeded per test so example sequences are reproducible.
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategy_kw.items()}
                    fn(*args, **kwargs, **drawn)

            # Hide the drawn parameters from pytest's fixture resolution.
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategy_kw
            ])
            wrapper.hypothesis_fallback = True
            return wrapper

        return deco

    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.lists = lists
    st.sampled_from = sampled_from
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_fallback()

# Imported at collection time, before any fixture patches threading's
# constructor names — repro.sched captures the real ones at import, and
# both this file's traced locks and the interleaving explorer's
# cooperative locks go through the same patch mechanism.
from repro.sched import patch_threading_ctors  # noqa: E402


# ---------------------------------------------------------------------------
# Instrumented locks: record runtime acquisition order, check it against
# the static lock graph.
# ---------------------------------------------------------------------------

_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock
_REAL_CONDITION = threading.Condition


class LockOrderRecorder:
    """Collects (outer, inner) lock-name pairs as threads nest locks.

    Lock names resolve lazily at first acquire by scanning caller frames
    for a ``self`` whose ``__dict__`` holds the wrapper — yielding the
    same ``ClassName._attr`` naming the static analyzer uses. Locks that
    never resolve (locals, module globals) record no edges, mirroring the
    static graph's scope.
    """

    def __init__(self) -> None:
        self.edges: dict[tuple[str, str], str] = {}
        self._held = threading.local()
        self._mu = _REAL_LOCK()

    def _stack(self) -> list:
        st = getattr(self._held, "stack", None)
        if st is None:
            st = self._held.stack = []
        return st

    def on_acquire(self, wrapper) -> None:
        name = wrapper._name or wrapper._resolve_name()
        stack = self._stack()
        if name is not None:
            for held in stack:
                hname = held._name
                if hname is None or hname == name:
                    continue
                with self._mu:
                    self.edges.setdefault(
                        (hname, name), threading.current_thread().name
                    )
        stack.append(wrapper)

    def on_release(self, wrapper) -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is wrapper:
                del stack[i]
                break


class _TracedLock:
    """Wrapper over a real Lock/RLock/Condition that reports to a
    recorder. Everything not intercepted delegates to the inner object."""

    def __init__(self, recorder: LockOrderRecorder, inner) -> None:
        self._recorder = recorder
        self._inner = inner
        self._name: str | None = None

    def _resolve_name(self) -> str | None:
        f = sys._getframe(2)
        for _ in range(12):
            if f is None:
                return None
            owner = f.f_locals.get("self")
            if owner is not None and owner is not self:
                try:
                    d = object.__getattribute__(owner, "__dict__")
                except AttributeError:
                    d = {}
                for attr, val in list(d.items()):
                    if val is self:
                        self._name = f"{type(owner).__name__}.{attr}"
                        return self._name
            f = f.f_back
        return None

    def acquire(self, *args, **kwargs):
        got = self._inner.acquire(*args, **kwargs)
        if got:
            self._recorder.on_acquire(self)
        return got

    def release(self) -> None:
        self._recorder.on_release(self)
        self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __getattr__(self, name):
        return getattr(self._inner, name)


class _TracedCondition(_TracedLock):
    def wait(self, timeout=None):
        # wait() releases and reacquires the underlying lock; mirror that
        # in the held stack so edges recorded across the wakeup are real.
        self._recorder.on_release(self)
        try:
            return self._inner.wait(timeout)
        finally:
            self._recorder.on_acquire(self)

    def wait_for(self, predicate, timeout=None):
        self._recorder.on_release(self)
        try:
            return self._inner.wait_for(predicate, timeout)
        finally:
            self._recorder.on_acquire(self)

    def notify(self, n: int = 1) -> None:
        self._inner.notify(n)

    def notify_all(self) -> None:
        self._inner.notify_all()


_restore_lock_ctors = None


def _patch_lock_ctors(recorder: LockOrderRecorder):
    global _restore_lock_ctors

    def make_lock():
        return _TracedLock(recorder, _REAL_LOCK())

    def make_rlock():
        return _TracedLock(recorder, _REAL_RLOCK())

    def make_condition(lock=None):
        if isinstance(lock, _TracedLock):
            lock = lock._inner
        return _TracedCondition(recorder, _REAL_CONDITION(lock))

    _restore_lock_ctors = patch_threading_ctors(
        lock=make_lock, rlock=make_rlock, condition=make_condition)


def _unpatch_lock_ctors() -> None:
    global _restore_lock_ctors
    if _restore_lock_ctors is not None:
        _restore_lock_ctors()
        _restore_lock_ctors = None


@pytest.fixture(scope="session")
def static_lock_graph():
    """The analyzer's lock-order graph over src/, built once per run."""
    from repro.analysis import build_lock_graph, load_project

    root = os.path.join(os.path.dirname(__file__), os.pardir)
    project, _ = load_project([os.path.join(root, "src")])
    return build_lock_graph(project)


def assert_order_consistent(recorder: LockOrderRecorder, graph) -> None:
    """Every observed (outer → inner) edge must be compatible with the
    static graph: the static graph must not already order inner BEFORE
    outer (a path inner → outer), or the union would be cyclic."""
    violations = []
    for (outer, inner), thread in sorted(recorder.edges.items()):
        a, b = graph.normalize(outer), graph.normalize(inner)
        if a == b:
            continue
        if graph.has_path(b, a):
            violations.append(
                f"runtime acquired {outer} then {inner} (thread {thread}), "
                f"but the static graph orders {b} before {a}"
            )
    assert not violations, (
        "runtime lock order contradicts static lock graph:\n  "
        + "\n  ".join(violations)
    )


@pytest.fixture
def traced_locks(static_lock_graph):
    """Opt-in: record this test's real lock acquisition order and check
    it against the static graph on teardown."""
    recorder = LockOrderRecorder()
    _patch_lock_ctors(recorder)
    try:
        yield recorder
    finally:
        _unpatch_lock_ctors()
    assert_order_consistent(recorder, static_lock_graph)


@pytest.fixture(autouse=True)
def _lock_order_autocheck(request):
    """With REPRO_LOCK_ORDER=1, apply traced_locks to the concurrency
    suites without editing each test."""
    if os.environ.get("REPRO_LOCK_ORDER") and request.module.__name__ in (
        "test_cache_tiers",
        "test_peer",
    ):
        request.getfixturevalue("traced_locks")
    yield
