"""Read-path A/B: fixed-policy Rolling Prefetch vs the adaptive scheduler
(coalesced range GETs + AIMD stream depth + closed-loop autotune), on the
scaled-Table-I simulated S3 store.

Three scenarios spanning the cost model's regimes (Eq. 1: ``n_b·l_c``
vs ``f/b_cr`` vs ``c·f``):

  * ``latency_bound``  — many small files, high request latency: Eq. 1 is
    dominated by per-request latency, so coalescing adjacent blocks into
    one ``get_ranges`` request and growing stream depth should win big
    (claim: >= 1.3x, and fewer store requests than blocks fetched);
  * ``bandwidth_bound`` — few large files on a fat-payload link: latency
    is already amortized, the cost model must hold the coalesce width at
    1 and adaptivity must not regress (claim: >= 0.95x);
  * ``mixed_compute``  — balanced T_cloud ~= T_comp with per-chunk reader
    compute: the paper's overlap regime; adaptive must at least hold the
    fixed arm while re-estimating the link.

Emits ``name,us_per_call,derived`` CSV rows (like every other benchmark)
and writes the full A/B record to ``BENCH_read.json`` so CI tracks the
read-path speedup over time.

  PYTHONPATH=src python -m benchmarks.bench_adaptive_read [--smoke]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import TrkDataset, emit, fresh_store, make_trk_dataset
from repro.io import IOPolicy, PrefetchFS


def _median(times: list[float]) -> float:
    return float(np.median(times))


def run_arm(ds: TrkDataset, policy: IOPolicy, *, latency: float,
            bandwidth: float, chunk: int, compute_s_per_byte: float,
            reps: int) -> dict:
    """Stream the whole dataset through one reader configuration `reps`
    times (fresh store + link per rep so arms never share reservation
    state); returns median wall seconds + the last rep's FSStats."""
    times: list[float] = []
    snap: dict = {}
    for _ in range(reps):
        store = fresh_store(ds, latency=latency, bandwidth=bandwidth)
        fs = PrefetchFS(store, policy=policy)
        f = fs.open_many(ds.metas())
        nread = 0
        t0 = time.perf_counter()
        while True:
            data = f.read(chunk)
            if not data:
                break
            nread += len(data)
            if compute_s_per_byte:
                time.sleep(compute_s_per_byte * len(data))
        times.append(time.perf_counter() - t0)
        assert nread == ds.total_bytes, (nread, ds.total_bytes)
        f.close()
        snap = fs.stats().snapshot()
        fs.close()
    return dict(seconds=_median(times), fs_stats=snap)


def run_scenario(name: str, ds: TrkDataset, *, latency: float,
                 bandwidth: float, blocksize: int, chunk: int,
                 compute_s_per_byte: float = 0.0, depth: int = 2,
                 max_depth: int = 8, coalesce: int = 16,
                 reps: int = 3) -> dict:
    common = dict(engine="rolling", blocksize=blocksize,
                  eviction_interval_s=0.02, depth=depth)
    fixed_policy = IOPolicy(**common)
    adaptive_policy = IOPolicy(**common, max_depth=max_depth,
                               coalesce=coalesce, autotune=True)
    kw = dict(latency=latency, bandwidth=bandwidth, chunk=chunk,
              compute_s_per_byte=compute_s_per_byte, reps=reps)
    fixed = run_arm(ds, fixed_policy, **kw)
    adaptive = run_arm(ds, adaptive_policy, **kw)
    speedup = fixed["seconds"] / adaptive["seconds"]
    totals = adaptive["fs_stats"]["totals"]
    emit(f"read_{name}_fixed", fixed["seconds"] * 1e6,
         f"blocks={totals.get('blocks_fetched', 0)}")
    emit(f"read_{name}_adaptive", adaptive["seconds"] * 1e6,
         f"speedup={speedup:.2f}x;"
         f"requests={totals.get('store_requests', 0)}")
    return dict(
        fixed_s=fixed["seconds"],
        adaptive_s=adaptive["seconds"],
        speedup=speedup,
        adaptive_stats=adaptive["fs_stats"],
        fixed_stats=fixed["fs_stats"],
        params=dict(latency_s=latency, bandwidth_Bps=bandwidth,
                    blocksize=blocksize, chunk=chunk,
                    compute_s_per_byte=compute_s_per_byte, depth=depth,
                    max_depth=max_depth, coalesce=coalesce, reps=reps,
                    total_bytes=ds.total_bytes, n_files=len(ds.objects)),
    )


def main(quick: bool = False, out: str = "BENCH_read.json") -> dict:
    reps = 2 if quick else 3
    scale = 2 if quick else 1

    # Latency-bound: per-request latency (20 ms) dwarfs per-block payload
    # time (32 KiB / 200 MB/s ~= 0.16 ms) — Eq. 1's n_b*l_c regime.
    lat_ds = make_trk_dataset(16 // scale, streamlines_per_file=1400)
    latency_bound = run_scenario(
        "latency_bound", lat_ds, latency=0.02, bandwidth=200e6,
        blocksize=32 << 10, chunk=64 << 10, reps=reps,
    )

    # Bandwidth-bound: per-block payload time (256 KiB / 45 MB/s ~= 5.7 ms)
    # dwarfs latency (1 ms); the width must stay 1 and nothing may regress.
    # Cheapest scenario and a tight (>= 0.95x) claim: extra reps so the
    # median rides out scheduler noise.
    bw_ds = make_trk_dataset(4, streamlines_per_file=8000 // scale)
    bandwidth_bound = run_scenario(
        "bandwidth_bound", bw_ds, latency=0.001, bandwidth=45e6,
        blocksize=256 << 10, chunk=128 << 10, reps=max(reps, 5),
    )

    # Mixed: T_cloud ~= T_comp, the paper's overlap sweet spot, with the
    # reader burning real compute between chunks.
    mix_ds = make_trk_dataset(8 // scale, streamlines_per_file=2800)
    mixed_compute = run_scenario(
        "mixed_compute", mix_ds, latency=0.01, bandwidth=100e6,
        blocksize=64 << 10, chunk=64 << 10, compute_s_per_byte=1.5e-7,
        reps=reps,
    )

    record = dict(
        latency_bound=latency_bound,
        bandwidth_bound=bandwidth_bound,
        mixed_compute=mixed_compute,
        smoke=bool(quick),
    )
    with open(out, "w") as f:
        json.dump(record, f, indent=2)

    lb, bb = latency_bound, bandwidth_bound
    totals = lb["adaptive_stats"]["totals"]
    print(f"wrote {out}: latency-bound {lb['speedup']:.2f}x, "
          f"bandwidth-bound {bb['speedup']:.2f}x, "
          f"mixed {mixed_compute['speedup']:.2f}x "
          f"(adaptive vs fixed rolling)")

    # Acceptance claims (run.py reports AssertionError as CLAIM_FAILED).
    assert lb["speedup"] >= 1.3, (
        f"latency-bound adaptive speedup {lb['speedup']:.2f}x < 1.3x"
    )
    assert bb["speedup"] >= 0.95, (
        f"bandwidth-bound adaptive regressed: {bb['speedup']:.2f}x < 0.95x"
    )
    assert totals.get("store_requests", 0) < totals.get("blocks_fetched", 0), (
        "coalescing never engaged: "
        f"{totals.get('store_requests')} requests for "
        f"{totals.get('blocks_fetched')} blocks"
    )
    return record


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_read.json")
    args = ap.parse_args()
    main(quick=args.smoke, out=args.out)


if __name__ == "__main__":
    _cli()
