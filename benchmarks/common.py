"""Shared benchmark harness.

Constants are the paper's Table I values scaled down so every figure
reproduces in seconds on CI hardware (the validated quantities are the
RATIOS — speed-up curves, optimum locations, bounds — not absolute times):

                      paper              scaled          factor
  S3 latency          0.1 s              0.02 s          /5
  S3 bandwidth        91 MB/s            45 MB/s         /2
  memory bandwidth    2221 MB/s          1100 MB/s       /2
  memory latency      1.6 us             1.6 us          1
  file sizes          0.7-1.7 GiB        1.5-3.5 MB      /~500
  block size          8 MiB-2 GiB        32 KiB-4 MiB    /~500

Each benchmark reports `name,us_per_call,derived` CSV rows via `emit`.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.data.trk import synth_trk
from repro.io import IOPolicy, PrefetchFS, open_store
from repro.store import LinkModel, MemTier, SimS3Store
from repro.store.base import ObjectMeta

# Scaled Table I.
S3_LATENCY = 0.02
S3_BW = 45e6
MEM_LATENCY = 1.6e-6
MEM_BW = 1100e6
DEFAULT_BLOCK = 256 << 10       # scaled analog of the paper's 64 MiB
CACHE_BUDGET = 4 << 20          # scaled analog of the paper's 2 GiB tmpfs


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


@dataclass
class TrkDataset:
    objects: dict[str, bytes]

    @property
    def total_bytes(self) -> int:
        return sum(len(v) for v in self.objects.values())

    def metas(self) -> list[ObjectMeta]:
        return [ObjectMeta(k, len(v)) for k, v in sorted(self.objects.items())]


def make_trk_dataset(n_files: int, streamlines_per_file: int = 4000,
                     seed: int = 0, mean_points: int = 15) -> TrkDataset:
    """Short streamlines (~190 B) keep per-byte parse compute high enough
    that scaled T_comp ~= scaled T_cloud — the balanced regime where the
    paper's speed-ups are visible."""
    rng = np.random.default_rng(seed)
    objects = {
        f"hydi/shard_{i:04d}.trk": synth_trk(
            rng, streamlines_per_file, mean_points=mean_points
        )
        for i in range(n_files)
    }
    return TrkDataset(objects)


def store_uri(*, latency: float = S3_LATENCY, bandwidth: float = S3_BW,
              bucket: str = "s3") -> str:
    """The registry URI for a scaled-Table-I simulated S3 bucket."""
    return (f"sims3://{bucket}?latency_ms={latency * 1e3:g}"
            f"&bw_mbps={bandwidth / 1e6:g}")


def fresh_store(ds: TrkDataset, *, latency: float = S3_LATENCY,
                bandwidth: float = S3_BW) -> SimS3Store:
    """A new store + link per measurement (``open_store(..., fresh=True)``)
    so A/B runs never share link reservation state."""
    store = open_store(store_uri(latency=latency, bandwidth=bandwidth),
                       fresh=True)
    for k, v in ds.objects.items():
        store.backing.put(k, v)
    return store


def fresh_tiers(capacity: int = CACHE_BUDGET) -> list[MemTier]:
    return [
        MemTier(
            capacity,
            read_link=LinkModel(latency_s=MEM_LATENCY, bandwidth_Bps=MEM_BW,
                                name="tmpfs.r"),
            write_link=LinkModel(latency_s=MEM_LATENCY, bandwidth_Bps=MEM_BW,
                                 name="tmpfs.w"),
            name="tmpfs",
        )
    ]


def open_reader(store, metas, engine: str, *, blocksize: int = DEFAULT_BLOCK,
                tiers=None, **policy_overrides):
    """Every A/B benchmark constructs its readers through the PrefetchFS
    facade: same open call on both sides, only `IOPolicy(engine=...)`
    differs."""
    policy_overrides.setdefault("eviction_interval_s", 0.05)
    policy = IOPolicy(engine=engine, blocksize=blocksize, **policy_overrides)
    return PrefetchFS(store, policy=policy, tiers=tiers).open_many(metas)


def timed(fn, *, reps: int = 3) -> tuple[float, float, list[float]]:
    """Median + min of `reps` runs of fn() -> wall seconds."""
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), min(times), times
