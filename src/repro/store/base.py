"""Object-store protocol.

All remote data in the framework (training shards, `.trk` streamline files,
checkpoints) flows through this interface so that the simulated S3 store,
the real local-directory store, and any future real S3 binding are
interchangeable.

Writes come in two shapes: whole-object ``put`` and a multipart upload
(``start_multipart``) used by the write-behind pipeline in ``repro.io`` —
parts upload concurrently while the producer keeps writing, and
``complete()`` is the atomic publish point.
"""

from __future__ import annotations

import abc
import threading
from dataclasses import dataclass


class StoreError(RuntimeError):
    """Permanent store failure (bad key, malformed range)."""


class TransientStoreError(StoreError):
    """Retryable failure (simulated network fault, dropped connection)."""


class ThrottleError(TransientStoreError):
    """Backend pushback (S3 503 SlowDown): retryable, but the correct
    response is to back off AND shrink concurrency — `repro.io.retry`
    routes this subclass into the AIMD depth controller so the prefetch
    pipeline stops hammering a rate-limited store."""


class IntegrityError(TransientStoreError):
    """Payload bytes do not match their content digest (corrupt store
    response, bit-rotted cache block, mangled peer frame). Transient by
    design: a re-read from the next-more-authoritative source usually
    heals it, so the `Retrier` retries these like any network fault —
    but exhaustion re-raises *as* `IntegrityError` (not bare
    `StoreError`), so callers can distinguish "the data itself is bad
    everywhere" from ordinary unavailability."""


@dataclass(frozen=True)
class ObjectMeta:
    key: str
    size: int


def adjacent_runs(
    spans: list[tuple[int, int]],
) -> list[list[tuple[int, int]]]:
    """Group spans into maximal runs where each span starts exactly where
    the previous one ended — the unit a coalescing store can serve with a
    single request. Order is preserved; non-adjacent neighbours break the
    run."""
    runs: list[list[tuple[int, int]]] = []
    for span in spans:
        if runs and runs[-1][-1][1] == span[0]:
            runs[-1].append(span)
        else:
            runs.append([span])
    return runs


class MultipartUpload:
    """Portable client-buffered multipart upload.

    Parts accumulate in memory (``put_part`` is thread-safe and accepts
    parts in any order) and publish atomically with a single ``put()`` at
    ``complete()`` — correct for any store, no overlap benefit. Stores
    with a cheaper native path (the simulated S3's server-side assembly,
    the directory store's part files) override the ``_charge_part`` /
    ``_publish`` hooks or the methods themselves via
    :meth:`ObjectStore.start_multipart`.
    """

    def __init__(self, store: "ObjectStore", key: str) -> None:
        self.store = store
        self.key = key
        self._parts: dict[int, bytes] = {}
        self._lock = threading.Lock()
        self._aborted = False

    def put_part(self, index: int, data: bytes) -> None:
        """Upload part `index` (0-based). Re-putting the same index is
        idempotent (last write wins), which makes hedged uploads safe."""
        if index < 0:
            raise StoreError(f"multipart {self.key!r}: bad part index {index}")
        self._charge_part(data)
        with self._lock:
            if self._aborted:
                raise StoreError(f"multipart {self.key!r}: upload aborted")
            # Immutable input needs no defensive copy; only bytearray /
            # memoryview parts (mutable after return) are snapshotted.
            self._parts[index] = data if type(data) is bytes else bytes(data)

    def complete(self) -> None:
        """Assemble parts 0..n-1 and publish the object atomically. Safe
        to retry after a transient publish failure."""
        with self._lock:
            if self._aborted:
                raise StoreError(f"multipart {self.key!r}: upload aborted")
            parts = dict(self._parts)
        indexes = sorted(parts)
        if indexes != list(range(len(indexes))):
            raise StoreError(
                f"multipart {self.key!r}: non-contiguous parts {indexes}"
            )
        self._publish(b"".join(parts[i] for i in indexes))

    def abort(self) -> None:
        """Drop staged parts; the object is never published."""
        with self._lock:
            self._aborted = True
            self._parts.clear()

    # -- backend hooks -----------------------------------------------------
    def _charge_part(self, data: bytes) -> None:
        """Pay the transfer cost of one part at upload time (default: the
        cost is deferred to the final put in `_publish`)."""

    def _publish(self, data: bytes) -> None:
        self.store.put(self.key, data)


class ObjectStore(abc.ABC):
    """Byte-range addressable object store."""

    @abc.abstractmethod
    def list_objects(self, prefix: str = "") -> list[ObjectMeta]:
        ...

    @abc.abstractmethod
    def size(self, key: str) -> int:
        ...

    @abc.abstractmethod
    def get_range(self, key: str, start: int, end: int) -> bytes:
        """Fetch bytes [start, end) of `key`. One call == one request
        (pays one latency)."""

    def get_ranges(
        self, key: str, spans: list[tuple[int, int]]
    ) -> list[bytes]:
        """Vectorized range fetch: bytes for each [start, end) span of
        `key`, in span order.

        The portable fallback issues one request per span. Stores with a
        cheaper native path override it: the simulated S3 coalesces runs
        of adjacent spans into one request (one latency for the whole
        run), the directory store serves every span from a single file
        open. Adjacent spans SHOULD therefore be passed in stream order —
        that is what the prefetch scheduler's coalesced GETs do.
        """
        return [self.get_range(key, start, end) for start, end in spans]

    @abc.abstractmethod
    def put(self, key: str, data: bytes) -> None:
        ...

    @abc.abstractmethod
    def delete(self, key: str) -> None:
        ...

    def get(self, key: str) -> bytes:
        """Fetch the whole object. The portable fallback pays two
        round-trips (HEAD for the size, then the ranged GET); concrete
        stores override it to serve whole-object gets in one request."""
        return self.get_range(key, 0, self.size(key))

    # -- verified reads ----------------------------------------------------
    # The integrity layer's store edge. A *verified* read returns
    # ``(payload, digest)`` where the digest describes the bytes the
    # store believes it holds — the authoritative reference the engines
    # check received bytes against and carry through the cache tiers,
    # the peer wire protocol, and checkpoint manifests. The defaults
    # hash the returned payload, which is exact for leaf stores (their
    # ``get_range`` IS the authority); wrapper stores that can corrupt
    # or substitute bytes in transit (`FaultyStore`, `PeerAwareStore`)
    # override these so the digest is computed from the authoritative
    # inner bytes BEFORE any mangling — modeling S3's GetObject
    # checksum mode, where the server attests what it sent.

    def get_range_verified(self, key: str, start: int,
                           end: int) -> tuple[bytes, str]:
        """Fetch bytes [start, end) plus the store-attested content
        digest (see `repro.io.integrity.block_digest`)."""
        from repro.io.integrity import block_digest

        data = self.get_range(key, start, end)
        return data, block_digest(data)

    def get_ranges_verified(
        self, key: str, spans: list[tuple[int, int]]
    ) -> list[tuple[bytes, str]]:
        """Vectorized :meth:`get_range_verified` (coalescing stores keep
        their one-request-per-run behaviour via `get_ranges`)."""
        from repro.io.integrity import block_digest

        return [(d, block_digest(d)) for d in self.get_ranges(key, spans)]

    def digest_range(self, key: str, start: int, end: int) -> str:
        """Digest of bytes [start, end) without returning them — the
        authoritative cross-check `verify="full"` uses against
        peer-served payloads. The portable fallback reads the range
        (paying its full cost); stores with a cheap checksum RPC
        override it."""
        from repro.io.integrity import block_digest

        return block_digest(self.get_range(key, start, end))

    def start_multipart(self, key: str) -> MultipartUpload:
        """Begin a multipart upload of `key`; see `MultipartUpload`."""
        return MultipartUpload(self, key)

    def exists(self, key: str) -> bool:
        try:
            self.size(key)
            return True
        except TransientStoreError:
            # A throttled/faulting store does NOT mean the object is
            # missing — propagate so callers can retry.
            raise
        except StoreError:
            return False
