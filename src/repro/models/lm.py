"""Decoder-only LM backbone covering dense / MoE / SSM / hybrid / VLM archs.

The layer stack is expressed as `cfg.periods` repetitions of the config's
block pattern and lowered as ONE `jax.lax.scan` over stacked per-period
parameters — HLO size is O(pattern), not O(depth), keeping 40-cell x
2-mesh dry-run compiles tractable. Training remats each period (inputs
saved, internals recomputed), bounding live activations to the residual
stream.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import BlockDef, ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssd as S
from repro.models.spec import stacked
from repro.sharding.rules import constrain

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# Block spec / forward
# --------------------------------------------------------------------------- #
def block_spec(cfg: ModelConfig, bd: BlockDef) -> dict:
    spec: dict = {"norm1": L.norm_spec(cfg)}
    if bd.mixer == "attn":
        spec["attn"] = L.attention_spec(cfg)
    else:
        spec["mamba"] = S.mamba_spec(cfg)
    if bd.cross_attn:
        spec["norm_cross"] = L.norm_spec(cfg)
        spec["cross"] = L.attention_spec(cfg, cross=True)
    if bd.ffn is not None and not cfg.parallel_block:
        spec["norm2"] = L.norm_spec(cfg)
    if bd.ffn == "dense":
        spec["ffn"] = L.mlp_spec(cfg)
    elif bd.ffn == "moe":
        spec["ffn"] = M.moe_spec(cfg)
    return spec


def block_cache_spec(cfg: ModelConfig, bd: BlockDef) -> dict:
    """Logical-axis tree describing this block's decode cache."""
    spec: dict = {}
    if bd.mixer == "attn":
        spec["attn"] = L.cache_logical_axes()
    else:
        spec["mamba"] = S.mamba_cache_logical_axes()
    if bd.cross_attn:
        spec["cross"] = L.cache_logical_axes()
    return spec


def make_block_cache(cfg: ModelConfig, bd: BlockDef, batch: int, max_len: int,
                     *, cross_len: int = 0, length: int = 0) -> dict:
    cache: dict = {}
    if bd.mixer == "attn":
        cache["attn"] = L.make_cache(cfg, batch, max_len, length=length)
    else:
        cache["mamba"] = S.make_mamba_cache(cfg, batch)
    if bd.cross_attn:
        cache["cross"] = L.make_cache(cfg, batch, cross_len, length=cross_len)
    return cache


def block_fwd(
    p: dict,
    cfg: ModelConfig,
    bd: BlockDef,
    x: jax.Array,
    *,
    positions: jax.Array,
    cache: dict | None = None,
    update_cache: bool = False,
    enc_hidden: jax.Array | None = None,
    causal: bool = True,
    q_chunk: int = 512,
):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    rm = jnp.asarray(cfg.residual_multiplier, x.dtype)

    h = L.apply_norm(p["norm1"], cfg, x)
    if bd.mixer == "attn":
        attn_out, kv = L.attention(
            p["attn"], cfg, h,
            positions=positions,
            causal=causal,
            cache=None if cache is None else cache.get("attn"),
            update_cache=update_cache,
            q_chunk=q_chunk,
        )
        if kv is not None:
            new_cache["attn"] = kv
    else:
        attn_out, mc = S.mamba_block(
            p["mamba"], cfg, h,
            cache=None if cache is None else cache.get("mamba"),
            update_cache=update_cache,
        )
        if mc is not None:
            new_cache["mamba"] = mc

    if cfg.parallel_block and bd.ffn is not None:
        # Cohere: attn and FFN both read the same normed input.
        if bd.ffn == "dense":
            ffn_out = L.mlp(p["ffn"], cfg, h)
        else:
            ffn_out, aux = M.moe(p["ffn"], cfg, h)
        x = x + rm * (attn_out + ffn_out)
        return x, new_cache, aux

    x = x + rm * attn_out

    if bd.cross_attn:
        hc = L.apply_norm(p["norm_cross"], cfg, x)
        if enc_hidden is not None:
            cross_out, _ = L.attention(
                p["cross"], cfg, hc,
                positions=positions,
                causal=False,
                kv_source=enc_hidden,
                q_chunk=q_chunk,
            )
        else:
            cross_out, _ = L.attention(
                p["cross"], cfg, hc,
                positions=positions,
                causal=False,
                cache=cache.get("cross") if cache else None,
                update_cache=False,
                q_chunk=q_chunk,
            )
        x = x + rm * cross_out
        if cache is not None and "cross" in cache:
            new_cache["cross"] = cache["cross"]

    if bd.ffn is not None:
        h2 = L.apply_norm(p["norm2"], cfg, x)
        if bd.ffn == "dense":
            ffn_out = L.mlp(p["ffn"], cfg, h2)
        else:
            ffn_out, aux = M.moe(p["ffn"], cfg, h2)
        x = x + rm * ffn_out
    return x, new_cache, aux


# --------------------------------------------------------------------------- #
# Stack (scan over periods)
# --------------------------------------------------------------------------- #
def stack_spec(cfg: ModelConfig, pattern: tuple[BlockDef, ...] | None = None,
               periods: int | None = None) -> dict:
    pattern = pattern if pattern is not None else cfg.pattern
    periods = periods if periods is not None else cfg.periods
    period = {f"block{i}": block_spec(cfg, bd) for i, bd in enumerate(pattern)}
    return stacked(periods, period)


def make_stack_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                     pattern=None, periods=None, cross_len: int = 0,
                     length: int = 0) -> dict:
    pattern = pattern if pattern is not None else cfg.pattern
    periods = periods if periods is not None else cfg.periods
    per = {
        f"block{i}": make_block_cache(
            cfg, bd, batch, max_len, cross_len=cross_len, length=length
        )
        for i, bd in enumerate(pattern)
    }
    return jax.tree.map(lambda leaf: jnp.stack([leaf] * periods), per)


def stack_cache_axes(cfg: ModelConfig, pattern=None, periods_axis: bool = True):
    """Logical-axes tree (Ax leaves) structurally matching make_stack_cache;
    used to attach shardings to abstract decode-state inputs."""
    from repro.models.spec import Ax

    pattern = pattern if pattern is not None else cfg.pattern
    per = {f"block{i}": block_cache_spec(cfg, bd) for i, bd in enumerate(pattern)}
    if not periods_axis:
        return per
    return jax.tree.map(
        lambda leaf: Ax((None, *leaf.axes)) if isinstance(leaf, Ax) else leaf,
        per,
        is_leaf=lambda x: isinstance(x, Ax) or x is None,
    )


def stack_fwd(
    p_stack: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    caches: dict | None = None,
    update_cache: bool = False,
    enc_hidden: jax.Array | None = None,
    causal: bool = True,
    q_chunk: int = 512,
    remat: bool = False,
    pattern: tuple[BlockDef, ...] | None = None,
):
    """Scan the stacked period params over the residual stream. Caches ride
    in the scan CARRY with per-period indexed in-place updates — carrying
    them as xs/ys forces XLA to materialize input AND output stacked-cache
    buffers with a full copy per iteration (measured 4.3 GB/chip/layer of
    phantom traffic on command-r decode_32k).
    Returns (x, new_caches, total_aux)."""
    pattern = pattern if pattern is not None else cfg.pattern
    periods = jax.tree.leaves(p_stack)[0].shape[0]

    def period_fn(carry, xs):
        x, caches_all, aux_sum = carry
        pp, idx = xs
        pc = None
        if caches_all is not None:
            pc = jax.tree.map(
                lambda buf: jax.lax.dynamic_index_in_dim(buf, idx, 0,
                                                         keepdims=False),
                caches_all,
            )
        new_pc: dict = {}
        for i, bd in enumerate(pattern):
            x, nc, aux = block_fwd(
                pp[f"block{i}"], cfg, bd, x,
                positions=positions,
                cache=None if pc is None else pc[f"block{i}"],
                update_cache=update_cache,
                enc_hidden=enc_hidden,
                causal=causal,
                q_chunk=q_chunk,
            )
            new_pc[f"block{i}"] = nc
            aux_sum = aux_sum + aux
        if caches_all is not None:
            caches_all = jax.tree.map(
                lambda buf, leaf: jax.lax.dynamic_update_index_in_dim(
                    buf, leaf.astype(buf.dtype), idx, 0
                ),
                caches_all, new_pc,
            )
        return (x, caches_all, aux_sum), None

    fn = jax.checkpoint(period_fn) if remat else period_fn
    (x, new_caches, aux_sum), _ = jax.lax.scan(
        fn,
        (x, caches, jnp.zeros((), jnp.float32)),
        (p_stack, jnp.arange(periods, dtype=jnp.int32)),
    )
    return x, new_caches, aux_sum


# --------------------------------------------------------------------------- #
# LM spec + forward + loss
# --------------------------------------------------------------------------- #
def lm_spec(cfg: ModelConfig) -> dict:
    return {
        "embed": L.embedding_spec(cfg),
        "layers": stack_spec(cfg),
        "final_norm": L.norm_spec(cfg),
    }


def lm_inputs_to_hidden(p: dict, cfg: ModelConfig, inputs: jax.Array) -> jax.Array:
    """Token ids (B,S) -> embeddings, or pass through (B,S,D) embeddings
    (VLM/audio stub frontends)."""
    if inputs.ndim == 3:
        return inputs.astype(L.COMPUTE_DTYPE)
    return L.embed_tokens(p["embed"], cfg, inputs)


def lm_hidden(
    p: dict, cfg: ModelConfig, inputs: jax.Array, *,
    positions: jax.Array | None = None,
    caches=None, update_cache=False, q_chunk: int = 512, remat=False,
):
    seq = inputs.shape[1]
    if positions is None:
        positions = jnp.arange(seq, dtype=jnp.int32)
    x = lm_inputs_to_hidden(p, cfg, inputs)
    x = constrain(x, "batch", None, "residual")
    x, new_caches, aux = stack_fwd(
        p["layers"], cfg, x,
        positions=positions,
        caches=caches,
        update_cache=update_cache,
        q_chunk=q_chunk,
        remat=remat,
    )
    x = L.apply_norm(p["final_norm"], cfg, x)
    return x, new_caches, aux


def logits_from_hidden(p: dict, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    table = L.output_table(p["embed"])
    logits = jnp.einsum(
        "bsd,vd->bsv", h, table.astype(h.dtype),
        preferred_element_type=jnp.float32,
    ) * cfg.logit_scale
    v_pad = cfg.padded_vocab()
    if v_pad != cfg.vocab_size:
        invalid = jnp.arange(v_pad) >= cfg.vocab_size
        logits = jnp.where(invalid[None, None, :], NEG_INF, logits)
    return logits


def chunked_xent(
    p: dict, cfg: ModelConfig, h: jax.Array, labels: jax.Array,
    *, chunk: int = 512,
) -> jax.Array:
    """Mean next-token cross-entropy without materializing (B,S,V) at once.
    labels < 0 are masked out."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    hc = h.reshape(b, n, chunk, d).swapaxes(0, 1)        # (n, B, C, D)
    lc = labels.reshape(b, n, chunk).swapaxes(0, 1)      # (n, B, C)

    def body(carry, xs):
        loss_sum, count = carry
        h_c, l_c = xs
        logits = logits_from_hidden(p, cfg, h_c)          # (B, C, V) fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(l_c, 0)[..., None], axis=-1
        )[..., 0]
        valid = (l_c >= 0).astype(jnp.float32)
        loss_sum = loss_sum + jnp.sum((lse - picked) * valid)
        count = count + jnp.sum(valid)
        return (loss_sum, count), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, lc)
    )
    return loss_sum / jnp.maximum(count, 1.0)


def lm_loss(
    p: dict, cfg: ModelConfig, inputs: jax.Array, labels: jax.Array,
    *, q_chunk: int = 512, loss_chunk: int = 512, remat: bool = True,
) -> jax.Array:
    h, _, aux = lm_hidden(p, cfg, inputs, q_chunk=q_chunk, remat=remat)
    loss = chunked_xent(p, cfg, h, labels, chunk=loss_chunk)
    if cfg.is_moe:
        loss = loss + cfg.moe_aux_loss_weight * aux / max(cfg.num_layers, 1)
    return loss


# --------------------------------------------------------------------------- #
# Serving steps
# --------------------------------------------------------------------------- #
def lm_prefill(
    p: dict, cfg: ModelConfig, inputs: jax.Array, *, max_len: int | None = None,
    q_chunk: int = 512,
):
    """Process the prompt; returns (last-position logits (B,V), caches)."""
    b, s = inputs.shape[0], inputs.shape[1]
    max_len = max_len if max_len is not None else s
    caches = make_stack_cache(cfg, b, max_len)
    h, caches, _ = lm_hidden(
        p, cfg, inputs, caches=caches, update_cache=True, q_chunk=q_chunk
    )
    logits = logits_from_hidden(p, cfg, h[:, -1:, :])[:, 0]
    return logits, caches


def lm_decode_step(
    p: dict, cfg: ModelConfig, inputs: jax.Array, caches, position,
):
    """One token step. inputs: (B, 1) ids or (B, 1, D) embeds; `position` is
    the scalar global position of the new token. Returns (logits, caches)."""
    positions = jnp.asarray(position, jnp.int32)[None]
    h, new_caches, _ = lm_hidden(
        p, cfg, inputs,
        positions=positions,
        caches=caches,
        update_cache=True,
        q_chunk=1,
    )
    logits = logits_from_hidden(p, cfg, h)[:, 0]
    return logits, new_caches
