"""Rendezvous (highest-random-weight) hashing.

The one ownership function shared by the distributed-prefetch layers:
`repro.peer.PeerGroup` maps a block id to its home host with it, and
`BlockPlan.shard` partitions a prefetch plan with the SAME function — so
the blocks a host warms proactively are exactly the blocks its siblings
will come asking it for.

Rendezvous hashing (vs a ring with virtual nodes) keeps the property the
peer layer leans on: removing a candidate reassigns ONLY that candidate's
items, uniformly across the survivors — a dead host's blocks spread over
the remaining peers with no other block changing owner.
"""

from __future__ import annotations

import hashlib
from typing import Iterable, Sequence


def _weight(item: str, candidate: int) -> int:
    h = hashlib.blake2b(f"{candidate}\x00{item}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


def rendezvous_owner(item: str, candidates: Sequence[int] | Iterable[int]) -> int:
    """The candidate id owning `item`: argmax of a keyed hash, stable
    under candidate-set changes (deterministic across hosts and runs —
    no process seeding involved). Ties broken by the smaller id (blake2b
    collisions at digest_size=8 are negligible, but determinism must not
    depend on iteration order)."""
    best_id: int | None = None
    best_w = -1
    for c in candidates:
        w = _weight(item, c)
        if w > best_w or (w == best_w and (best_id is None or c < best_id)):
            best_id, best_w = c, w
    if best_id is None:
        raise ValueError("rendezvous_owner: empty candidate set")
    return best_id
