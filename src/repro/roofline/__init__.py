from repro.roofline.analysis import (
    HW_V5E,
    CollectiveStats,
    RooflineReport,
    analyze_compiled,
    collective_bytes,
    model_flops,
)

__all__ = [
    "HW_V5E",
    "CollectiveStats",
    "RooflineReport",
    "analyze_compiled",
    "collective_bytes",
    "model_flops",
]
