"""olmo-1b — AI2 OLMo dense transformer.

16L, d_model 2048, 16 heads (MHA), d_ff 8192, vocab 50304.
OLMo specifics: NON-PARAMETRIC LayerNorm (no scale, no bias), SwiGLU,
RoPE, no biases anywhere, tied embeddings. [arXiv:2402.00838; hf]
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="olmo-1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=50304,
        pattern=(BlockDef("attn", "dense"),),
        norm_type="layernorm",
        parametric_norm=False,
        act="silu",
        glu=True,
        tie_embeddings=True,
        rope_theta=10000.0,
        source="arXiv:2402.00838",
    )
)
