"""HSM placement A/B: mixed serve+loader workload on a mem+disk hierarchy.

The north-star contention: a latency-critical serving replica keeps its
weight blocks in the top (mem) tier while a bulk data-loader epoch sweep —
several times the mem tier's capacity — streams past. Two arms over
identical tiers and the same scaled-Table-I simulated S3 store:

  * ``hsm`` — `HSMIndex`: serve restores admit protected into mem, the
    loader enters at the disk level scan-resistant, capacity pressure
    demotes instead of deleting.
  * ``flat`` — plain `CacheIndex` (the pre-HSM flat-LRU walk): every
    class admits into mem first, so the sweep flushes the weights.

Acceptance (asserted): the serve class's top-tier hit rate on re-read is
HIGHER under the HSM, and the loader sweep does not displace the pinned
hot set (its blocks are still level 0 afterwards). Emits
``name,us_per_call,derived`` CSV rows and writes ``BENCH_hsm.json``.

  PYTHONPATH=src python -m benchmarks.bench_hsm [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

from benchmarks.common import (
    MEM_BW,
    MEM_LATENCY,
    S3_BW,
    S3_LATENCY,
    emit,
    make_trk_dataset,
)
from repro.io import IOPolicy, PrefetchFS, open_store
from repro.store import CacheIndex, DirTier, HSMIndex, LinkModel, MemTier

DISK_LATENCY = 1e-4
DISK_BW = 500e6


def _store(ds, hot: bytes, ckpt: bytes, bucket: str):
    store = open_store(
        f"sims3://{bucket}?latency_ms={S3_LATENCY * 1e3:g}"
        f"&bw_mbps={S3_BW / 1e6:g}",
        fresh=True,
    )
    store.backing.put("weights/hot", hot)
    store.backing.put("ckpt/state", ckpt)
    for k, v in ds.objects.items():
        store.backing.put(k, v)
    return store


def _tiers(mem_cap: int, disk_cap: int, root: str):
    mem = MemTier(
        mem_cap,
        read_link=LinkModel(latency_s=MEM_LATENCY, bandwidth_Bps=MEM_BW,
                            name="hsm.mem.r"),
        write_link=LinkModel(latency_s=MEM_LATENCY, bandwidth_Bps=MEM_BW,
                             name="hsm.mem.w"),
        name="hsm.mem",
    )
    disk = DirTier(
        disk_cap, root=root,
        read_link=LinkModel(latency_s=DISK_LATENCY, bandwidth_Bps=DISK_BW,
                            name="hsm.disk.r"),
        write_link=LinkModel(latency_s=DISK_LATENCY, bandwidth_Bps=DISK_BW,
                             name="hsm.disk.w"),
        name="hsm.disk",
    )
    return [mem, disk]


def _run_arm(arm: str, ds, hot: bytes, ckpt: bytes, *, mem_cap: int,
             disk_cap: int, blocksize: int, root: str) -> dict:
    """One full mixed workload: serve restore -> ckpt restore (overflows
    mem) -> loader epoch sweep -> serve re-read. Returns placement +
    timing measurements."""
    store = _store(ds, hot, ckpt, f"bench-hsm-{arm}")
    tiers = _tiers(mem_cap, disk_cap, root)
    if arm == "hsm":
        index = HSMIndex(tiers, mover_interval_s=None)
    else:
        index = CacheIndex(tiers, keep_cached=True)
    fs = PrefetchFS(store, policy=IOPolicy(
        engine="sequential", blocksize=blocksize, keep_cached=True),
        tiers=tiers, index=index)

    serve_pol = IOPolicy(engine="sequential", blocksize=blocksize,
                         keep_cached=True, io_class="serve")
    ckpt_pol = IOPolicy(engine="sequential", blocksize=blocksize,
                        keep_cached=True, io_class="ckpt")
    loader_pol = IOPolicy(engine="sequential", blocksize=blocksize,
                          keep_cached=True, io_class="loader")

    # Phase 1: serving replica restores its weights (cold, from S3).
    with fs.open("weights/hot", policy=serve_pol) as f:
        assert f.read() == hot
    mem = tiers[0]
    hot_blocks = [bid for bid, _ in mem.resident_blocks()
                  if bid.startswith("weights/hot")]
    nhot = len(hot_blocks)

    # Phase 1b: a checkpoint restore bigger than the remaining mem
    # headroom — top-tier pressure. The HSM demotes the unprotected ckpt
    # blocks down to disk; the flat walk evicts whatever is LRU (including
    # the serve weights).
    with fs.open("ckpt/state", policy=ckpt_pol) as f:
        assert f.read() == ckpt

    # Phase 2: a full epoch sweep, several times mem capacity.
    t0 = time.perf_counter()
    for k in sorted(ds.objects):
        with fs.open(k, policy=loader_pol) as f:
            assert len(f.read()) == len(ds.objects[k])
    sweep_s = time.perf_counter() - t0
    hot_in_mem_after = sum(1 for bid in hot_blocks if mem.contains(bid))

    # Phase 3: the replica re-reads its weights (steady-state serving).
    t0 = time.perf_counter()
    with fs.open("weights/hot", policy=serve_pol) as f:
        assert f.read() == hot
    reread_s = time.perf_counter() - t0
    snap = fs.stats().snapshot()
    hsm = snap.get("hsm") or {}
    top_hits = (hsm.get("class_hits", {}).get("serve:hsm.mem", 0)
                if arm == "hsm"
                else sum(1 for bid in hot_blocks if mem.contains(bid)))
    cold_blocks = (nhot + -(-len(ckpt) // blocksize)
                   + sum(-(-len(v) // blocksize) for v in ds.objects.values()))
    store_refetches = snap["totals"].get("blocks_fetched", 0) - cold_blocks
    fs.close()
    if arm == "hsm":
        index.close()
    for t in tiers:
        t.close()
    return dict(
        arm=arm,
        hot_blocks=nhot,
        hot_in_mem_after_sweep=hot_in_mem_after,
        serve_top_tier_hit_rate=(top_hits / (2 * nhot) if arm == "hsm"
                                 else hot_in_mem_after / nhot),
        sweep_s=sweep_s,
        reread_s=reread_s,
        reread_store_refetches=max(0, store_refetches),
        hsm=hsm,
    )


def bench_mixed(n_files: int, blocksize: int, tmp: str) -> dict:
    ds = make_trk_dataset(n_files, streamlines_per_file=4000)
    hot = bytes(range(256)) * ((3 * blocksize) // 256)   # 3-block hot set
    ckpt = bytes(range(255, -1, -1)) * ((4 * blocksize) // 256)
    mem_cap = 4 * blocksize                              # ckpt alone fills it
    disk_cap = 4 * (ds.total_bytes + len(hot) + len(ckpt))

    res = {}
    for arm in ("hsm", "flat"):
        root = os.path.join(tmp, arm)
        res[arm] = _run_arm(arm, ds, hot, ckpt, mem_cap=mem_cap,
                            disk_cap=disk_cap, blocksize=blocksize,
                            root=root)

    h, fl = res["hsm"], res["flat"]
    # Acceptance: HSM serves the hot set from the top tier through the
    # sweep; the flat walk let the loader flush it.
    assert h["hot_in_mem_after_sweep"] == h["hot_blocks"], (
        f"loader sweep displaced {h['hot_blocks'] - h['hot_in_mem_after_sweep']}"
        f"/{h['hot_blocks']} protected serve blocks"
    )
    assert h["serve_top_tier_hit_rate"] > fl["serve_top_tier_hit_rate"], (
        f"hsm top-tier hit rate {h['serve_top_tier_hit_rate']:.2f} not above "
        f"flat {fl['serve_top_tier_hit_rate']:.2f}"
    )
    assert h["hsm"]["demotions"] > 0      # pressure moved blocks down...
    assert h["hsm"]["forced_evictions"] == 0   # ...and never wedged

    speedup = fl["reread_s"] / h["reread_s"] if h["reread_s"] else 1.0
    emit("hsm_serve_reread", h["reread_s"] * 1e6,
         f"top_tier_rate={h['serve_top_tier_hit_rate']:.2f};"
         f"hot_in_mem={h['hot_in_mem_after_sweep']}/{h['hot_blocks']};"
         f"speedup={speedup:.2f}x")
    emit("flat_serve_reread", fl["reread_s"] * 1e6,
         f"top_tier_rate={fl['serve_top_tier_hit_rate']:.2f};"
         f"hot_in_mem={fl['hot_in_mem_after_sweep']}/{fl['hot_blocks']}")
    emit("hsm_loader_sweep", h["sweep_s"] * 1e6,
         f"demotions={h['hsm']['demotions']};"
         f"promotions={h['hsm']['promotions']};"
         f"evictions={h['hsm']['evictions']}")
    return dict(
        hsm=h, flat=fl, reread_speedup=speedup,
        params=dict(n_files=n_files, blocksize=blocksize,
                    mem_capacity=4 * blocksize,
                    dataset_bytes=ds.total_bytes),
    )


def main(quick: bool = False, out: str = "BENCH_hsm.json") -> None:
    with tempfile.TemporaryDirectory(prefix="bench-hsm-") as tmp:
        if quick:
            mixed = bench_mixed(n_files=4, blocksize=64 << 10, tmp=tmp)
        else:
            mixed = bench_mixed(n_files=12, blocksize=128 << 10, tmp=tmp)
    record = dict(
        mixed=mixed,
        link=dict(latency_s=S3_LATENCY, bandwidth_Bps=S3_BW),
        smoke=bool(quick),
    )
    with open(out, "w") as f:
        json.dump(record, f, indent=2)
    h, fl = mixed["hsm"], mixed["flat"]
    print(f"wrote {out}: serve top-tier hit rate {h['serve_top_tier_hit_rate']:.2f} "
          f"(flat {fl['serve_top_tier_hit_rate']:.2f}), hot set "
          f"{h['hot_in_mem_after_sweep']}/{h['hot_blocks']} resident through the "
          f"sweep, re-read speedup {mixed['reread_speedup']:.2f}x")


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_hsm.json")
    args = ap.parse_args()
    main(quick=args.smoke, out=args.out)


if __name__ == "__main__":
    _cli()
