"""Per-architecture smoke tests (reduced configs, CPU).

Each assigned arch instantiates a reduced config of the same family and
runs: (1) a train step forward asserting output shapes + finiteness,
(2) prefill + decode, (3) incremental-decode == full-forward consistency
(the KV/SSM cache correctness property).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, get_config
from repro.models import make_model

ARCHS = sorted(all_configs().keys())


def _train_batch(cfg, key, b=2, s=32):
    if cfg.is_encdec:
        return dict(
            enc_inputs=jax.random.normal(key, (b, s, cfg.d_model), jnp.bfloat16),
            dec_ids=jax.random.randint(key, (b, s), 0, cfg.vocab_size),
            labels=jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        )
    if cfg.embed_inputs:
        return dict(
            inputs=jax.random.normal(key, (b, s, cfg.d_model), jnp.bfloat16),
            labels=jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        )
    return dict(
        inputs=jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        labels=jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    )


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).reduced()
            m = make_model(cfg)
            cache[name] = (m, m.init(jax.random.key(hash(name) % 2**31)))
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_loss_finite(models, arch):
    m, params = models(arch)
    batch = _train_batch(m.cfg, jax.random.key(0))
    loss = m.loss(params, batch, q_chunk=16, loss_chunk=16)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss={loss}"
    # Initialization sanity: random-guess loss is ~ln(vocab).
    assert float(loss) < np.log(m.cfg.vocab_size) + 2.0


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_finite(models, arch):
    m, params = models(arch)
    batch = _train_batch(m.cfg, jax.random.key(1), b=1, s=16)
    grads = jax.grad(lambda p: m.loss(p, batch, q_chunk=16, loss_chunk=16))(params)
    flat = jax.tree.leaves(grads)
    assert all(jnp.isfinite(g).all() for g in flat), arch
    # Gradients reach every parameter group (no silently dead branches)
    # except known-structural cases (e.g. unused padding rows).
    nonzero = sum(float(jnp.abs(g).sum()) > 0 for g in flat)
    assert nonzero / len(flat) > 0.8, f"{arch}: only {nonzero}/{len(flat)} grads nonzero"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(models, arch):
    m, params = models(arch)
    cfg = m.cfg
    b, s = 2, 32
    if cfg.is_encdec:
        batch = dict(
            enc_inputs=jax.random.normal(jax.random.key(2), (b, s, cfg.d_model), jnp.bfloat16),
            dec_prompt=jnp.ones((b, 8), jnp.int32),
        )
    elif cfg.embed_inputs:
        batch = dict(inputs=jax.random.normal(jax.random.key(2), (b, s, cfg.d_model), jnp.bfloat16))
    else:
        batch = dict(inputs=jnp.ones((b, s), jnp.int32))
    logits, caches = m.prefill(params, batch, q_chunk=16)
    assert logits.shape == (b, cfg.padded_vocab())
    assert jnp.isfinite(logits[:, : cfg.vocab_size]).all()

    caches = m.make_decode_caches(b, s, filled=True)
    logits2, _ = m.decode_step(params, m.decode_inputs(b), caches, s - 1)
    assert logits2.shape == (b, cfg.padded_vocab())
    assert jnp.isfinite(logits2[:, : cfg.vocab_size]).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_incremental_decode_matches_full_forward(models, arch):
    """Prefill(s) then decode tokens one-by-one must reproduce the
    logits of a single full forward pass — the cache-correctness property
    for KV caches, conv windows, and SSM states alike."""
    m, params = models(arch)
    cfg = m.cfg
    if cfg.is_encdec:
        pytest.skip("covered by test_encdec_incremental below")
    if cfg.is_moe:
        # Capacity-limited routing legitimately drops tokens in batched
        # passes but never in single-token decode; compare drop-free.
        from dataclasses import replace

        cfg = replace(cfg, moe_capacity_factor=float(cfg.moe_num_experts))
        m = make_model(cfg)
    b, s_total, s_prefill = 1, 24, 16
    key = jax.random.key(3)
    if cfg.embed_inputs:
        full_inputs = jax.random.normal(key, (b, s_total, cfg.d_model), jnp.bfloat16)
    else:
        full_inputs = jax.random.randint(key, (b, s_total), 0, cfg.vocab_size)

    # Reference: full forward, logits at every position.
    from repro.models import lm as LM

    h, _, _ = LM.lm_hidden(params, cfg, full_inputs, q_chunk=8)
    ref_logits = LM.logits_from_hidden(params, cfg, h)  # (B, S, V)

    # Incremental: prefill then single-token decode steps.
    prompt = full_inputs[:, :s_prefill]
    caches = m.make_decode_caches(b, s_total, filled=False)
    h_p, caches, _ = LM.lm_hidden(
        params, cfg, prompt, caches=caches, update_cache=True, q_chunk=8
    )
    last = LM.logits_from_hidden(params, cfg, h_p[:, -1:, :])[:, 0]
    np.testing.assert_allclose(
        np.asarray(last, np.float32),
        np.asarray(ref_logits[:, s_prefill - 1], np.float32),
        rtol=0.15, atol=0.15,
    )
    for t in range(s_prefill, s_total):
        tok = full_inputs[:, t : t + 1]
        logits_t, caches = m.decode_step(params, tok, caches, t)
        np.testing.assert_allclose(
            np.asarray(logits_t, np.float32),
            np.asarray(ref_logits[:, t], np.float32),
            rtol=0.15, atol=0.15,
            err_msg=f"{arch}: decode step {t} diverges from full forward",
        )


def test_encdec_incremental():
    cfg = get_config("whisper-large-v3").reduced()
    m = make_model(cfg)
    params = m.init(jax.random.key(0))
    b, s_enc, s_dec = 1, 16, 16
    enc = jax.random.normal(jax.random.key(4), (b, s_enc, cfg.d_model), jnp.bfloat16)
    dec_ids = jax.random.randint(jax.random.key(5), (b, s_dec), 0, cfg.vocab_size)

    from repro.models import encdec as ED
    from repro.models import lm as LM

    enc_h = ED.encode(params, cfg, enc, q_chunk=8)
    h = ED.decode_train(params, cfg, enc_h, dec_ids, q_chunk=8)
    ref_logits = LM.logits_from_hidden(params, cfg, h)

    # Prefill 8 tokens, decode the rest one-by-one.
    logits, caches = ED.encdec_prefill(
        params, cfg, enc, dec_ids[:, :8], max_len=s_dec, q_chunk=8
    )
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(ref_logits[:, 7], np.float32),
        rtol=0.15, atol=0.15,
    )
    for t in range(8, s_dec):
        logits, caches = ED.encdec_decode_step(
            params, cfg, dec_ids[:, t : t + 1], caches, t
        )
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(ref_logits[:, t], np.float32),
            rtol=0.15, atol=0.15,
            err_msg=f"whisper decode step {t}",
        )


def test_full_configs_match_assignment():
    """The registered full configs carry the exact assigned dimensions."""
    expect = {
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
    }
    for name, (nl, d, h, kv, ff, v) in expect.items():
        cfg = get_config(name)
        assert cfg.num_layers == nl, name
        assert cfg.d_model == d, name
        assert cfg.num_heads == h, name
        assert cfg.num_kv_heads == kv, name
        assert cfg.d_ff == ff, name
        assert cfg.vocab_size == v, name
    # MoE / SSM extras
    assert get_config("jamba-1.5-large-398b").moe_num_experts == 16
    assert get_config("jamba-1.5-large-398b").moe_top_k == 2
    assert get_config("granite-moe-3b-a800m").moe_num_experts == 40
    assert get_config("granite-moe-3b-a800m").moe_top_k == 8
    assert get_config("dbrx-132b").moe_num_experts == 16
    assert get_config("dbrx-132b").moe_top_k == 4
    assert get_config("mamba2-1.3b").ssm_state == 128


def test_param_counts_match_billing():
    """Full-config parameter counts land near the names on the tin."""

    expect_b = {
        "command-r-plus-104b": (95, 115),
        "codeqwen1.5-7b": (6, 8.5),
        "smollm-135m": (0.1, 0.2),
        "olmo-1b": (0.9, 1.4),
        "llava-next-mistral-7b": (6.5, 8),
        "jamba-1.5-large-398b": (330, 440),
        "dbrx-132b": (120, 140),
        "granite-moe-3b-a800m": (2.5, 4),
        "mamba2-1.3b": (1.0, 1.6),
    }
    for name, (lo, hi) in expect_b.items():
        n = make_model(get_config(name)).param_count() / 1e9
        assert lo <= n <= hi, f"{name}: {n:.1f}B not in [{lo}, {hi}]"


def test_active_params_moe():
    granite = make_model(get_config("granite-moe-3b-a800m"))
    active = granite.active_param_count() / 1e9
    assert 0.5 <= active <= 1.2, f"granite active {active:.2f}B"
