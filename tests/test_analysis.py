"""Tests for the `repro.analysis` static analyzer.

Three layers:

1. Paired fixtures per rule: each "bad" snippet fires exactly its rule
   and the matching "good" snippet is clean, so rule heuristics cannot
   silently widen or narrow.
2. The suppression and baseline machinery round-trips.
3. The gate itself: running the analyzer over this repo's real `src/`
   and `tests/` trees yields zero unsuppressed findings and an acyclic
   lock graph — the exact check CI runs.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import threading

import pytest

from repro.analysis import (
    Baseline,
    Report,
    all_rules,
    analyze,
    build_lock_graph,
    get_rule,
    load_project,
    render_json,
    render_text,
)

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))


def run_on(tmp_path, sources: dict[str, str]):
    """Write `sources` (relpath -> code) under tmp_path and analyze."""
    for rel, code in sources.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(code)
    _, findings = analyze([str(tmp_path)])
    return findings


def fired(findings) -> set[str]:
    return {f.rule for f in findings if not f.suppressed}


# --------------------------------------------------------------------------- #
# Rule fixtures: bad fires exactly its rule, good is clean.
# --------------------------------------------------------------------------- #

RULE_FIXTURES = {
    "RP001": (
        # bad: bare acquire with no finally-release
        """
import threading

class Box:
    def __init__(self):
        self._lock = threading.Lock()
        self.x = 0

    def bump(self):
        self._lock.acquire()
        self.x += 1
        self._lock.release()
""",
        # good: release lives in a finally block
        """
import threading

class Box:
    def __init__(self):
        self._lock = threading.Lock()
        self.x = 0

    def bump(self):
        self._lock.acquire()
        try:
            self.x += 1
        finally:
            self._lock.release()
""",
    ),
    "RP002": (
        # bad: store round-trip while holding the lock
        """
import threading

class Cache:
    def __init__(self, store):
        self._lock = threading.Lock()
        self.store = store
        self.blocks = {}

    def fill(self, key):
        with self._lock:
            self.blocks[key] = self.store.get_range(key, 0, 1 << 20)
""",
        # good: fetch outside, publish under the lock
        """
import threading

class Cache:
    def __init__(self, store):
        self._lock = threading.Lock()
        self.store = store
        self.blocks = {}

    def fill(self, key):
        data = self.store.get_range(key, 0, 1 << 20)
        with self._lock:
            self.blocks[key] = data
""",
    ),
    "RP003": (
        # bad: wait() guarded by `if`, not `while`
        """
import threading

class Gate:
    def __init__(self):
        self._cond = threading.Condition()
        self.ready = False

    def block(self):
        with self._cond:
            if not self.ready:
                self._cond.wait()
""",
        # good: wait() re-checks its predicate in a loop
        """
import threading

class Gate:
    def __init__(self):
        self._cond = threading.Condition()
        self.ready = False

    def block(self):
        with self._cond:
            while not self.ready:
                self._cond.wait()
""",
    ),
    "RP004": (
        # bad: hand-rolled exponential backoff in an except handler
        """
import time

def fetch(fn):
    for attempt in range(5):
        try:
            return fn()
        except OSError:
            time.sleep(0.1 * 2 ** attempt)
    raise OSError("gave up")
""",
        # good: the handler classifies and re-raises; pacing is the
        # retry layer's job
        """
def fetch(fn):
    try:
        return fn()
    except OSError as e:
        raise TimeoutError(str(e)) from e
""",
    ),
    "RP005": (
        # bad: broad handler that swallows everything
        """
def probe(fn):
    try:
        return fn()
    except Exception:
        return None
""",
        # good: broad handler that re-raises
        """
def probe(fn):
    try:
        return fn()
    except Exception:
        raise
""",
    ),
    "RP006": (
        # bad: owned thread with no join path anywhere in the class
        """
import threading

class Pump:
    def __init__(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        pass
""",
        # good: close() reaps the thread
        """
import threading

class Pump:
    def __init__(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        pass

    def close(self):
        self._thread.join()
""",
    ),
    "RP007": (
        # bad: range-get bytes published to a tier unchecked
        """
class Mirror:
    def __init__(self, store, tier):
        self.store = store
        self.tier = tier

    def pull(self, key):
        data = self.store.get_range(key, 0, 4096)
        self.tier.write(key, data)
""",
        # good: length-checked before publish
        """
class Mirror:
    def __init__(self, store, tier):
        self.store = store
        self.tier = tier

    def pull(self, key):
        data = self.store.get_range(key, 0, 4096)
        if len(data) != 4096:
            raise ValueError("short read")
        self.tier.write(key, data)
""",
    ),
    "RP008": (
        # bad: unseeded randomness in a test module
        """
import random

def test_pick():
    assert random.randint(0, 5) >= 0
""",
        # good: module seeds its RNG
        """
import random

random.seed(1234)

def test_pick():
    assert random.randint(0, 5) >= 0
""",
    ),
    "RP009": (
        # bad: leader flight leaks if prepare() raises; the waiter
        # branch exits without join()/leave()
        """
def fetch(index, bid, data, prepare):
    kind, handle = index.acquire(bid)
    if kind == "leader":
        prepare(data)
        index.publish(handle, data, len(data))
""",
        # good: leader aborts on the error edge, waiter joins or leaves
        """
def fetch(index, bid, data):
    kind, handle = index.acquire(bid)
    if kind == "leader":
        try:
            index.publish(handle, data, len(data))
        except BaseException:
            index.abort_fetch(handle)
            raise
    elif kind == "wait":
        if index.join(handle, timeout=5.0) is None:
            index.leave(handle)
""",
    ),
    "RP010": (
        # bad: the pin is released twice
        """
def read_block(index, bid):
    kind, tier = index.acquire(bid)
    assert kind == "hit"
    data = tier.read(bid, 0, 10)
    index.unpin(bid)
    index.unpin(bid)
    return data
""",
        # good: read while pinned, exactly one unpin
        """
def read_block(index, bid):
    kind, tier = index.acquire(bid)
    assert kind == "hit"
    data = tier.read(bid, 0, 10)
    index.unpin(bid)
    return data
""",
    ),
    "RP011": (
        # bad: reservation leaks on the write error edge and on the
        # normal exit (never committed)
        """
def stage(index, bid, payload):
    tier = index.reserve_space(len(payload))
    if tier is None:
        raise MemoryError("no space")
    tier.write(bid, payload)
""",
        # good: commit on success, cancel on the error edge
        """
def stage(index, bid, payload):
    tier = index.reserve_space(len(payload))
    if tier is None:
        raise MemoryError("no space")
    try:
        tier.write(bid, payload)
    except BaseException:
        tier.cancel(len(payload))
        raise
    tier.commit(len(payload))
""",
    ),
    "RP012": (
        # bad: a put_part failure orphans the multipart upload
        """
def push(store, key, data):
    mp = store.start_multipart(key)
    mp.put_part(0, data)
    mp.complete()
""",
        # good: abort on the error edge
        """
def push(store, key, data):
    mp = store.start_multipart(key)
    try:
        mp.put_part(0, data)
    except BaseException:
        mp.abort()
        raise
    mp.complete()
""",
    ),
    "RP013": (
        # bad: pool constructed, submitted to, never closed
        """
from repro.io.write import UploadPool

def drain(jobs):
    pool = UploadPool()
    for job in jobs:
        pool.submit(job)
""",
        # good: close() on every normal path
        """
from repro.io.write import UploadPool

def drain(jobs):
    pool = UploadPool()
    try:
        for job in jobs:
            pool.submit(job)
    finally:
        pool.close()
""",
    ),
}


@pytest.mark.parametrize("rule_id", sorted(RULE_FIXTURES))
def test_bad_fixture_fires_exactly_its_rule(tmp_path, rule_id):
    bad, _ = RULE_FIXTURES[rule_id]
    # RP008 only applies under a path containing "tests".
    rel = "tests/test_fx.py" if rule_id == "RP008" else "fx.py"
    findings = run_on(tmp_path, {rel: bad})
    assert fired(findings) == {rule_id}, [f.to_dict() for f in findings]


@pytest.mark.parametrize("rule_id", sorted(RULE_FIXTURES))
def test_good_fixture_is_clean(tmp_path, rule_id):
    _, good = RULE_FIXTURES[rule_id]
    rel = "tests/test_fx.py" if rule_id == "RP008" else "fx.py"
    findings = run_on(tmp_path, {rel: good})
    assert fired(findings) == set(), [f.to_dict() for f in findings]


def test_every_registered_rule_has_a_fixture_pair():
    assert {spec.rule_id for spec in all_rules()} == set(RULE_FIXTURES)


def test_rule_metadata_complete():
    for spec in all_rules():
        assert spec.summary and spec.rationale
    assert get_rule("RP008").only_paths == ("tests",)


# --------------------------------------------------------------------------- #
# Suppressions
# --------------------------------------------------------------------------- #

def test_suppression_with_reason_silences(tmp_path):
    bad, _ = RULE_FIXTURES["RP005"]
    code = bad.replace(
        "except Exception:",
        "except Exception:  # repro: allow[RP005] — probe is best-effort",
    )
    findings = run_on(tmp_path, {"fx.py": code})
    assert fired(findings) == set()
    sup = [f for f in findings if f.suppressed]
    assert len(sup) == 1
    assert sup[0].rule == "RP005"
    assert sup[0].suppress_reason == "probe is best-effort"


def test_suppression_standalone_comment_covers_next_line(tmp_path):
    bad, _ = RULE_FIXTURES["RP005"]
    code = bad.replace(
        "    except Exception:",
        "    # repro: allow[RP005] — probe is best-effort\n"
        "    except Exception:",
    )
    findings = run_on(tmp_path, {"fx.py": code})
    assert fired(findings) == set()


def test_suppression_without_reason_is_rp000(tmp_path):
    bad, _ = RULE_FIXTURES["RP005"]
    # (concatenated so the scanner does not read this literal as a
    # malformed suppression of this very file)
    code = bad.replace(
        "except Exception:",
        "except Exception:  # repro: " + "allow[RP005]",
    )
    findings = run_on(tmp_path, {"fx.py": code})
    assert "RP000" in fired(findings)


def test_suppression_for_other_rule_does_not_silence(tmp_path):
    bad, _ = RULE_FIXTURES["RP005"]
    code = bad.replace(
        "except Exception:",
        "except Exception:  # repro: allow[RP001] — wrong rule",
    )
    findings = run_on(tmp_path, {"fx.py": code})
    assert "RP005" in fired(findings)


# --------------------------------------------------------------------------- #
# Baseline
# --------------------------------------------------------------------------- #

def test_baseline_round_trip(tmp_path):
    bad, _ = RULE_FIXTURES["RP005"]
    findings = run_on(tmp_path, {"fx.py": bad})
    assert fired(findings)

    bl_path = str(tmp_path / "baseline.json")
    Baseline.from_findings(findings).save(bl_path)
    loaded = Baseline.load(bl_path)

    report = Report.build(findings, baseline=loaded)
    assert report.ok
    assert not report.new
    assert report.baselined

    # Editing the flagged line changes the fingerprint -> finding is new.
    edited = bad.replace("return None", "return 0")
    findings2 = run_on(tmp_path, {"fx2.py": edited})
    report2 = Report.build(findings2, baseline=loaded)
    assert not report2.ok


def test_baseline_rejects_unknown_version(tmp_path):
    p = tmp_path / "bl.json"
    p.write_text(json.dumps({"version": 99, "findings": []}))
    with pytest.raises(ValueError):
        Baseline.load(str(p))


def test_reports_render(tmp_path):
    bad, _ = RULE_FIXTURES["RP005"]
    findings = run_on(tmp_path, {"fx.py": bad})
    report = Report.build(findings)
    doc = json.loads(render_json(report))
    assert doc["ok"] is False
    assert doc["summary"]["new"] == 1
    text = render_text(report)
    assert "RP005" in text and "FAIL" in text


# --------------------------------------------------------------------------- #
# The real gate: this repo must be clean, and its lock graph acyclic.
# --------------------------------------------------------------------------- #

def test_repo_has_zero_unsuppressed_findings():
    _, findings = analyze([os.path.join(REPO_ROOT, "src"),
                           os.path.join(REPO_ROOT, "tests")])
    new = [f for f in findings if not f.suppressed]
    assert new == [], "\n".join(
        f"{f.location()}: {f.rule}: {f.message}" for f in new
    )


def test_repo_suppressions_all_carry_reasons():
    _, findings = analyze([os.path.join(REPO_ROOT, "src")])
    for f in findings:
        if f.suppressed:
            assert f.suppress_reason, f.location()


def test_lock_graph_is_acyclic_and_ordered():
    project, _ = load_project([os.path.join(REPO_ROOT, "src")])
    graph = build_lock_graph(project)
    assert graph.cycles() == []
    order = graph.topo_order()
    assert order is not None
    # The documented global order: the engine lock is outermost, the
    # index condition sits above the tier locks.
    pos = {name: i for i, name in enumerate(order)}
    assert pos["PrefetchFS._lock"] < pos["CacheIndex._cond"]
    assert pos["RollingPrefetcher._cond"] < pos["CacheIndex._cond"]
    assert pos["CacheIndex._cond"] < pos["CacheTier._lock"]


def test_lock_graph_aliases_subclass_locks():
    project, _ = load_project([os.path.join(REPO_ROOT, "src")])
    graph = build_lock_graph(project)
    assert graph.normalize("HSMIndex._cond") == "CacheIndex._cond"
    assert graph.normalize("MemTier._lock") == "CacheTier._lock"


def test_lock_cycle_detected(tmp_path):
    code = """
import threading

class A:
    def __init__(self, b: B):
        self._lock = threading.Lock()
        self.b = b

    def one(self):
        with self._lock:
            with self.b._lock:
                pass

class B:
    def __init__(self, a: A):
        self._lock = threading.Lock()
        self.a = a

    def two(self):
        with self._lock:
            with self.a._lock:
                pass
"""
    (tmp_path / "fx.py").write_text(code)
    project, _ = load_project([str(tmp_path)])
    graph = build_lock_graph(project)
    cycles = graph.cycles()
    assert cycles, graph.to_dict()
    assert {"A._lock", "B._lock"} <= set(cycles[0])
    assert graph.topo_order() is None


# --------------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------------- #

def _run_cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd, env=env,
    )


def test_cli_clean_tree_exits_zero(tmp_path):
    (tmp_path / "ok.py").write_text("x = 1\n")
    proc = _run_cli([str(tmp_path)], cwd=str(tmp_path))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout


def test_cli_findings_exit_one_and_json(tmp_path):
    bad, _ = RULE_FIXTURES["RP005"]
    (tmp_path / "fx.py").write_text(bad)
    proc = _run_cli([str(tmp_path), "--format", "json", "--no-lock-graph"],
                    cwd=str(tmp_path))
    assert proc.returncode == 1
    doc = json.loads(proc.stdout)
    assert doc["ok"] is False
    assert doc["findings"][0]["rule"] == "RP005"


def test_cli_missing_path_exits_two(tmp_path):
    proc = _run_cli([str(tmp_path / "nope")], cwd=str(tmp_path))
    assert proc.returncode == 2


def test_cli_write_baseline_then_gate_passes(tmp_path):
    bad, _ = RULE_FIXTURES["RP005"]
    (tmp_path / "fx.py").write_text(bad)
    bl = str(tmp_path / "bl.json")
    proc = _run_cli([str(tmp_path), "--baseline", bl, "--write-baseline"],
                    cwd=str(tmp_path))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    proc = _run_cli([str(tmp_path), "--baseline", bl], cwd=str(tmp_path))
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_check_baseline_fails_on_stale_fingerprints(tmp_path):
    bad, good = RULE_FIXTURES["RP005"]
    src = tmp_path / "fx.py"
    src.write_text(bad)
    bl = str(tmp_path / "bl.json")
    proc = _run_cli([str(tmp_path), "--baseline", bl, "--write-baseline"],
                    cwd=str(tmp_path))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    # The finding gets fixed; its baseline fingerprint is now stale.
    src.write_text(good)
    proc = _run_cli([str(tmp_path), "--baseline", bl], cwd=str(tmp_path))
    assert proc.returncode == 0      # without the flag: lenient
    proc = _run_cli([str(tmp_path), "--baseline", bl, "--check-baseline"],
                    cwd=str(tmp_path))
    assert proc.returncode == 1
    assert "stale" in proc.stdout + proc.stderr


def test_cli_check_locks_md_freshness(tmp_path):
    code = RULE_FIXTURES["RP001"][1]     # has a real lock attribute
    (tmp_path / "fx.py").write_text(code)
    md = tmp_path / "LOCKS.md"
    proc = _run_cli([str(tmp_path), "--locks-md", str(md)],
                    cwd=str(tmp_path))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    proc = _run_cli([str(tmp_path), "--check-locks-md", str(md)],
                    cwd=str(tmp_path))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    md.write_text(md.read_text() + "\nout of date\n")
    proc = _run_cli([str(tmp_path), "--check-locks-md", str(md)],
                    cwd=str(tmp_path))
    assert proc.returncode == 1
    assert "stale" in proc.stdout + proc.stderr
    # Missing file counts as stale too.
    proc = _run_cli([str(tmp_path), "--check-locks-md",
                     str(tmp_path / "absent.md")], cwd=str(tmp_path))
    assert proc.returncode == 1


def test_cli_check_locks_md_conflicts_with_no_lock_graph(tmp_path):
    (tmp_path / "fx.py").write_text("x = 1\n")
    proc = _run_cli([str(tmp_path), "--no-lock-graph",
                     "--check-locks-md", str(tmp_path / "LOCKS.md")],
                    cwd=str(tmp_path))
    assert proc.returncode == 2


# --------------------------------------------------------------------------- #
# Analyzer robustness: damaged inputs become per-file findings, never a
# crashed analyzer.
# --------------------------------------------------------------------------- #

def test_syntax_error_file_is_rp000_not_crash(tmp_path):
    (tmp_path / "broken.py").write_text("def broken(:\n")
    (tmp_path / "ok.py").write_text("x = 1\n")
    project, findings = load_project([str(tmp_path)])
    assert fired(findings) == {"RP000"}
    # The healthy file still got analyzed.
    assert any(m.path.endswith("ok.py") for m in project.modules)


def test_null_byte_file_is_rp000_not_crash(tmp_path):
    (tmp_path / "nul.py").write_bytes(b"x = 1\x00\n")
    _, findings = load_project([str(tmp_path)])
    assert fired(findings) == {"RP000"}


def test_non_utf8_file_is_rp000_not_crash(tmp_path):
    (tmp_path / "latin.py").write_bytes(b"s = '\xff\xfe'\n")
    _, findings = load_project([str(tmp_path)])
    assert fired(findings) == {"RP000"}


def test_unreadable_file_is_rp000_not_crash(tmp_path):
    # A dangling symlink raises OSError at open() even for root.
    (tmp_path / "gone.py").symlink_to(tmp_path / "no-such-target.py")
    (tmp_path / "ok.py").write_text("x = 1\n")
    project, findings = load_project([str(tmp_path)])
    assert fired(findings) == {"RP000"}
    assert any(m.path.endswith("ok.py") for m in project.modules)


# --------------------------------------------------------------------------- #
# Runtime lock-order tracing (the conftest fixture) agrees with the
# static graph.
# --------------------------------------------------------------------------- #

def test_traced_locks_record_real_nesting(traced_locks):
    from repro.store.tiers import CacheIndex, MemTier

    tier = MemTier(1 << 20)
    index = CacheIndex([tier])
    assert type(index._cond).__name__ == "_TracedCondition"
    kind, flight = index.acquire("blk")
    assert kind == "leader"
    tier.write("blk", b"x" * 64)
    index.publish(flight, tier, 64)
    index.unpin("blk")
    # The wrapper resolved the same name the static analyzer uses; the
    # fixture asserts edge consistency against the static graph on
    # teardown.
    assert index._cond._name == "CacheIndex._cond"
    assert tier._blk_lock._name == "MemTier._blk_lock"


def test_assert_order_consistent_flags_inversion():
    from conftest import LockOrderRecorder, assert_order_consistent

    project, _ = load_project([os.path.join(REPO_ROOT, "src")])
    graph = build_lock_graph(project)
    rec = LockOrderRecorder()
    # Invert a real static edge: runtime claims the index condition was
    # held while taking the engine lock.
    rec.edges[("CacheIndex._cond", "PrefetchFS._lock")] = "t0"
    with pytest.raises(AssertionError):
        assert_order_consistent(rec, graph)


def test_traced_lock_wrapper_mechanics(traced_locks):
    class Pair:
        def __init__(self):
            self.outer = threading.Lock()
            self.inner = threading.Lock()

        def nest(self):
            with self.outer:
                with self.inner:
                    pass

    Pair().nest()
    assert ("Pair.outer", "Pair.inner") in traced_locks.edges
