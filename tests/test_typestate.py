"""Tests for the path-sensitive typestate pass (rules RP009–RP013).

The paired good/bad snippet per rule lives in test_analysis.py's
RULE_FIXTURES (so the every-rule-has-a-fixture invariant covers them);
this file exercises the *interpreter semantics* the pass relies on:
discriminator refinement, exception edges, try/finally and `with`
discharge, escape-to-caller transfer, loop back-edge behaviour, the
publish-spawned pin, and suppression plumbing.
"""

from __future__ import annotations

from repro.analysis import analyze


_runs = 0


def run_on(tmp_path, sources: dict[str, str]):
    # Each call gets its own subtree so two runs in one test don't see
    # each other's files.
    global _runs
    _runs += 1
    root = tmp_path / f"run{_runs}"
    for rel, code in sources.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(code)
    _, findings = analyze([str(root)])
    return findings


def fired(findings) -> set[str]:
    return {f.rule for f in findings if not f.suppressed}


# --------------------------------------------------------------------------- #
# Discriminator refinement.
# --------------------------------------------------------------------------- #

def test_assert_kills_infeasible_discriminants(tmp_path):
    # `assert kind == "hit"` proves the leader/waiter obligations away.
    findings = run_on(tmp_path, {"fx.py": """
def read_block(index, bid):
    kind, tier = index.acquire(bid)
    assert kind == "hit"
    return tier.read(bid, 0, 10)
"""})
    assert fired(findings) == set()


def test_unrefined_acquire_reports_both_obligations(tmp_path):
    # No refinement at all: leader AND waiter leaks, anchored at the
    # acquire() call.
    findings = run_on(tmp_path, {"fx.py": """
def peek(index, bid):
    kind, handle = index.acquire(bid)
    return kind
"""})
    assert fired(findings) == {"RP009"}
    msgs = " ".join(f.message for f in findings)
    assert "leader flight" in msgs and "waiter handle" in msgs


def test_elif_chain_discharges_every_arm(tmp_path):
    findings = run_on(tmp_path, {"fx.py": """
def fetch(index, bid, data):
    kind, handle = index.acquire(bid)
    if kind == "leader":
        try:
            index.publish(handle, data, len(data))
        except BaseException:
            index.abort_fetch(handle)
            raise
    elif kind == "wait":
        index.join(handle, timeout=5.0)
    else:
        index.unpin(bid)
"""})
    assert fired(findings) == set()


def test_none_check_refines_value_handle_without_escaping(tmp_path):
    # `if tier is None` is a refinement mention, not an escape — the
    # reserved-path leak must still be reported.
    findings = run_on(tmp_path, {"fx.py": """
def stage(index, bid, payload):
    tier = index.reserve_space(len(payload))
    if tier is None:
        return None
    tier.write(bid, payload)
"""})
    assert fired(findings) == {"RP011"}


def test_bool_creator_in_if_test(tmp_path):
    # `if tier.reserve(n):` — true arm owns a reservation.
    bad = """
def place(tier, bid, data):
    if tier.reserve(len(data)):
        tier.write(bid, data)
"""
    good = """
def place(tier, bid, data):
    if tier.reserve(len(data)):
        try:
            tier.write(bid, data)
        except BaseException:
            tier.cancel(len(data))
            raise
        tier.commit(len(data))
"""
    assert fired(run_on(tmp_path, {"fx.py": bad})) == {"RP011"}
    assert fired(run_on(tmp_path, {"ok.py": good})) == set()


# --------------------------------------------------------------------------- #
# Immediate rules: double-unpin, use-after-release.
# --------------------------------------------------------------------------- #

def test_use_after_release_is_rp010(tmp_path):
    findings = run_on(tmp_path, {"fx.py": """
def read_block(index, bid):
    kind, tier = index.acquire(bid)
    assert kind == "hit"
    index.unpin(bid)
    return tier.read(bid, 0, 10)
"""})
    assert fired(findings) == {"RP010"}
    assert any("use-after-release" in f.message for f in findings)


def test_publish_spawns_pin_so_double_unpin_after_publish_fires(tmp_path):
    bad = """
def lead(index, bid, data):
    kind, handle = index.acquire(bid)
    assert kind == "leader"
    index.publish(handle, data, len(data))
    index.unpin(bid)
    index.unpin(bid)
"""
    good = """
def lead(index, bid, data):
    kind, handle = index.acquire(bid)
    assert kind == "leader"
    index.publish(handle, data, len(data))
    index.unpin(bid)
"""
    assert fired(run_on(tmp_path, {"fx.py": bad})) == {"RP010"}
    assert fired(run_on(tmp_path, {"ok.py": good})) == set()


def test_double_unpin_only_on_the_path_that_released(tmp_path):
    # The release happens on one branch only; the merge point unpin is
    # a double release on that path alone — still reported.
    findings = run_on(tmp_path, {"fx.py": """
def maybe(index, bid, early):
    kind, tier = index.acquire(bid)
    assert kind == "hit"
    if early:
        index.unpin(bid)
    index.unpin(bid)
"""})
    assert fired(findings) == {"RP010"}


# --------------------------------------------------------------------------- #
# Structural discharge: try/finally, with, escapes.
# --------------------------------------------------------------------------- #

def test_try_finally_discharges_lifecycle(tmp_path):
    findings = run_on(tmp_path, {"fx.py": """
from repro.io.write import UploadPool

def drain(jobs):
    pool = UploadPool()
    try:
        for job in jobs:
            pool.submit(job)
    finally:
        pool.close()
"""})
    assert fired(findings) == set()


def test_with_block_discharges_managed_creator(tmp_path):
    findings = run_on(tmp_path, {"fx.py": """
def put(fs, key, data):
    with fs.open_write(key) as w:
        w.write(data)
"""})
    assert fired(findings) == set()


def test_return_escapes_obligation_to_caller(tmp_path):
    findings = run_on(tmp_path, {"fx.py": """
def begin(index, bid):
    kind, handle = index.acquire(bid)
    assert kind == "leader"
    return handle
"""})
    assert fired(findings) == set()


def test_attribute_store_escapes_obligation(tmp_path):
    findings = run_on(tmp_path, {"fx.py": """
def park(self, index, bid):
    kind, handle = index.acquire(bid)
    assert kind == "leader"
    self.flight = handle
"""})
    assert fired(findings) == set()


def test_passing_handle_to_unknown_call_escapes(tmp_path):
    findings = run_on(tmp_path, {"fx.py": """
def hand_off(index, bid, finisher):
    kind, handle = index.acquire(bid)
    assert kind == "leader"
    finisher(handle)
"""})
    assert fired(findings) == set()


def test_loop_back_edge_escapes_inner_resources(tmp_path):
    # A resource created inside a loop body may be discharged by a later
    # iteration — under-approximate, not reported.
    findings = run_on(tmp_path, {"fx.py": """
def sweep(index, bids):
    for bid in bids:
        kind, handle = index.acquire(bid)
        assert kind == "leader"
"""})
    assert fired(findings) == set()


def test_resource_from_before_loop_keeps_its_state(tmp_path):
    findings = run_on(tmp_path, {"fx.py": """
def lead(index, bid, chunks):
    kind, handle = index.acquire(bid)
    assert kind == "leader"
    for c in chunks:
        len(c)
"""})
    assert fired(findings) == {"RP009"}


# --------------------------------------------------------------------------- #
# Exception-path gating and suppression.
# --------------------------------------------------------------------------- #

def test_exception_edges_not_checked_in_tests(tmp_path):
    # Leak only on the raise edge: reported in src, silent in a test
    # module (a test dying mid-protocol already fails loudly).
    code = """
def stage(index, bid, payload):
    tier = index.reserve_space(len(payload))
    if tier is None:
        return None
    tier.write(bid, payload)
    tier.commit(len(payload))
    return tier
"""
    assert fired(run_on(tmp_path, {"fx.py": code})) == {"RP011"}
    assert fired(run_on(tmp_path, {"tests/test_fx.py": code})) == set()


def test_suppression_with_reason_silences_typestate(tmp_path):
    findings = run_on(tmp_path, {"fx.py": """
def peek(index, bid):
    # repro: allow[RP009] — probe intentionally leaves the flight for reclaim
    kind, handle = index.acquire(bid)
    return kind
"""})
    assert fired(findings) == set()
    assert any(f.rule == "RP009" and f.suppressed for f in findings)


def test_self_receiver_does_not_create_obligation(tmp_path):
    # A CacheIndex method calling its own acquire() is implementing the
    # protocol, not consuming it.
    findings = run_on(tmp_path, {"fx.py": """
class CacheIndex:
    def reacquire(self, bid):
        kind, handle = self.acquire(bid)
        return kind, handle
"""})
    assert fired(findings) == set()
