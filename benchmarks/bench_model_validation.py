"""§II-B model validation: measured T_seq / T_pf vs Eq. 1-3 predictions.

Uses a synthetic workload with exactly-controlled compute (busy-sleep of
c bytes-per-second per block) so every model parameter (l_c, b_cr, c) is
known, then checks measured runtimes against the closed forms and the
measured speed-up against Eq. 3, including the S < 2 bound and the
balanced-pipeline maximum near T_cloud ~= T_comp.
"""

from __future__ import annotations

import time

from repro.core import cost_model
from repro.store import LinkModel, MemTier, SimS3Store
from repro.store.base import ObjectMeta

from benchmarks.common import emit, open_reader

LAT = 0.015
BW = 80e6
FILE_BYTES = 1 << 20
N_FILES = 2


def _store() -> SimS3Store:
    store = SimS3Store(link=LinkModel(latency_s=LAT, bandwidth_Bps=BW))
    payload = bytes(FILE_BYTES)
    for i in range(N_FILES):
        store.backing.put(f"f{i}", payload)
    return store


def _consume(f, blocksize: int, c: float) -> None:
    """Read block-by-block, spending exactly c seconds/byte of compute."""
    while True:
        data = f.read(blocksize)
        if not data:
            return
        deadline = time.perf_counter() + c * len(data)
        while time.perf_counter() < deadline:
            pass


def _measure(mode: str, blocksize: int, c: float) -> float:
    store = _store()
    metas = [ObjectMeta(f"f{i}", FILE_BYTES) for i in range(N_FILES)]
    if mode == "seq":
        f = open_reader(store, metas, "sequential", blocksize=blocksize)
    else:
        f = open_reader(store, metas, "rolling", blocksize=blocksize,
                        tiers=[MemTier(16 << 20)], eviction_interval_s=0.02)
    t0 = time.perf_counter()
    _consume(f, blocksize, c)
    elapsed = time.perf_counter() - t0
    f.close()
    return elapsed


def main(quick: bool = False) -> dict:
    total = N_FILES * FILE_BYTES
    results = {}
    cases = [
        ("balanced", 128 << 10, (LAT + (128 << 10) / BW) / (128 << 10)),
        ("compute_heavy", 128 << 10, 3 * (LAT + (128 << 10) / BW) / (128 << 10)),
        ("io_heavy", 128 << 10, 0.2 * (LAT + (128 << 10) / BW) / (128 << 10)),
    ]
    if quick:
        cases = cases[:2]
    for name, bs, c in cases:
        n_b = total // bs
        p = cost_model.CostParams(f=total, n_b=n_b, l_c=LAT, b_cr=BW, c=c)
        pred_seq, pred_pf = cost_model.t_seq(p), cost_model.t_pf(p)
        pred_sp = cost_model.speedup(p)

        t_seq = min(_measure("seq", bs, c) for _ in range(2))
        t_pf = min(_measure("pf", bs, c) for _ in range(2))
        sp = t_seq / t_pf
        results[name] = (sp, pred_sp)
        emit(
            f"model_validation_{name}",
            t_pf * 1e6,
            f"meas_seq={t_seq:.3f};pred_seq={pred_seq:.3f};"
            f"meas_pf={t_pf:.3f};pred_pf={pred_pf:.3f};"
            f"meas_S={sp:.3f};pred_S={pred_sp:.3f}",
        )
        # Measured vs predicted within 25% (threaded-runtime noise).
        assert abs(t_seq - pred_seq) / pred_seq < 0.25, (name, t_seq, pred_seq)
        assert abs(t_pf - pred_pf) / pred_pf < 0.30, (name, t_pf, pred_pf)
        assert sp < 2.0

    if not quick:
        # The balanced case should approach the bound hardest (Eq. 3).
        assert results["balanced"][0] >= results["io_heavy"][0] - 0.1
    return results


if __name__ == "__main__":
    main()
