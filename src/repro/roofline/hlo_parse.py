"""Mini HLO-text parser for roofline accounting.

XLA's built-in `cost_analysis()` visits `while` bodies ONCE — every layer
stack in this framework is a `lax.scan`, so its FLOPs/bytes undercount by
the trip count. This parser rebuilds the call graph (while / fusion / call
/ conditional), extracts loop trip counts from the condition computations
(scan conditions compare the induction variable against a literal), and
multiplies per-op costs accordingly:

  * FLOPs: every `dot` = 2 * prod(output dims) * prod(lhs contracting dims)
  * memory bytes: ~2x output bytes of every materializing instruction
    (read+write), with dynamic-update-slice charged at update size
    (in-place on the big operand), bookkeeping ops skipped
  * collective bytes: output bytes per collective, all-reduce x2 (ring AR
    moves ~2x payload), reduce-scatter charged at operand size

Shapes in the post-SPMD module are per-partition, so all totals are
per-chip.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "c64": 8, "c128": 16,
}

_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"(\([^()]*\)|[\w\[\],<>]+?\[[0-9,]*\](?:\{[^}]*\})?|\w+\[\])\s+"
    r"([\w\-]+)\((.*)$"
)
_SHAPE = re.compile(r"^(\w+)\[([0-9,]*)\]")
_CONST_INT = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")

SKIP_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "reshape", "copy-start",
    "copy-done", "add-dependency", "custom-call", "rng-get-and-update-state",
}

COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


@dataclass
class Instr:
    name: str
    dtype: str | None       # None for tuple-shaped
    shape: tuple[int, ...] | None
    opcode: str
    operands: list[str]
    attrs: str

    def out_bytes(self) -> float:
        if self.dtype is None or self.shape is None:
            return 0.0
        bpe = _DTYPE_BYTES.get(self.dtype)
        if bpe is None:
            return 0.0
        n = 1
        for d in self.shape:
            n *= d
        return float(n * bpe)


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


def _parse_shape(txt: str):
    m = _SHAPE.match(txt)
    if not m:
        return None, None
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


def _split_operands(arg_txt: str) -> list[str]:
    """Operand names from the call-site text (up to the closing paren at
    depth 0); operands look like `%name` possibly typed."""
    out, depth = [], 0
    for tok in re.finditer(r"[(){}]|%[\w.\-]+", arg_txt):
        t = tok.group(0)
        if t == "(":
            depth += 1
        elif t == ")":
            if depth == 0:
                break
            depth -= 1
        elif t in "{}":
            continue
        else:
            out.append(t[1:])
    return out


def parse_module(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry: str | None = None
    current: Computation | None = None
    for line in text.splitlines():
        if current is None:
            m = _COMP_START.match(line)
            if m:
                current = Computation(m.group(1))
                if line.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.startswith("}"):
            comps[current.name] = current
            current = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, shape_txt, opcode, rest = m.groups()
        dtype, dims = _parse_shape(shape_txt)
        instr = Instr(
            name=name,
            dtype=dtype,
            shape=dims,
            opcode=opcode,
            operands=_split_operands(rest),
            attrs=rest,
        )
        current.instrs.append(instr)
        current.by_name[name] = instr
    if current is not None:
        comps[current.name] = current
    return comps, entry


def _attr_ref(attrs: str, key: str) -> str | None:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _attr_refs(attrs: str, key: str) -> list[str]:
    m = re.search(key + r"=\{([^}]*)\}", attrs)
    if not m:
        return []
    return [s.strip().lstrip("%") for s in m.group(1).split(",") if s.strip()]


def trip_count(comps: dict[str, Computation], cond_name: str) -> int:
    """Scan conditions compare the induction variable to a literal bound;
    take the largest integer constant in the condition computation."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for m in _CONST_INT.finditer("\n".join(_raw_lines(comp))):
        best = max(best, int(m.group(1)))
    return best


def _raw_lines(comp: Computation) -> list[str]:
    # Reconstruct enough text for the constant regex.
    out = []
    for i in comp.instrs:
        if i.opcode == "constant":
            out.append(f"%{i.name} = {i.dtype}[] constant({i.attrs}")
    return out


def dot_flops(instr: Instr, comp: Computation) -> float:
    if instr.shape is None:
        return 0.0
    out_elems = 1
    for d in instr.shape:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    contract = 1
    if m and instr.operands:
        lhs = comp.by_name.get(instr.operands[0])
        if lhs is not None and lhs.shape is not None:
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(lhs.shape):
                    contract *= lhs.shape[idx]
    return 2.0 * out_elems * contract


def _conv_flops(instr: Instr, comp: Computation) -> float:
    # convolution: 2 * out_elems * (kernel spatial * in_channels) — rough.
    if instr.shape is None or len(instr.operands) < 2:
        return 0.0
    rhs = comp.by_name.get(instr.operands[1])
    if rhs is None or rhs.shape is None:
        return 0.0
    out_elems = 1
    for d in instr.shape:
        out_elems *= d
    kernel = 1
    for d in rhs.shape[:-1]:
        kernel *= d
    return 2.0 * out_elems * kernel


@dataclass
class HloCost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = field(default_factory=dict)
    collective_count: dict = field(default_factory=dict)
    while_trip_counts: list = field(default_factory=list)
    # Profiling: (weighted_bytes_or_flops, mult, opcode, shape, metadata_hint)
    top_traffic: list = field(default_factory=list)
    top_collectives: list = field(default_factory=list)
    top_flops: list = field(default_factory=list)


_META_RE = re.compile(r'op_name="([^"]+)"')


def _hint(attrs: str) -> str:
    m = _META_RE.search(attrs)
    return m.group(1)[-120:] if m else ""


def _comp_edges(comps: dict[str, Computation], cost: "HloCost"):
    """Static call-graph edges: comp -> [(callee, weight)]. While bodies get
    weight = trip count; everything else weight 1. Also returns the set of
    fusion-called computations (their internals live in registers — no HBM
    traffic)."""
    edges: dict[str, list[tuple[str, float]]] = {}
    fusion_comps: set[str] = set()
    for cname, comp in comps.items():
        lst: list[tuple[str, float]] = []
        for instr in comp.instrs:
            if instr.opcode == "while":
                body = _attr_ref(instr.attrs, "body")
                cond = _attr_ref(instr.attrs, "condition")
                trips = trip_count(comps, cond) if cond else 1
                cost.while_trip_counts.append(trips)
                if body:
                    lst.append((body, float(trips)))
                if cond:
                    lst.append((cond, float(trips + 1)))
            elif instr.opcode == "fusion":
                callee = _attr_ref(instr.attrs, "calls")
                if callee:
                    lst.append((callee, 1.0))
                    fusion_comps.add(callee)
            elif instr.opcode in ("call", "async-start"):
                callee = _attr_ref(instr.attrs, "to_apply")
                if callee:
                    lst.append((callee, 1.0))
            elif instr.opcode == "conditional":
                for ref in _attr_refs(instr.attrs, "branch_computations"):
                    lst.append((ref, 1.0))
                for key in ("true_computation", "false_computation"):
                    ref = _attr_ref(instr.attrs, key)
                    if ref:
                        lst.append((ref, 1.0))
        edges[cname] = lst
    return edges, fusion_comps


def analyze_hlo(text: str) -> HloCost:
    comps, entry = parse_module(text)
    cost = HloCost()
    if entry is None:
        return cost
    edges, fusion_comps = _comp_edges(comps, cost)

    # Topological multipliers (HLO call graphs are DAGs).
    post: list[str] = []
    visited: set = set()

    def dfs(c: str) -> None:
        if c in visited:
            return
        visited.add(c)
        for callee, _ in edges.get(c, []):
            dfs(callee)
        post.append(c)

    dfs(entry)
    mult: dict[str, float] = {entry: 1.0}
    for cname in reversed(post):  # callers before callees
        m = mult.get(cname, 0.0)
        for callee, w in edges.get(cname, []):
            mult[callee] = mult.get(callee, 0.0) + m * w

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for instr in comp.instrs:
            if instr.opcode == "dot":
                f = m * dot_flops(instr, comp)
                cost.flops += f
                cost.top_flops.append(
                    (f, m, "dot", instr.shape, _hint(instr.attrs))
                )
            elif instr.opcode == "convolution":
                cost.flops += m * _conv_flops(instr, comp)
            if instr.opcode in COLLECTIVES:
                kind = instr.opcode.replace("-start", "")
                nbytes = instr.out_bytes()
                if kind == "reduce-scatter" and instr.operands:
                    op = comp.by_name.get(instr.operands[0])
                    if op is not None:
                        nbytes = op.out_bytes()
                if kind == "all-reduce":
                    nbytes *= 2
                    # XLA-CPU float normalization promotes bf16 all-reduces
                    # to f32 ("..._promoted" reduction computations). The
                    # TPU target runs them natively in bf16 — count the
                    # pre-promotion payload.
                    if "promoted" in instr.attrs:
                        nbytes *= 0.5
                cost.collective_bytes += m * nbytes
                cost.collective_by_kind[kind] = (
                    cost.collective_by_kind.get(kind, 0.0) + m * nbytes
                )
                cost.collective_count[kind] = (
                    cost.collective_count.get(kind, 0) + m
                )
                cost.top_collectives.append(
                    (m * nbytes, m, kind, instr.shape, _hint(instr.attrs))
                )
            # Reads of tensors produced outside the dataflow we cost via
            # outputs (parameters, loop-carried tuple elements): weights and
            # KV caches — the dominant decode-step traffic. Slicing ops read
            # only their output (already counted); in-place update fusions
            # alias their big operand.
            if instr.opcode in ("dot", "convolution", "fusion"):
                root = None
                if instr.opcode == "fusion":
                    callee = comps.get(_attr_ref(instr.attrs, "calls") or "")
                    root = callee.instrs[-1] if callee and callee.instrs else None
                if not (root is not None and root.opcode == "dynamic-update-slice"):
                    for opname in instr.operands:
                        producer = comp.by_name.get(opname)
                        if producer is not None and producer.opcode in (
                            "parameter", "get-tuple-element",
                        ):
                            rb = m * producer.out_bytes()
                            if rb > 0:
                                cost.traffic_bytes += rb
                                cost.top_traffic.append(
                                    (rb, m, f"read<-{producer.opcode}",
                                     producer.shape, _hint(instr.attrs))
                                )
            if (
                instr.opcode in SKIP_TRAFFIC
                or instr.opcode in COLLECTIVES
                or cname in fusion_comps  # fused internals stay in registers
            ):
                continue
            if instr.opcode == "dynamic-update-slice" and len(instr.operands) >= 2:
                upd = comp.by_name.get(instr.operands[1])
                if upd is not None:
                    b = m * 2.0 * upd.out_bytes()
                    cost.traffic_bytes += b
                    cost.top_traffic.append(
                        (b, m, "dyn-update-slice", upd.shape, _hint(instr.attrs))
                    )
                continue
            if instr.opcode == "fusion":
                # In-place update fusions (root = dynamic-update-slice) write
                # the update, not the whole aliased buffer.
                callee = comps.get(_attr_ref(instr.attrs, "calls") or "")
                root = callee.instrs[-1] if callee and callee.instrs else None
                if root is not None and root.opcode == "dynamic-update-slice":
                    upd = callee.by_name.get(root.operands[1]) if len(root.operands) > 1 else None
                    if upd is not None:
                        b = m * 2.0 * upd.out_bytes()
                        cost.traffic_bytes += b
                        cost.top_traffic.append(
                            (b, m, "dus-fusion", upd.shape, _hint(instr.attrs))
                        )
                        continue
            b = m * 2.0 * instr.out_bytes()
            cost.traffic_bytes += b
            cost.top_traffic.append(
                (b, m, instr.opcode, instr.shape, _hint(instr.attrs))
            )
    for lst in (cost.top_traffic, cost.top_collectives, cost.top_flops):
        lst.sort(key=lambda t: -t[0])
        del lst[40:]
    return cost
