"""Sequential-transfer baseline, modeling S3Fs/FSSpec on-demand block cache.

This is the paper's comparison point: data transfer and compute occur in
distinct phases. A ``read()`` that misses the single-block cache fetches
the containing block from the object store synchronously (paying one
request latency + bandwidth), then serves from memory. No background
threads, no overlap.

When the `PrefetchFS` facade hands this engine a shared `CacheIndex`
(i.e. the filesystem owns cache tiers), misses consult it first: blocks
another reader already fetched — or a recovered persistent `DirTier`
holds — are read from the local tier instead of the store, and
single-flight registration keeps N concurrent sequential readers of the
same object at ~1x store GETs. Constructed bare (no index), the engine
is byte- and request-identical to the paper's baseline.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import TYPE_CHECKING

from repro.core.plan import Block, BlockPlan
from repro.io.integrity import check_block
from repro.io.retry import Retrier, RetryPolicy
from repro.store.base import (
    IntegrityError,
    ObjectMeta,
    ObjectStore,
    StoreError,
    TransientStoreError,
)
from repro.store.tiers import BlockMeta, CacheIndex

if TYPE_CHECKING:
    from repro.core.autotune import BlockSizeTuner


@dataclass
class SequentialStats:
    blocks_fetched: int = 0
    bytes_fetched: int = 0
    bytes_read: int = 0
    fetch_s: float = 0.0
    store_requests: int = 0
    retries: int = 0            # transient faults retried (shared Retrier)
    throttles: int = 0          # ThrottleError responses (503 SlowDown)
    cache_hits: int = 0         # blocks served from the shared index
    flight_joins: int = 0       # blocks obtained from another reader's GET
    blocks_verified: int = 0    # digest checks that passed
    integrity_failures: int = 0  # digest mismatches detected (then healed)

    def snapshot(self) -> dict:
        return dict(self.__dict__)


@dataclass
class _CacheEntry:
    index: int
    data: bytes


class SequentialFile:
    """fsspec-style read-ahead block cache over the same logical stream the
    Rolling Prefetch file exposes, so both sides of every A/B benchmark
    perform byte-identical application reads."""

    def __init__(
        self,
        store: ObjectStore,
        files: list[ObjectMeta],
        blocksize: int,
        cache_blocks: int = 1,
        tuner: "BlockSizeTuner | None" = None,
        index: CacheIndex | None = None,
        retry: RetryPolicy | None = None,
        io_class: str = "default",
        verify: str = "edges",
    ) -> None:
        if verify not in ("off", "edges", "full"):
            raise ValueError(
                f"verify must be 'off', 'edges', or 'full', got {verify!r}"
            )
        self.store = store
        self.plan = BlockPlan(files, blocksize)
        self.cache_blocks = max(1, cache_blocks)
        self.tuner = tuner
        self.index = index
        self.io_class = io_class
        self.verify = verify
        self.stats = SequentialStats()
        # Pre-resilience-layer this engine retried NOTHING: the first
        # transient fault of a direct read or a `_join_flight` fallback
        # GET killed the application's read() while the rolling engine
        # rode out the same schedule. Every store request now resolves
        # through the shared Retrier (full-jitter backoff), so both
        # engines survive the same faults.
        self.retry = retry if retry is not None else RetryPolicy()
        self._retrier = Retrier(
            self.retry,
            on_retry=self._on_retry,
            on_throttle=self._on_throttle,
        )
        self._cache: dict[int, _CacheEntry] = {}
        self._lru: list[int] = []
        self._pos = 0
        self._closed = False

    @property
    def size(self) -> int:
        return self.plan.total_bytes

    @property
    def closed(self) -> bool:
        return self._closed

    def _get_block(self, index: int) -> bytes:
        entry = self._cache.get(index)
        if entry is not None:
            return entry.data
        # Read-ahead: with cache_blocks > 1 the miss fetches the run of
        # adjacent same-file blocks that fills the cache with ONE
        # vectorized request (fsspec's readahead cache, request-efficient
        # via `get_ranges`); cache_blocks == 1 keeps the paper's baseline
        # shape of exactly one request per block.
        run = []
        for b in self.plan.run_from(index, self.cache_blocks):
            if b.index in self._cache:
                break  # keep the request one adjacent span
            run.append(b)
        if self.index is None:
            datas = [d for d, _ in self._fetch_run(run)]
        else:
            datas = self._resolve_shared(run)
        for b, d in zip(run, datas):
            self._cache[b.index] = _CacheEntry(b.index, d)
            self._lru.append(b.index)
        while len(self._lru) > self.cache_blocks:
            self._cache.pop(self._lru.pop(0), None)
        return self._cache[index].data

    def _on_retry(self, attempt: int, exc: Exception, pause: float) -> None:
        self.stats.retries += 1

    def _on_throttle(self) -> None:
        self.stats.throttles += 1

    def _request(self, run: list[Block]) -> list[tuple[bytes, str | None]]:
        if self.verify == "off":
            if len(run) == 1:
                datas = [self.store.get_range(run[0].key, run[0].start,
                                              run[0].end)]
            else:
                datas = self.store.get_ranges(
                    run[0].key, [(b.start, b.end) for b in run]
                )
            pairs: list[tuple[bytes, str | None]] = [(d, None) for d in datas]
        else:
            if len(run) == 1:
                pairs = [self.store.get_range_verified(
                    run[0].key, run[0].start, run[0].end)]
            else:
                pairs = self.store.get_ranges_verified(
                    run[0].key, [(b.start, b.end) for b in run]
                )
        for b, (d, dig) in zip(run, pairs):
            if len(d) != b.size:
                # Short response reported as complete: retry, don't
                # cache-and-corrupt (same guard as the rolling engine).
                raise TransientStoreError(
                    f"truncated response for {b.block_id}: "
                    f"got {len(d)} of {b.size} bytes"
                )
            if dig is not None:
                # Received bytes vs store-attested digest: a mismatch is
                # transient (the Retrier re-fetches); exhaustion raises a
                # typed IntegrityError, never returns wrong bytes.
                try:
                    check_block(d, dig, what=f"fetched block {b.block_id}")
                except IntegrityError:
                    self.stats.integrity_failures += 1
                    raise
                self.stats.blocks_verified += 1
        return pairs

    def _fetch_run(self, run: list[Block]) -> list[tuple[bytes, str | None]]:
        """One synchronous (resilient) store request for a contiguous run
        of blocks. Returns (payload, digest) pairs; digests are None with
        verify="off"."""
        retries_before = self.stats.retries
        t0 = time.perf_counter()
        pairs = self._retrier.call(
            lambda: self._request(run),
            label=f"blocks {run[0].block_id}..{run[-1].block_id}",
        )
        dt = time.perf_counter() - t0
        nbytes = sum(len(d) for d, _ in pairs)
        self.stats.fetch_s += dt
        self.stats.store_requests += 1
        self.stats.blocks_fetched += len(run)
        self.stats.bytes_fetched += nbytes
        if self.tuner is not None and self.stats.retries == retries_before:
            # Synchronous fetches time the store request exactly, so this
            # engine closes the loop too: with autotune on, PrefetchFS
            # retunes the Eq.-4 blocksize from these samples on reopen.
            # Retried calls are excluded — their wall time carries
            # backoff sleeps, not link behaviour.
            self.tuner.observe_request(nbytes, dt)
        return pairs

    # -- shared-index path --------------------------------------------------
    def _resolve_shared(self, run: list[Block]) -> list[bytes]:
        """Resolve a run against the shared `CacheIndex`: resident blocks
        are read from their local tier, in-flight blocks join the other
        reader's fetch, and only led blocks hit the store (contiguous
        leader segments still coalesce into one request, published back to
        a tier for the next reader)."""
        out: dict[int, bytes] = {}
        group: list[tuple[Block, object]] = []
        for b in run:
            kind, val = self.index.acquire(b.block_id, self.io_class)
            if kind == "leader":
                group.append((b, val))
                continue
            try:
                self._fetch_leaders(group, out)
                group = []
            except Exception:  # repro: allow[RP005] — releases, then re-raises
                # The pin (hit) / waiter slot (wait) just taken for `b`
                # must not leak past a failed leader group, or the block
                # becomes unevictable forever.
                if kind == "hit":
                    self.index.unpin(b.block_id)
                else:
                    self.index.leave(val)
                raise
            if kind == "hit":
                out[b.index] = self._read_hit(b, val)
            else:
                out[b.index] = self._join_flight(b, val)
        self._fetch_leaders(group, out)
        return [out[b.index] for b in run]

    def _verify_tier_read(self, tier, data: bytes, block_id: str) -> None:
        """Engine-side digest re-check of a full-block tier read; same
        posture as the rolling engine ("edges" trusts self-verifying
        tiers, "full" re-checks unconditionally). Raises `IntegrityError`
        for the caller to quarantine and heal."""
        if self.verify == "off":
            return
        if self.verify == "edges" and getattr(tier, "verifies_reads", False):
            return
        dig = self.index.digest_of(block_id)
        if dig is None:
            return
        check_block(data, dig, what=f"cached block {block_id}")
        self.stats.blocks_verified += 1

    def _read_hit(self, b: Block, tier) -> bytes:
        """Serve a resident block from its tier. Hits/joins deliberately
        do NOT count into blocks_fetched/bytes_fetched — those mean store
        traffic, matching the rolling engine's accounting. The unpin asks
        for eviction unless the index retains (keep_cached), preserving
        the evict-when-consumed default for this engine too."""
        try:
            try:
                data = tier.read(b.block_id, 0, b.size)
                self._verify_tier_read(tier, data, b.block_id)
            finally:
                self.index.unpin(b.block_id,
                                 want_evict=not self.index.keep_cached)
        except IntegrityError:
            # The resident copy is provably wrong: quarantine (evict +
            # tombstone) and heal with a direct fetch — a rotted cache
            # block costs one GET, never wrong data.
            self.stats.integrity_failures += 1
            self.index.quarantine(b.block_id)
            return self._fetch_run([b])[0][0]
        except StoreError:
            # A sibling process sharing a persistent cache dir may have
            # evicted the file beneath the entry — drop the stale entry
            # and fetch it ourselves.
            self.index.invalidate(b.block_id)
            return self._fetch_run([b])[0][0]
        self.stats.cache_hits += 1
        return data

    def _fetch_leaders(self, group: list[tuple[Block, object]],
                       out: dict[int, bytes]) -> None:
        if not group:
            return
        blocks = [b for b, _ in group]
        try:
            pairs = self._fetch_run(blocks)
        except Exception as e:   # repro: allow[RP005] — waiters must not hang
            for _, fl in group:
                self.index.abort_fetch(fl, e)
            raise
        for (b, fl), (d, dig) in zip(group, pairs):
            out[b.index] = d
            if fl.waiters == 0 and not self.index.keep_cached:
                # Nobody is waiting and retention is off: publishing would
                # write the block into a tier and evict it on the very
                # next line — skip the dead work. (A waiter registering in
                # this racy instant just re-fetches itself.)
                self.index.abort_fetch(fl)
                continue
            tier = self.index.reserve_space(b.size, self.io_class)
            if tier is None:
                # Nowhere to publish (tiers full of pinned blocks): the
                # data is still returned; waiters re-acquire and fetch.
                self.index.abort_fetch(fl)
                continue
            try:
                tier.write(b.block_id, d,
                           meta=BlockMeta(key=b.key, offset=b.start))
            except Exception:   # repro: allow[RP005] — cache write is best-effort
                tier.cancel(b.size)
                self.index.abort_fetch(fl)
                continue
            tier.commit(b.size)
            self.index.publish(fl, tier, b.size, digest=dig)
            # No long pin (bytes copied out); without keep_cached the
            # block must not outlive its consumption — the paper's
            # evict-when-consumed default applies to this engine too.
            self.index.unpin(b.block_id,
                             want_evict=not self.index.keep_cached)

    # How long a synchronous reader waits on another reader's in-flight
    # fetch before giving up and fetching the block itself. A leaked
    # flight (leader killed without publish/abort) must never hang the
    # application's read() forever — a duplicate GET beats a deadlock.
    JOIN_PATIENCE_S = 10.0

    def _join_flight(self, b: Block, flight) -> bytes:
        waited = 0.0
        while True:
            kind, val = self.index.join(flight, timeout=0.5)
            if kind == "timeout":
                waited += 0.5
                if waited >= self.JOIN_PATIENCE_S:
                    self.index.leave(flight)
                    return self._fetch_run([b])[0][0]
                continue
            if kind == "hit":
                try:
                    try:
                        data = val.read(b.block_id, 0, b.size)
                        self._verify_tier_read(val, data, b.block_id)
                    finally:
                        self.index.unpin(b.block_id,
                                         want_evict=not self.index.keep_cached)
                except IntegrityError:
                    self.stats.integrity_failures += 1
                    self.index.quarantine(b.block_id)
                    return self._fetch_run([b])[0][0]
                except StoreError:
                    self.index.invalidate(b.block_id)
                    return self._fetch_run([b])[0][0]
                self.stats.flight_joins += 1
                return data
            # Leader failed: take over (or join the next attempt).
            kind, val = self.index.acquire(b.block_id, self.io_class)
            if kind == "hit":
                return self._read_hit(b, val)
            if kind == "wait":
                flight = val
                continue
            out: dict[int, bytes] = {}
            self._fetch_leaders([(b, val)], out)
            return out[b.index]

    def read(self, n: int = -1) -> bytes:
        if self._closed:
            raise ValueError("read on closed file")
        if n < 0:
            n = self.size - self._pos
        end = min(self._pos + n, self.size)
        out = bytearray()
        while self._pos < end:
            block = self.plan.block_at(self._pos)
            data = self._get_block(block.index)
            lo = self._pos - block.global_start
            hi = min(end, block.global_end) - block.global_start
            out.extend(data[lo:hi])
            self._pos += hi - lo
        self.stats.bytes_read += len(out)
        return bytes(out)

    def seek(self, offset: int, whence: int = 0) -> int:
        if whence == 1:
            offset += self._pos
        elif whence == 2:
            offset += self.size
        if not 0 <= offset <= self.size:
            raise ValueError(f"seek out of range: {offset}")
        self._pos = offset
        return self._pos

    def tell(self) -> int:
        return self._pos

    def close(self) -> None:
        self._closed = True
        self._cache.clear()
        self._lru.clear()

    def __enter__(self) -> "SequentialFile":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
