"""Multi-host input-pipeline simulation: N hosts stream disjoint shard
sets from one shared object store — with failures, stragglers, and a
host replacement mid-epoch — asserting the properties a thousand-node
job depends on. The peer-cluster tests at the bottom add the
distributed-prefetch claim: N hosts streaming ONE shared dataset through
a `PeerGroup` issue ~1x backing-store GETs, including across a host
death mid-epoch."""

from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.data import DataCursor, LoaderConfig, PrefetchingDataLoader, synth_token_shard
from repro.io import IOPolicy
from repro.peer.sim import SimCluster
from repro.store import LinkModel, MemTier, SimS3Store

N_HOSTS = 8
N_SHARDS = 32


@pytest.fixture()
def store():
    rng = np.random.default_rng(7)
    s = SimS3Store(link=LinkModel(latency_s=0.001, bandwidth_Bps=200e6))
    for i in range(N_SHARDS):
        s.backing.put(f"tok{i:03d}.bin", synth_token_shard(rng, 3000, vocab=1000))
    return s


def _loader(store, host, cursor=None, **kw):
    cfg = LoaderConfig(
        seq_len=64, batch_size=2, blocksize=4096,
        host_id=host, num_hosts=N_HOSTS, **kw,
    )
    return PrefetchingDataLoader(
        store, store.backing.list_objects(), [MemTier(1 << 20)], cfg,
        cursor=cursor,
    )


class TestMultiHost:
    def test_hosts_cover_disjoint_shards(self, store):
        files = store.backing.list_objects()
        assigned = []
        for h in range(N_HOSTS):
            loader = _loader(store, h)
            assigned.extend(m.key for m in loader.my_files)
            loader.close()
        assert sorted(assigned) == sorted(m.key for m in files)
        assert len(set(assigned)) == len(assigned)

    def test_concurrent_hosts_stream_correct_data(self, store):
        """All hosts pull batches concurrently through the SHARED link;
        every host's stream must equal its single-threaded reference."""
        results: dict[int, list] = {}
        errors: list = []

        def run(host):
            try:
                loader = _loader(store, host)
                results[host] = [b[0] for b in loader.batches(max_batches=3)]
                loader.close()
            except BaseException as e:  # repro: allow[RP005] — stashed; asserted after join
                errors.append((host, e))

        threads = [threading.Thread(target=run, args=(h,))
                   for h in range(N_HOSTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors

        for h in range(N_HOSTS):
            ref_loader = _loader(store, h)
            ref = [b[0] for b in ref_loader.batches(max_batches=3)]
            ref_loader.close()
            for a, b in zip(results[h], ref):
                np.testing.assert_array_equal(a, b)

    def test_host_replacement_resumes_deterministically(self, store):
        """Host 3 'dies' after 2 batches; its replacement restores the
        cursor and must produce exactly the batches the original would
        have produced next."""
        loader = _loader(store, 3)
        consumed = [b for b in loader.batches(max_batches=2)]
        cursor = DataCursor(**loader.cursor.to_dict())
        loader.close()  # host dies

        # Uninterrupted reference.
        ref_loader = _loader(store, 3)
        ref = [b for b in ref_loader.batches(max_batches=5)]
        ref_loader.close()

        # Replacement host resumes from the checkpointed cursor.
        repl = _loader(store, 3, cursor=cursor)
        resumed = [b for b in repl.batches(max_batches=3)]
        repl.close()
        for (a, _), (b, _) in zip(resumed, ref[2:]):
            np.testing.assert_array_equal(a, b)

    def test_transient_store_failures_do_not_corrupt_streams(self, store):
        store.link.fail_prob = 0.02
        store.link._rng.seed(123)
        loader = _loader(store, 0, mode="rolling")
        batches = [b for b in loader.batches(max_batches=4)]
        loader.close()
        store.link.fail_prob = 0.0
        ref_loader = _loader(store, 0)
        ref = [b for b in ref_loader.batches(max_batches=4)]
        ref_loader.close()
        for (a, _), (b, _) in zip(batches, ref):
            np.testing.assert_array_equal(a, b)

    def test_straggler_hedging_under_jitter(self, store):
        store.link.jitter = 2.0  # heavy-tailed latencies
        loader = _loader(store, 1, hedge_timeout_s=0.01)
        batches = [b for b in loader.batches(max_batches=3)]
        stats = loader.stats
        loader.close()
        assert len(batches) == 3
        assert stats is not None  # hedges counter exists (may or may not fire)


# --------------------------------------------------------------------------- #
# Distributed prefetch: peer cluster over one shared dataset
# --------------------------------------------------------------------------- #
PEER_HOSTS = 4
PEER_BLOCKSIZE = 4096


def peer_payload(n: int, seed: int = 0) -> bytes:
    return bytes((i * 31 + seed * 7) % 256 for i in range(n))


@pytest.fixture()
def peer_dataset():
    return {f"shard{i:02d}": peer_payload(24_576, seed=i) for i in range(6)}


@pytest.fixture()
def peer_backing(peer_dataset):
    s = SimS3Store(link=LinkModel(latency_s=0.001, bandwidth_Bps=200e6))
    for k, v in peer_dataset.items():
        s.backing.put(k, v)
    return s


def _stream_all(cluster, hosts, *, engine="rolling"):
    """Every listed host reads the FULL dataset through its peer store;
    returns ({host: bytes}, [errors])."""
    outs: dict[int, bytes] = {}
    errors: list = []

    def run(h):
        try:
            host = cluster.host(h)
            fs = host.open_fs(IOPolicy(
                engine=engine, blocksize=PEER_BLOCKSIZE, depth=2,
                keep_cached=True, eviction_interval_s=0.05))
            files = sorted(host.store.list_objects(), key=lambda m: m.key)
            f = fs.open_many(files)
            try:
                outs[h] = f.read()
            finally:
                f.close()
        except BaseException as e:  # repro: allow[RP005] — stashed; asserted after join
            errors.append((h, e))

    threads = [threading.Thread(target=run, args=(h,)) for h in hosts]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return outs, errors


class TestPeerCluster:
    def test_shared_dataset_issues_one_x_backing_gets(self, peer_dataset,
                                                      peer_backing):
        """The headline claim: N hosts each streaming the WHOLE dataset
        through a shared PeerGroup cost ~1x backing GETs (each block's
        home host does the one WAN fetch; siblings pull over the LAN),
        not Nx — and every host's bytes are exact."""
        n_blocks = sum(-(-len(v) // PEER_BLOCKSIZE)
                       for v in peer_dataset.values())
        want = b"".join(peer_dataset[k] for k in sorted(peer_dataset))
        cluster = SimCluster(PEER_HOSTS, peer_backing)
        try:
            outs, errors = _stream_all(cluster, range(PEER_HOSTS))
            assert not errors, errors
            for h in range(PEER_HOSTS):
                assert outs[h] == want, f"host {h} bytes diverged"
            amplification = cluster.backing_fetches / n_blocks
            assert amplification <= 1.2, (
                f"{cluster.backing_fetches} backing GETs for {n_blocks} "
                f"blocks = {amplification:.2f}x (expected ~1x, "
                f"Nx would be {PEER_HOSTS}.0x)"
            )
            # The LAN actually carried the fan-out.
            peer_hits = sum(
                cluster.host(h).store.peer_snapshot()["peer_hits"]
                for h in range(PEER_HOSTS))
            assert peer_hits > 0
        finally:
            cluster.close()

    def test_without_peers_costs_n_x(self, peer_dataset, peer_backing):
        """Control arm: the same N-host read with every host routing all
        blocks to itself (single-member groups) pays ~Nx — the
        amplification the peer layer removes."""
        n_blocks = sum(-(-len(v) // PEER_BLOCKSIZE)
                       for v in peer_dataset.values())
        clusters = [SimCluster(1, peer_backing) for _ in range(PEER_HOSTS)]
        try:
            total = 0
            for c in clusters:
                outs, errors = _stream_all(c, [0])
                assert not errors, errors
                total += c.backing_fetches
            assert total >= PEER_HOSTS * n_blocks
        finally:
            for c in clusters:
                c.close()

    def test_host_death_mid_epoch_survivors_reown_blocks(self, peer_dataset,
                                                         peer_backing):
        """Host 3 dies halfway through the epoch. Survivors mark it dead
        on the first failed RPC (miss_limit=1), rendezvous re-owns its
        blocks across the remaining hosts, and every survivor finishes
        with byte-identical data and ZERO read errors."""
        want = b"".join(peer_dataset[k] for k in sorted(peer_dataset))
        half = len(want) // 2
        cluster = SimCluster(PEER_HOSTS, peer_backing, miss_limit=1)
        survivors = range(PEER_HOSTS - 1)
        outs: dict[int, bytes] = {}
        errors: list = []
        # Two barriers bracket the kill: every survivor finishes the
        # first half, host 3 dies, then the second half proceeds against
        # a silently-dead peer.
        reached_half = threading.Barrier(len(survivors) + 1)
        killed = threading.Barrier(len(survivors) + 1)

        def run(h):
            try:
                host = cluster.host(h)
                fs = host.open_fs(IOPolicy(
                    engine="sequential", blocksize=PEER_BLOCKSIZE,
                    keep_cached=True))
                files = sorted(host.store.list_objects(),
                               key=lambda m: m.key)
                f = fs.open_many(files)
                try:
                    first = f.read(half)
                    reached_half.wait(timeout=30)
                    killed.wait(timeout=30)
                    outs[h] = first + f.read()
                finally:
                    f.close()
            except BaseException as e:  # repro: allow[RP005] — stashed; asserted after join
                errors.append((h, e))

        threads = [threading.Thread(target=run, args=(h,))
                   for h in survivors]
        for t in threads:
            t.start()
        reached_half.wait(timeout=30)
        cluster.kill(PEER_HOSTS - 1)
        killed.wait(timeout=30)
        for t in threads:
            t.join()
        try:
            assert not errors, errors
            for h in survivors:
                assert outs[h] == want, f"survivor {h} bytes diverged"
            snaps = {h: cluster.host(h).store.peer_snapshot()
                     for h in survivors}
            # At least one survivor hit the dead host and degraded.
            assert sum(s["dead_peer_fallbacks"] for s in snaps.values()) > 0
            assert any(s["group"]["deaths"] > 0 for s in snaps.values())
            # Survivors converge on the dead peer's absence.
            for h in survivors:
                host = cluster.host(h)
                if not host.group.is_alive(PEER_HOSTS - 1):
                    assert PEER_HOSTS - 1 not in host.group.alive_ids()
        finally:
            cluster.close()
