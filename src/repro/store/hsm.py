"""User-space hierarchical storage manager over the cache tiers.

The paper treats local storage as a flat priority list of caches that mask
S3 latency; the authors' follow-up work (arXiv:2404.11556) argues the next
step is a real user-space HSM — mem -> local disk -> shared disk -> remote
— with cost-model-driven placement. This module promotes the shared
`CacheIndex` into exactly that:

  * **heat tracking** — every hit touches an exponentially-decaying
    per-block temperature (access count + recency in one number);
  * **promotion / demotion** — a background mover copies hot unpinned
    blocks up-tier when the cost model says the move pays for itself, and
    capacity pressure on a non-bottom tier *demotes* cold blocks down-tier
    instead of deleting them; only the bottom tier truly evicts;
  * **cost-model placement** — each tier carries a `TierCostModel` seeded
    from its `LinkModel` (latency + bandwidth) and refined online from the
    link's observed-request telemetry, the same signals `BlockSizeTuner`
    fits; placement walks candidate tiers in per-byte cost order, not list
    order;
  * **workload-class admission** — `IOPolicy.io_class` ("loader" /
    "ckpt" / "serve") selects an `AdmissionPolicy`: serve restores admit
    into mem and are *protected* (a non-protected class can never displace
    them), bulk loader scans enter at the disk level and are
    *scan-resistant* (their blocks queue at the FRONT of the eviction
    order, so one epoch sweep evicts its own blocks first and cannot flush
    the hot set).

`HSMStore` wraps a backing `ObjectStore` together with the assembled
hierarchy so one ``hsm://`` URI (registered in ``repro.io.stores``)
carries the whole thing::

    hsm://?mem=64MB&disk=/scratch/cache:1GB&backing=mem://bucket

`PrefetchFS` recognizes the wrapper and adopts its tiers + `HSMIndex`, so
every existing engine, loader, checkpoint, and serve call site gets HSM
placement without code changes.
"""

from __future__ import annotations

import re
import threading
import time
from dataclasses import dataclass, field

from repro.store.base import (
    IntegrityError,
    MultipartUpload,
    ObjectMeta,
    ObjectStore,
)
from repro.store.link import LinkModel
from repro.store.tiers import (
    CacheIndex,
    CacheTier,
    DirTier,
    MemTier,
    _IndexEntry,
)
from repro.utils import get_logger

log = get_logger("store.hsm")


def _check_move(data: bytes, digest: str | None, block_id: str,
                move: str) -> None:
    """Verify block bytes against their index digest before an HSM move
    copies them to another tier — a move is a tier/tier boundary, and
    boundaries are where digests get checked. No digest (verify="off"
    producers, pre-digest entries) verifies nothing. Lazy import: the io
    layer imports this module at package init."""
    if digest is None:
        return
    from repro.io.integrity import check_block
    check_block(data, digest, what=f"hsm {move} of {block_id}")


# --------------------------------------------------------------------------- #
# sizes
# --------------------------------------------------------------------------- #
_SIZE_RE = re.compile(r"^\s*(\d+(?:\.\d+)?)\s*([KMGT]i?B?|B)?\s*$", re.IGNORECASE)
_SIZE_UNITS = {
    "": 1, "b": 1,
    "k": 1 << 10, "kb": 1 << 10, "kib": 1 << 10,
    "m": 1 << 20, "mb": 1 << 20, "mib": 1 << 20,
    "g": 1 << 30, "gb": 1 << 30, "gib": 1 << 30,
    "t": 1 << 40, "tb": 1 << 40, "tib": 1 << 40,
}


def parse_size(text: str | int) -> int:
    """``"64MB"`` / ``"1GiB"`` / ``"4096"`` -> bytes (binary units)."""
    if isinstance(text, int):
        return text
    m = _SIZE_RE.match(text)
    if not m:
        raise ValueError(f"not a size: {text!r} (expected e.g. 64MB, 1GiB, 4096)")
    value, unit = m.groups()
    return int(float(value) * _SIZE_UNITS[(unit or "").lower()])


# --------------------------------------------------------------------------- #
# cost model
# --------------------------------------------------------------------------- #
@dataclass
class TierCostModel:
    """Per-tier access cost: ``cost(n) = latency + n / bandwidth`` seconds.

    Seeded from the tier's read `LinkModel` (the configured simulation
    constants) and refined online from the link's observed telemetry —
    the same per-request latency/bandwidth signals `BlockSizeTuner` fits —
    via an EWMA, so a tier whose device behaves differently from its
    nameplate migrates the placement decisions with it.
    """

    latency_s: float
    bandwidth_Bps: float
    alpha: float = 0.3          # EWMA weight for observed telemetry
    refined: int = field(default=0, repr=False)   # observe() updates applied

    @classmethod
    def from_tier(cls, tier: CacheTier) -> "TierCostModel":
        link = tier.read_link
        return cls(latency_s=link.latency_s, bandwidth_Bps=link.bandwidth_Bps)

    def observe(self, tier: CacheTier) -> None:
        """Fold the tier's observed request telemetry into the estimates
        (no-op until the link has served traffic)."""
        link = tier.read_link
        if link.requests <= 0:
            return
        lat = link.observed_latency()
        bw = link.observed_bandwidth()
        self.latency_s += self.alpha * (lat - self.latency_s)
        if bw != float("inf") and self.bandwidth_Bps != float("inf"):
            self.bandwidth_Bps += self.alpha * (bw - self.bandwidth_Bps)
        elif bw != float("inf"):
            self.bandwidth_Bps = bw
        self.refined += 1

    def cost(self, nbytes: int) -> float:
        """Estimated seconds to read `nbytes` from this tier."""
        if self.bandwidth_Bps == float("inf") or self.bandwidth_Bps <= 0:
            return self.latency_s
        return self.latency_s + nbytes / self.bandwidth_Bps

    def snapshot(self) -> dict:
        return dict(latency_s=self.latency_s, bandwidth_Bps=self.bandwidth_Bps,
                    refined=self.refined)


# --------------------------------------------------------------------------- #
# admission
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class AdmissionPolicy:
    """How one workload class is admitted into the hierarchy.

    ``entry_level`` — highest (fastest) level the class may occupy, as an
    index into the tier list; new blocks are placed no higher than this
    and promotion never lifts them above it. ``protected`` — the class's
    blocks can only be displaced (demoted/evicted) by pressure from
    another protected class, so a bulk scan can never flush them.
    ``scan_resistant`` — the class's own blocks queue at the FRONT of the
    eviction order, so its sweep recycles its own footprint first.
    """

    entry_level: int = 0
    protected: bool = False
    scan_resistant: bool = False


#: Default per-class admission. ``serve`` models latency-critical restore
#: reads (pinned into the top tier, protected); ``ckpt`` restores admit
#: top but are displaceable; ``loader`` models bulk epoch sweeps
#: (disk-level entry, scan-resistant).
DEFAULT_ADMISSION: dict[str, AdmissionPolicy] = {
    "default": AdmissionPolicy(),
    "serve": AdmissionPolicy(entry_level=0, protected=True),
    "ckpt": AdmissionPolicy(entry_level=0),
    "loader": AdmissionPolicy(entry_level=1, scan_resistant=True),
    # Blocks a peer BlockServer fetches on a sibling host's behalf: the
    # local replica may never read them itself, so they stay out of the
    # top tier and recycle their own footprint under pressure.
    "peer": AdmissionPolicy(entry_level=1, scan_resistant=True),
}


class _Heat:
    """Exponentially-decayed access temperature of one block."""

    __slots__ = ("temp", "last_t")

    def __init__(self, now: float) -> None:
        self.temp = 1.0
        self.last_t = now

    def _decay(self, now: float, half_life_s: float) -> float:
        dt = max(0.0, now - self.last_t)
        if dt > 0.0 and half_life_s > 0.0:
            self.temp *= 0.5 ** (dt / half_life_s)
            self.last_t = now
        return self.temp

    def touch(self, now: float, half_life_s: float) -> None:
        self._decay(now, half_life_s)
        self.temp += 1.0

    def value(self, now: float, half_life_s: float) -> float:
        return self._decay(now, half_life_s)


# --------------------------------------------------------------------------- #
# the HSM index
# --------------------------------------------------------------------------- #
class HSMIndex(CacheIndex):
    """`CacheIndex` subclass that turns the flat tier walk into an HSM.

    Drop-in for every engine (same acquire/publish/unpin/evict_from/
    reserve_space surface); the differences:

      * retention is always on (`keep_cached`): demotion, not reader
        consumption, is what moves blocks down and out;
      * `reserve_space` starts the walk at the workload class's admission
        entry level and orders candidate tiers by modeled cost;
      * `evict_from` on a non-bottom tier *demotes* victims to the next
        level down (cascading; only the bottom tier deletes), skips
        blocks of protected classes unless the requester is protected
        itself, and falls back to deletion only when the whole hierarchy
        below is wedged (availability beats purity);
      * a background mover promotes hot unpinned blocks up-tier whenever
        the heat-weighted read-cost saving exceeds the cost of the move
        itself, and demotes cold blocks from tiers past their high-water
        mark — so placement converges even without capacity pressure.
    """

    def __init__(
        self,
        tiers: list[CacheTier],
        *,
        admission: dict[str, AdmissionPolicy] | None = None,
        half_life_s: float = 30.0,
        promote_threshold: float = 2.0,
        demote_watermark: float = 0.9,
        mover_interval_s: float | None = 0.5,
        promote_batch: int = 8,
        keep_cached: bool = True,
        flight_ttl_s: float | None = CacheIndex.FLIGHT_TTL_S,
    ) -> None:
        # State the base constructor's priming may touch must exist first.
        self._heat: dict[str, _Heat] = {}
        self.admission = dict(DEFAULT_ADMISSION)
        if admission:
            self.admission.update(admission)
        self.half_life_s = half_life_s
        self.promote_threshold = promote_threshold
        self.demote_watermark = demote_watermark
        self.promote_batch = promote_batch
        self.promotions = 0
        self.demotions = 0
        self.forced_evictions = 0      # non-bottom deletes (demotion wedged)
        self.moves_failed = 0
        self.tier_hits: dict[str, int] = {}
        self.class_hits: dict[str, int] = {}
        super().__init__(tiers, keep_cached=True, flight_ttl_s=flight_ttl_s)
        for level, tier in enumerate(self.tiers):
            tier.level = level
        self.costs = [TierCostModel.from_tier(t) for t in self.tiers]
        self._seed_recovered_heat()
        self._mover_stop = threading.Event()
        self._mover: threading.Thread | None = None
        if mover_interval_s is not None:
            self._mover = threading.Thread(
                target=self._mover_loop, args=(mover_interval_s,),
                name="hsm-mover", daemon=True,
            )
            self._mover.start()

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        """Stop the background mover (blocks stay where they are)."""
        self._mover_stop.set()
        if self._mover is not None:
            self._mover.join(timeout=5.0)
            self._mover = None

    def set_keep_cached(self, keep: bool) -> None:
        """Retention is the HSM's semantic — demotion moves blocks down
        and out, readers never flip it off. Upgrades are no-ops too."""

    def _seed_recovered_heat(self) -> None:
        """Blocks recovered from a persistent tier whose journal says they
        lived at a HOTTER level before the restart (the tier-generation
        ``lvl`` field) are seeded promotable heat, so the mover restores
        the pre-crash placement instead of treating them as cold."""
        now = time.monotonic()
        with self._cond:
            for bid, e in self._entries.items():
                lvl = None
                journaled = getattr(e.tier, "journaled_level", None)
                if journaled is not None:
                    lvl = journaled(bid)
                if lvl is not None and lvl < e.tier.level:
                    h = self._heat.setdefault(bid, _Heat(now))
                    h.temp = max(h.temp, self.promote_threshold + 1.0)

    # -- admission ----------------------------------------------------------
    def _admission(self, io_class: str | None) -> AdmissionPolicy:
        pol = self.admission.get(io_class or "default")
        if pol is None:
            pol = self.admission.get("default", AdmissionPolicy())
        return pol

    def _entry_level(self, io_class: str | None) -> int:
        return min(self._admission(io_class).entry_level, len(self.tiers) - 1)

    # -- hooks from the base index (caller holds `_cond`) --------------------
    def _note_hit(self, block_id: str, e: _IndexEntry, io_class: str) -> None:
        now = time.monotonic()
        h = self._heat.get(block_id)
        if h is None:
            h = self._heat[block_id] = _Heat(now)
        else:
            h.touch(now, self.half_life_s)
        name = e.tier.name
        self.tier_hits[name] = self.tier_hits.get(name, 0) + 1
        ck = f"{io_class}:{name}"
        self.class_hits[ck] = self.class_hits.get(ck, 0) + 1

    def _on_insert(self, block_id: str, e: _IndexEntry) -> None:
        now = time.monotonic()
        h = self._heat.get(block_id)
        if h is None:
            self._heat[block_id] = _Heat(now)
        else:
            h.touch(now, self.half_life_s)

    def _note_evictable(self, block_id: str, e: _IndexEntry) -> None:
        self._evictable[block_id] = None
        if self._admission(e.io_class).scan_resistant:
            # Scan-resistant classes recycle their own footprint: their
            # blocks are the first pressure victims, so a sweep can never
            # push out the hot set behind them.
            self._evictable.move_to_end(block_id, last=False)
        else:
            self._evictable.move_to_end(block_id)

    # -- placement -----------------------------------------------------------
    def reserve_space(self, nbytes: int,
                      io_class: str = "default") -> CacheTier | None:
        start = self._entry_level(io_class)
        levels = sorted(range(start, len(self.tiers)),
                        key=lambda lv: self.costs[lv].cost(nbytes))
        for lv in levels:
            cand = self.tiers[lv]
            if cand.available() < nbytes:
                cand.verify_used()
            if cand.reserve(nbytes):
                return cand
            if (self.evict_from(cand, nbytes, requester=io_class) > 0
                    and cand.reserve(nbytes)):
                return cand
        return None

    def _tier_reserve(self, level: int, nbytes: int, requester: str) -> bool:
        """Reservation on one specific tier, applying pressure (which on a
        non-bottom tier cascades demotions further down)."""
        cand = self.tiers[level]
        if cand.available() < nbytes:
            cand.verify_used()
        if cand.reserve(nbytes):
            return True
        return (self.evict_from(cand, nbytes, requester=requester) > 0
                and cand.reserve(nbytes))

    # -- pressure: demote-not-evict ------------------------------------------
    def evict_from(self, tier: CacheTier, nbytes: int,
                   requester: str | None = None) -> int:
        req_protected = self._admission(requester).protected
        bottom = tier is self.tiers[-1]
        victims: list[tuple[str, _IndexEntry]] = []
        planned = 0
        with self._cond:
            for bid in list(self._evictable):
                e = self._entries.get(bid)
                if e is None or e.tier is not tier or bid in self._deleting:
                    continue
                if (self._admission(e.io_class).protected
                        and not req_protected):
                    continue
                victims.append((bid, e))
                planned += e.size
                if planned >= nbytes:
                    break
            for bid, e in victims:
                del self._entries[bid]
                self._evictable.pop(bid, None)
                self._deleting.add(bid)
        if not victims:
            return 0
        freed = 0
        try:
            for bid, e in victims:
                if not bottom and self._demote(bid, e):
                    freed += e.size
                    continue
                # Bottom tier — or the hierarchy below is wedged (full of
                # pinned bytes): delete. A stuck demotion must not stall
                # the prefetch pipeline.
                self._delete_from_tier(e.tier, bid, e.size)
                freed += e.size
                with self._cond:
                    self.evictions += 1
                    if not bottom:
                        self.forced_evictions += 1
                    self._heat.pop(bid, None)
        finally:
            with self._cond:
                for bid, _ in victims:
                    self._deleting.discard(bid)
                self._cond.notify_all()
        return freed

    def _demote(self, block_id: str, e: _IndexEntry) -> bool:
        """Move an (already tombstoned) victim one level down. Returns
        False when the copy could not be placed — the caller deletes."""
        dst_level = e.tier.level + 1
        if dst_level >= len(self.tiers):
            return False
        dst = self.tiers[dst_level]
        if not self._tier_reserve(dst_level, e.size, e.io_class):
            return False
        try:
            data = e.tier.read(block_id, 0, e.size)
            _check_move(data, e.digest, block_id, "demotion")
            dst.write(block_id, data)
            dst.commit(e.size)
        except IntegrityError as exc:
            # The copy rotted in the source tier: propagating it down
            # would launder corruption into a colder (often persistent)
            # level. Refuse the move — the caller deletes, and the next
            # read re-fetches clean bytes from the backing store.
            dst.cancel(e.size)
            with self._cond:
                self.moves_failed += 1
                self.quarantined += 1
            log.warning("demotion of %s: copy is corrupt, evicting: %s",
                        block_id, exc)
            return False
        except Exception as exc:   # repro: allow[RP005] — fall back to eviction
            dst.cancel(e.size)
            with self._cond:
                self.moves_failed += 1
            log.warning("demotion of %s to %s failed: %s",
                        block_id, dst.name, exc)
            return False
        self._delete_from_tier(e.tier, block_id, e.size)
        with self._cond:
            ne = _IndexEntry(dst, e.size, refs=0, io_class=e.io_class,
                             digest=e.digest)
            self._entries[block_id] = ne
            self._note_evictable(block_id, ne)
            self.demotions += 1
        return True

    # -- mover: promotion + watermark demotion --------------------------------
    def _mover_loop(self, interval_s: float) -> None:
        while not self._mover_stop.wait(interval_s):
            try:
                self.mover_tick()
            except Exception:   # repro: allow[RP005] — the mover must survive
                log.exception("hsm mover tick failed")

    def mover_tick(self) -> None:
        """One synchronous placement pass (the background thread calls
        this periodically; tests and benchmarks call it directly for
        determinism): refresh cost models from link telemetry, promote
        profitable hot blocks, demote from tiers past high-water, and
        prune dead heat records."""
        for cm, t in zip(self.costs, self.tiers):
            cm.observe(t)
        self._promote_pass()
        self._demote_pass()
        self._prune_heat()

    def _promote_pass(self) -> None:
        now = time.monotonic()
        plans: list[tuple[float, str]] = []
        with self._cond:
            for bid, e in self._entries.items():
                if e.refs > 0 or bid in self._deleting:
                    continue
                level = e.tier.level
                ceiling = self._entry_level(e.io_class)
                if level <= ceiling:
                    continue
                h = self._heat.get(bid)
                if h is None:
                    continue
                heat = h.value(now, self.half_life_s)
                if heat < self.promote_threshold:
                    continue
                if not self._worth_promoting(heat, e.size, level, level - 1):
                    continue
                plans.append((heat, bid))
        plans.sort(reverse=True)
        for _, bid in plans[: self.promote_batch]:
            self._promote(bid)

    def _worth_promoting(self, heat: float, size: int,
                         src: int, dst: int) -> bool:
        """Promote when the heat-weighted read-cost saving beats the move
        cost (read once from src + write once to dst ~ cost of both)."""
        saving = heat * (self.costs[src].cost(size) - self.costs[dst].cost(size))
        move_cost = self.costs[src].cost(size) + self.costs[dst].cost(size)
        return saving > move_cost

    def _promote(self, block_id: str) -> bool:
        with self._cond:
            e = self._entries.get(block_id)
            if e is None or e.refs > 0 or block_id in self._deleting:
                return False
            dst_level = e.tier.level - 1
            if dst_level < self._entry_level(e.io_class):
                return False
            del self._entries[block_id]
            self._evictable.pop(block_id, None)
            self._deleting.add(block_id)
        src = e.tier
        dst = self.tiers[dst_level]
        ok = False
        rotted = False
        try:
            if self._tier_reserve(dst_level, e.size, e.io_class):
                try:
                    data = src.read(block_id, 0, e.size)
                    _check_move(data, e.digest, block_id, "promotion")
                    dst.write(block_id, data)
                    dst.commit(e.size)
                    ok = True
                except IntegrityError as exc:
                    # Rotted in place: neither promote it NOR put it
                    # back. Quarantine — the entry stays gone, the tier
                    # copy is deleted below, the next read re-fetches.
                    dst.cancel(e.size)
                    rotted = True
                    with self._cond:
                        self.moves_failed += 1
                        self.quarantined += 1
                    log.warning("promotion of %s: copy is corrupt, "
                                "quarantining: %s", block_id, exc)
                except Exception as exc:   # repro: allow[RP005] — keep in place
                    dst.cancel(e.size)
                    with self._cond:
                        self.moves_failed += 1
                    log.warning("promotion of %s to %s failed: %s",
                                block_id, dst.name, exc)
        finally:
            with self._cond:
                if ok:
                    ne = _IndexEntry(dst, e.size, refs=0, io_class=e.io_class,
                                     digest=e.digest)
                    self._entries[block_id] = ne
                    self._note_evictable(block_id, ne)
                    self.promotions += 1
                elif not rotted:
                    self._entries[block_id] = e
                    self._note_evictable(block_id, e)
                self._deleting.discard(block_id)
                self._cond.notify_all()
        if ok or rotted:
            self._delete_from_tier(src, block_id, e.size)
        return ok

    def _demote_pass(self) -> None:
        for tier in self.tiers[:-1]:
            high = int(self.demote_watermark * tier.capacity)
            excess = tier.used - high
            if excess > 0:
                # Default-class pressure: demotes cold unprotected blocks,
                # leaves the protected hot set in place.
                self.evict_from(tier, excess, requester="default")

    def _prune_heat(self) -> None:
        now = time.monotonic()
        with self._cond:
            dead = [bid for bid, h in self._heat.items()
                    if bid not in self._entries
                    and bid not in self._flights
                    and h.value(now, self.half_life_s) < 0.05]
            for bid in dead:
                del self._heat[bid]

    # -- introspection --------------------------------------------------------
    def heat_of(self, block_id: str) -> float:
        """Current decayed temperature of a block (0.0 when untracked)."""
        now = time.monotonic()
        with self._cond:
            h = self._heat.get(block_id)
            return h.value(now, self.half_life_s) if h is not None else 0.0

    def level_of(self, block_id: str) -> int | None:
        """Hierarchy level currently holding the block (None = absent)."""
        with self._cond:
            e = self._entries.get(block_id)
            return e.tier.level if e is not None else None

    def hsm_snapshot(self) -> dict:
        with self._cond:
            per_level = {}
            for e in self._entries.values():
                d = per_level.setdefault(
                    e.tier.name, {"blocks": 0, "bytes": 0})
                d["blocks"] += 1
                d["bytes"] += e.size
            return dict(
                promotions=self.promotions,
                demotions=self.demotions,
                evictions=self.evictions,
                forced_evictions=self.forced_evictions,
                moves_failed=self.moves_failed,
                tier_hits=dict(self.tier_hits),
                class_hits=dict(self.class_hits),
                resident_per_tier=per_level,
                heat_tracked=len(self._heat),
                costs={t.name: cm.snapshot()
                       for t, cm in zip(self.tiers, self.costs)},
            )

    def snapshot(self) -> dict:
        out = super().snapshot()
        out["hsm"] = self.hsm_snapshot()
        return out


# --------------------------------------------------------------------------- #
# composite store
# --------------------------------------------------------------------------- #
class HSMStore(ObjectStore):
    """A backing `ObjectStore` bundled with its cache hierarchy.

    Pure delegation for the store protocol (the hierarchy caches *blocks*,
    which live above the store interface, in the engines); `PrefetchFS`
    recognizes the wrapper and adopts ``tiers`` + ``index``, reading
    through ``inner``. Built by the ``hsm://`` factory in
    ``repro.io.stores`` or directly.
    """

    def __init__(self, inner: ObjectStore, tiers: list[CacheTier],
                 index: HSMIndex) -> None:
        self.inner = inner
        self.tiers = list(tiers)
        self.index = index

    # -- delegation ---------------------------------------------------------
    def list_objects(self, prefix: str = "") -> list[ObjectMeta]:
        return self.inner.list_objects(prefix)

    def size(self, key: str) -> int:
        return self.inner.size(key)

    def get_range(self, key: str, start: int, end: int) -> bytes:
        return self.inner.get_range(key, start, end)

    def get_ranges(self, key: str, spans: list[tuple[int, int]]) -> list[bytes]:
        return self.inner.get_ranges(key, spans)

    def get_range_verified(self, key: str, start: int,
                           end: int) -> tuple[bytes, str]:
        return self.inner.get_range_verified(key, start, end)

    def get_ranges_verified(
        self, key: str, spans: list[tuple[int, int]],
    ) -> list[tuple[bytes, str]]:
        return self.inner.get_ranges_verified(key, spans)

    def digest_range(self, key: str, start: int, end: int) -> str:
        return self.inner.digest_range(key, start, end)

    def put(self, key: str, data: bytes) -> None:
        self.inner.put(key, data)

    def delete(self, key: str) -> None:
        self.inner.delete(key)

    def get(self, key: str) -> bytes:
        return self.inner.get(key)

    def start_multipart(self, key: str) -> MultipartUpload:
        return self.inner.start_multipart(key)

    def exists(self, key: str) -> bool:
        return self.inner.exists(key)

    def close(self) -> None:
        """Stop the mover and release tier OS resources (persistent tiers
        keep their blocks on disk)."""
        self.index.close()
        for t in self.tiers:
            t.close()


# Default simulated device links for URI-assembled hierarchies (scaled
# Table-I-style constants; override per deployment by constructing tiers
# directly).
MEM_LINK = dict(latency_s=1.6e-6, bandwidth_Bps=2221e6)
DISK_LINK = dict(latency_s=1e-4, bandwidth_Bps=500e6)
SHARED_LINK = dict(latency_s=1e-3, bandwidth_Bps=200e6)

HSM_URI_PARAMS = {
    "mem", "disk", "shared", "backing",
    "half_life_s", "promote_threshold", "watermark", "mover_ms",
}


def _dir_spec(value: str, what: str) -> tuple[str, int]:
    """``/path:1GB`` -> (path, capacity). The LAST colon splits, so
    Windows drive letters survive."""
    path, sep, size = value.rpartition(":")
    if not sep or not path:
        raise ValueError(
            f"hsm:// {what} must be path:size (e.g. /scratch/cache:1GB), "
            f"got {value!r}"
        )
    return path, parse_size(size)


def build_hsm(uri, open_inner) -> HSMStore:
    """Assemble an `HSMStore` from a parsed ``hsm://`` `StoreURI`.

    Recognized params: ``mem=<size>``, ``disk=<path>:<size>``,
    ``shared=<path>:<size>`` (each optional, at least one required; level
    order is mem, disk, shared), ``backing=<uri>`` (required; a nested
    query string must be percent-encoded), and the tuning knobs
    ``half_life_s``, ``promote_threshold``, ``watermark``, ``mover_ms``
    (``mover_ms=0`` disables the background mover).

    ``open_inner`` resolves the backing URI (the store registry's
    ``open_store``, injected to keep this module free of the io layer).
    """
    uri.require_known_params(HSM_URI_PARAMS)
    backing = uri.params.get("backing")
    if not backing:
        raise ValueError("hsm:// URI needs backing=<store uri>")
    tiers: list[CacheTier] = []
    if "mem" in uri.params:
        cap = parse_size(uri.params["mem"])
        tiers.append(MemTier(
            cap,
            read_link=LinkModel(name="hsm.mem.r", **MEM_LINK),
            write_link=LinkModel(name="hsm.mem.w", **MEM_LINK),
            name="hsm.mem",
        ))
    if "disk" in uri.params:
        path, cap = _dir_spec(uri.params["disk"], "disk")
        tiers.append(DirTier(
            cap, root=path,
            read_link=LinkModel(name="hsm.disk.r", **DISK_LINK),
            write_link=LinkModel(name="hsm.disk.w", **DISK_LINK),
            name="hsm.disk",
        ))
    if "shared" in uri.params:
        path, cap = _dir_spec(uri.params["shared"], "shared")
        tiers.append(DirTier(
            cap, root=path,
            read_link=LinkModel(name="hsm.shared.r", **SHARED_LINK),
            write_link=LinkModel(name="hsm.shared.w", **SHARED_LINK),
            name="hsm.shared",
        ))
    if not tiers:
        raise ValueError(
            "hsm:// URI needs at least one tier (mem=, disk=, or shared=)"
        )
    mover_ms = uri.float_param("mover_ms", 500.0)
    index = HSMIndex(
        tiers,
        half_life_s=uri.float_param("half_life_s", 30.0) or 30.0,
        promote_threshold=uri.float_param("promote_threshold", 2.0) or 2.0,
        demote_watermark=uri.float_param("watermark", 0.9) or 0.9,
        mover_interval_s=(mover_ms / 1e3 if mover_ms else None),
    )
    return HSMStore(open_inner(backing), tiers, index)
