"""Deterministic interleaving explorer over the concurrency core.

Runs small concurrency *models* — a few threads exercising a real
object (`CacheIndex` single flight, `UploadPool` close-vs-submit,
`PeerGroup` failover) or a deliberately-broken fixture — under
`repro.sched.CoopScheduler`, with the typestate protocols from
`repro.analysis.protocols` attached as runtime monitors. Two search
modes over the schedule space:

* `fuzz(model, seed=...)` — seeded random schedules; identical seed,
  identical trace and verdict, machine-independent (the scheduler's
  clock is virtual and its candidate ordering is by task name).
* `explore(model, preemption_bound=...)` — CHESS-style exhaustive
  enumeration of every schedule reachable with at most N preemptions
  (a context switch at a point where the running task could have
  continued). Most real concurrency bugs need only 1–2.

A violating schedule's decision sequence is returned in the `Verdict`;
`replay(model, decisions)` re-runs exactly that interleaving.

The monitors are the *same* `ProtocolSpec` tables the static pass
interprets — plus the one invariant statics cannot see: at most one
resource per key in an `exclusive_states` state (single flight).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from ..sched import (
    CoopScheduler,
    DeadlockError,
    LivelockError,
    RandomPicker,
    ReplayPicker,
    TaskFailed,
)
from .protocols import CACHE_ACQUIRE, LIFECYCLE, ProtocolSpec

__all__ = [
    "ProtocolMonitor",
    "Verdict",
    "fuzz",
    "explore",
    "replay",
    "RacySingleFlightModel",
    "SafeSingleFlightModel",
    "SingleFlightModel",
    "UploadPoolCloseModel",
    "PeerFailoverModel",
]


# ---------------------------------------------------------------------------
# Runtime protocol monitor.
# ---------------------------------------------------------------------------

class ProtocolMonitor:
    """Runs `ProtocolSpec` state machines over live objects.

    Violations are *recorded*, never raised — a bad interleaving must
    run to completion so its full trace and decision sequence can be
    reported and replayed.
    """

    def __init__(self) -> None:
        self.violations: list[str] = []
        #: (spec name, state, key) -> occupying handle, for states at
        #: most one resource per key may hold (single flight).
        self._exclusive: dict[tuple[str, str, str], object] = {}
        #: cache-acquire pin refcounts by block id.
        self.pins: dict[str, int] = {}

    def note(self, msg: str) -> None:
        if msg not in self.violations:
            self.violations.append(msg)

    def pin_imbalance(self) -> dict[str, int]:
        """Blocks whose pins were not released exactly as taken."""
        return {bid: n for bid, n in sorted(self.pins.items()) if n != 0}

    # -- generic receiver-matched protocols (lifecycle etc.) ----------------
    def watch(self, obj, spec: ProtocolSpec):
        """Attach `spec`'s event machine to one live object (the object
        IS the resource). A `uses` method counts as a violation only
        when it *returns normally* in a final state — an API that raises
        on use-after-close has defended itself, and the model catching
        that error is conforming."""
        mon = self
        state = {"s": spec.initial or spec.states[0]}
        label = type(obj).__name__

        for event, trans in spec.events.items():
            inner = getattr(obj, event, None)
            if inner is None:
                continue

            def wrap_event(event=event, trans=trans, inner=inner):
                def call(*a, **k):
                    out = inner(*a, **k)
                    st = state["s"]
                    if st in trans:
                        state["s"] = trans[st]
                    elif st not in spec.monitor_ignore_states:
                        mon.note(f"{spec.name}: {event}() on {label} "
                                 f"in state {st!r}")
                    return out
                return call

            setattr(obj, event, wrap_event())

        for use in spec.uses:
            inner = getattr(obj, use, None)
            if inner is None:
                continue

            def wrap_use(use=use, inner=inner):
                def call(*a, **k):
                    st = state["s"]
                    out = inner(*a, **k)
                    if st in spec.final:
                        mon.note(f"{spec.name}: {use}() succeeded on "
                                 f"{label} in final state {st!r}")
                    return out
                return call

            setattr(obj, use, wrap_use())
        return obj

    # -- cache-acquire (arg0-matched, resources born from returns) ----------
    def watch_index(self, index, spec: ProtocolSpec = CACHE_ACQUIRE):
        """Attach the cache-acquire machine to a live index-like object.

        Transitions, ignore-states and exclusivity all come from the
        spec; the glue here only extracts resource identity — flights
        from `acquire`'s return tuple, pins keyed by block id — which is
        the part the static binder does from the AST. Wrappers are
        instance attributes, so internal calls such as `leave()` →
        ``self.unpin(...)`` route through the monitor too.
        """
        mon = self
        # One logical resource PER ACQUISITION, not per handle: a leader
        # and its waiters share the same flight object, but each holds
        # its own obligation (publish/abort vs join/leave).
        acquisitions: dict[int, list[list]] = {}   # id(handle) -> [[state, key]]
        live: dict[int, object] = {}    # keep handles alive: ids stay unique

        def enter(handle, st: str, key: str) -> None:
            acquisitions.setdefault(id(handle), []).append([st, key])
            live[id(handle)] = handle
            if st in spec.exclusive_states:
                slot = (spec.name, st, key)
                if slot in mon._exclusive:
                    mon.note(f"{spec.name}: two concurrent {st!r} resources "
                             f"for key {key!r} (single flight violated)")
                else:
                    mon._exclusive[slot] = handle

        def transition(handle, event: str) -> None:
            lst = acquisitions.get(id(handle))
            if not lst:
                return                   # a flight born before watching began
            trans = spec.events.get(event, {})
            for res in lst:              # the acquisition this event retires
                if res[0] in trans:
                    if res[0] in spec.exclusive_states:
                        mon._exclusive.pop((spec.name, res[0], res[1]), None)
                    res[0] = trans[res[0]]
                    return
            for res in lst:
                if res[0] not in spec.monitor_ignore_states:
                    mon.note(f"{spec.name}: {event}() on a {res[0]!r} "
                             f"resource (key {res[1]!r})")
                    return

        real_acquire = index.acquire
        real = {m: getattr(index, m)
                for m in ("publish", "abort_fetch", "join", "leave", "unpin")
                if hasattr(index, m)}

        def acquire(block_id, *a, **k):
            kind, val = real_acquire(block_id, *a, **k)
            st = spec.discriminants.get(kind)
            if st == "pinned":
                mon.pins[block_id] = mon.pins.get(block_id, 0) + 1
            elif st is not None:
                enter(val, st, block_id)
            return kind, val

        def publish(flight, *a, **k):
            out = real["publish"](flight, *a, **k)
            # A publish from a still-leading flight pins once for the
            # leader plus once per registered waiter (their joins return
            # pre-pinned hits). flight.waiters is frozen once done.
            leading = [r for r in acquisitions.get(id(flight), [])
                       if r[0] == "leading"]
            if leading and not getattr(flight, "reclaimed", False):
                bid = getattr(flight, "block_id", leading[0][1])
                mon.pins[bid] = (mon.pins.get(bid, 0) + 1
                                 + getattr(flight, "waiters", 0))
            transition(flight, "publish")
            return out

        def abort_fetch(flight, *a, **k):
            out = real["abort_fetch"](flight, *a, **k)
            transition(flight, "abort_fetch")
            return out

        def join(flight, *a, **k):
            out = real["join"](flight, *a, **k)
            st = out[0] if isinstance(out, tuple) else out
            if st != "timeout":          # keep joining / leave() still owed
                transition(flight, "join")
            return out

        def leave(flight, *a, **k):
            out = real["leave"](flight, *a, **k)
            transition(flight, "leave")
            return out

        def unpin(block_id, *a, **k):
            n = mon.pins.get(block_id, 0) - 1
            mon.pins[block_id] = n
            if n < 0:
                mon.note(f"{spec.name}: unpin({block_id!r}) without a "
                         f"matching pin (double unpin)")
            return real["unpin"](block_id, *a, **k)

        index.acquire = acquire
        if "publish" in real:
            index.publish = publish
        if "abort_fetch" in real:
            index.abort_fetch = abort_fetch
        if "join" in real:
            index.join = join
        if "leave" in real:
            index.leave = leave
        if "unpin" in real:
            index.unpin = unpin
        return index


# ---------------------------------------------------------------------------
# Verdicts and search.
# ---------------------------------------------------------------------------

@dataclass
class Verdict:
    """Outcome of a schedule search. `decisions` replays the violating
    (or final) schedule via `replay`."""

    ok: bool
    schedules: int
    violations: list[str]
    trace: list[str]
    decisions: tuple[int, ...]
    error: str | None = None

    def describe(self) -> str:
        if self.ok:
            return f"ok after {self.schedules} schedule(s)"
        what = "; ".join(self.violations) or self.error or "violation"
        return (f"violation after {self.schedules} schedule(s): {what} "
                f"[replay decisions={list(self.decisions)}]")


@dataclass
class _Outcome:
    trace: list = field(default_factory=list)
    points: list = field(default_factory=list)
    decisions: list = field(default_factory=list)
    violations: list = field(default_factory=list)
    error: str | None = None


def _run_schedule(model_factory, picker) -> _Outcome:
    out = _Outcome()
    sched = CoopScheduler(picker)
    monitor = ProtocolMonitor()
    with sched.activate():
        model = model_factory()
        for name, fn in model.setup(monitor):
            sched.spawn(fn, name=name)
        try:
            sched.run()
            model.check()
        except AssertionError as e:
            out.error = f"check failed: {e}"
        except DeadlockError as e:
            out.error = f"deadlock: {e}"
        except LivelockError as e:
            out.error = f"livelock: {e}"
        except TaskFailed as e:
            out.error = str(e)
    out.trace = list(sched.trace)
    out.points = list(sched.points)
    out.decisions = list(sched.decisions)
    out.violations = list(monitor.violations)
    return out


def _verdict(out: _Outcome, schedules: int) -> Verdict:
    return Verdict(
        ok=not out.violations and out.error is None,
        schedules=schedules,
        violations=out.violations,
        trace=out.trace,
        decisions=tuple(out.decisions),
        error=out.error,
    )


def replay(model_factory, decisions) -> Verdict:
    """Re-run one exact interleaving from a recorded decision sequence."""
    return _verdict(_run_schedule(model_factory, ReplayPicker(decisions)), 1)


def fuzz(model_factory, *, seed: int = 0, runs: int = 25) -> Verdict:
    """Seeded random schedules; stops at the first violating one.
    Fully deterministic in (model, seed, runs)."""
    out = None
    for i in range(runs):
        out = _run_schedule(model_factory, RandomPicker(f"{seed}:{i}"))
        if out.violations or out.error is not None:
            return _verdict(out, i + 1)
    return _verdict(out, runs)


def explore(model_factory, *, preemption_bound: int = 2,
            max_schedules: int = 200) -> Verdict:
    """Preemption-bounded exhaustive search (CHESS-style).

    Runs the nonpreemptive baseline schedule, then branches a new
    decision prefix at every scheduling point where a *different*
    runnable task could have been chosen — counting a switch away from
    a still-runnable task as one preemption and never exceeding the
    bound. Within `max_schedules`, every schedule with ≤ bound
    preemptions is eventually visited."""
    tried: set[tuple[int, ...]] = set()
    stack: list[tuple[int, ...]] = [()]
    runs = 0
    last = None
    while stack and runs < max_schedules:
        prefix = stack.pop()
        if prefix in tried:
            continue
        tried.add(prefix)
        out = _run_schedule(model_factory, ReplayPicker(prefix))
        runs += 1
        last = out
        if out.violations or out.error is not None:
            return _verdict(out, runs)
        # Cumulative preemption count before each point.
        pre, prelist = 0, []
        for d, (_names, _chosen, cur) in zip(out.decisions, out.points):
            prelist.append(pre)
            if cur is not None and d != cur:
                pre += 1
        for i in range(len(out.points) - 1, len(prefix) - 1, -1):
            names, chosen, cur = out.points[i]
            for j in range(len(names)):
                if j == chosen:
                    continue
                cost = 0 if (cur is None or j == cur) else 1
                if prelist[i] + cost <= preemption_bound:
                    branch = tuple(out.decisions[:i]) + (j,)
                    if branch not in tried:
                        stack.append(branch)
    return _verdict(last, runs) if last is not None else Verdict(
        ok=True, schedules=0, violations=[], trace=[], decisions=())


# ---------------------------------------------------------------------------
# Fixture models: a known-racy single-flight index and its fixed twin.
# The explorer's own tests calibrate against these — the racy one MUST
# be caught, the safe one MUST pass.
# ---------------------------------------------------------------------------

class _FixtureFlight:
    __slots__ = ("block_id", "done", "waiters")

    def __init__(self, block_id: str) -> None:
        self.block_id = block_id
        self.done = False
        self.waiters = 0


class _BrokenIndex:
    """Deliberately racy single-flight registry: the absent-check and
    the leader-install sit in two separate lock regions (check-then-act),
    so two threads interleaved between them both become leaders."""

    def __init__(self) -> None:
        import threading
        self._lock = threading.Lock()
        self._flights: dict[str, _FixtureFlight] = {}
        self._published: set[str] = set()

    def acquire(self, block_id: str):
        with self._lock:
            if block_id in self._published:
                return "hit", None
            fl = self._flights.get(block_id)
        if fl is not None:
            return "wait", fl
        # BUG under test: a second thread can pass the check above
        # before this block runs, and both install themselves.
        with self._lock:
            fl = _FixtureFlight(block_id)
            self._flights[block_id] = fl
            return "leader", fl

    def publish(self, flight: _FixtureFlight) -> None:
        with self._lock:
            flight.done = True
            self._published.add(flight.block_id)
            if self._flights.get(flight.block_id) is flight:
                del self._flights[flight.block_id]

    def abort_fetch(self, flight: _FixtureFlight) -> None:
        self.publish(flight)

    def join(self, flight: _FixtureFlight, timeout: float | None = None):
        return ("hit", None) if flight.done else ("timeout", None)


class _SafeIndex(_BrokenIndex):
    """The fixed twin: check and install in one atomic lock region."""

    def acquire(self, block_id: str):
        with self._lock:
            if block_id in self._published:
                return "hit", None
            fl = self._flights.get(block_id)
            if fl is not None:
                return "wait", fl
            fl = _FixtureFlight(block_id)
            self._flights[block_id] = fl
            return "leader", fl


class _FixtureSingleFlight:
    def __init__(self, index_cls) -> None:
        self._index_cls = index_cls
        self.fetches = 0

    def setup(self, monitor: ProtocolMonitor):
        self.index = monitor.watch_index(self._index_cls())

        def reader():
            kind, fl = self.index.acquire("blk")
            if kind == "leader":
                self.fetches += 1          # "the" store fetch
                self.index.publish(fl)
            elif kind == "wait":
                self.index.join(fl)
            # "hit": already resident, nothing owed

        return [("reader-a", reader), ("reader-b", reader)]

    def check(self) -> None:
        # Single flight's observable promise: ONE store fetch per block.
        # (Two overlapping leaders additionally trip the monitor's
        # exclusive-state check, but that needs a second preemption.)
        assert self.fetches == 1, f"{self.fetches} fetches of one block"


def RacySingleFlightModel() -> _FixtureSingleFlight:
    return _FixtureSingleFlight(_BrokenIndex)


def SafeSingleFlightModel() -> _FixtureSingleFlight:
    return _FixtureSingleFlight(_SafeIndex)


# ---------------------------------------------------------------------------
# Real-tree models.
# ---------------------------------------------------------------------------

class SingleFlightModel:
    """Three readers race `CacheIndex.acquire` on one missing block: the
    protocol monitor checks single-leadership and pin balance; `check`
    asserts exactly one backing-store fetch and a fully-released index."""

    def __init__(self, readers: int = 3) -> None:
        self.readers = readers
        self.fetches = 0

    def setup(self, monitor: ProtocolMonitor):
        from ..store.tiers import CacheIndex, MemTier
        self.tier = MemTier(capacity=1 << 20)
        self.index = monitor.watch_index(
            CacheIndex([self.tier], flight_ttl_s=None))
        self.monitor = monitor
        payload = b"x" * 64

        def reader():
            idx = self.index
            kind, val = idx.acquire("blk")
            if kind == "leader":
                try:
                    self.fetches += 1
                    self.tier.write("blk", payload)
                except BaseException:
                    idx.abort_fetch(val)
                    raise
                idx.publish(val, self.tier, len(payload))
                assert self.tier.read("blk") == payload
                idx.unpin("blk")
            elif kind == "wait":
                st, tier = idx.join(val)
                assert st == "hit"
                assert tier.read("blk") == payload
                idx.unpin("blk")
            else:                          # a hit: leader already published
                assert val.read("blk") == payload
                idx.unpin("blk")

        return [(f"reader-{i}", reader) for i in range(self.readers)]

    def check(self) -> None:
        assert self.fetches == 1, f"single flight broken: {self.fetches} fetches"
        assert not self.index._flights, "flight leaked past the run"
        entry = self.index._entries.get("blk")
        assert entry is not None and entry.refs == 0, "pins leaked"
        assert not self.monitor.pin_imbalance(), (
            f"pin imbalance: {self.monitor.pin_imbalance()}")


class UploadPoolCloseModel:
    """`UploadPool.close` races `submit`: every job `submit` *accepted*
    must execute before close returns; late submits must be refused
    loudly, never silently dropped."""

    def __init__(self, jobs: int = 3) -> None:
        self.jobs = jobs
        self.submitted: list[int] = []
        self.executed: list[int] = []

    def setup(self, monitor: ProtocolMonitor):
        from ..io.write import UploadPool
        self.pool = monitor.watch(UploadPool(), LIFECYCLE)
        self.pool.ensure(1)

        def submitter():
            for i in range(self.jobs):
                try:
                    self.pool.submit(lambda i=i: self.executed.append(i))
                except ValueError:
                    return                 # pool closed under us: refused, fine
                self.submitted.append(i)

        def closer():
            self.pool.close()

        return [("submitter", submitter), ("closer", closer)]

    def check(self) -> None:
        assert self.pool._closed
        assert sorted(self.executed) == self.submitted, (
            f"accepted jobs dropped: submitted={self.submitted} "
            f"executed={sorted(self.executed)}")


class PeerFailoverModel:
    """Concurrent `PeerGroup.note_failure` reports racing to the miss
    limit: the peer must die exactly once (one death event, consistent
    membership), no matter which reporter's update lands last."""

    def setup(self, monitor: ProtocolMonitor):
        from ..peer.group import PeerGroup, PeerSpec
        self.group = PeerGroup(
            0,
            [PeerSpec(1, "sib-1", 1), PeerSpec(2, "sib-2", 1)],
            heartbeat_interval_s=None,
            miss_limit=2,
        )

        def reporter():
            self.group.note_failure(1)

        return [("reporter-a", reporter), ("reporter-b", reporter)]

    def check(self) -> None:
        g = self.group
        assert not g.is_alive(1), "peer 1 should be dead at the miss limit"
        assert g.deaths == 1, f"death double-counted: {g.deaths}"
        assert g.alive_ids() == [0, 2]
        g.close()
