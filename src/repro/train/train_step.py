"""Train-step builder: loss -> grads -> AdamW, with optional microbatch
gradient accumulation (`lax.scan`) for memory-bound cells."""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.train.optimizer import AdamWConfig, AdamWState, apply_updates, init_state


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState


def init_train_state(model: Model, key, param_dtype=jnp.float32,
                     opt_cfg: AdamWConfig | None = None) -> TrainState:
    params = model.init(key, param_dtype)
    return TrainState(params=params, opt=init_state(params, opt_cfg))


def abstract_train_state(model: Model, rules, param_dtype=jnp.float32,
                         opt_cfg: AdamWConfig | None = None) -> TrainState:
    """ShapeDtypeStruct train state for the dry-run (no allocation)."""
    from repro.train.optimizer import _moment_dtype

    params = model.abstract_params(rules, param_dtype)
    mdt = _moment_dtype(opt_cfg) if opt_cfg is not None else jnp.float32

    def like(p, dtype=None):
        dtype = dtype or p.dtype
        return jax.ShapeDtypeStruct(p.shape, dtype, sharding=p.sharding) \
            if p.sharding is not None else jax.ShapeDtypeStruct(p.shape, dtype)

    opt = AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(lambda p: like(p, mdt), params),
        v=jax.tree.map(lambda p: like(p, mdt), params),
    )
    return TrainState(params=params, opt=opt)


@dataclass(frozen=True)
class StepConfig:
    microbatches: int = 1
    q_chunk: int = 512
    loss_chunk: int = 512
    remat: bool = True


def _split_batch(batch: dict, n: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    return {k: split(v) for k, v in batch.items()}


def build_train_step(model: Model, opt_cfg: AdamWConfig,
                     step_cfg: StepConfig = StepConfig()):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(
            params, batch,
            q_chunk=step_cfg.q_chunk,
            loss_chunk=step_cfg.loss_chunk,
            remat=step_cfg.remat,
        )

    def train_step(state: TrainState, batch: dict):
        if step_cfg.microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            mb = _split_batch(batch, step_cfg.microbatches)

            def body(acc, micro):
                loss_i, g_i = jax.value_and_grad(loss_fn)(state.params, micro)
                acc_loss, acc_g = acc
                return (
                    acc_loss + loss_i,
                    jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), acc_g, g_i
                    ),
                ), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (loss_sum, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_g), mb
            )
            inv = 1.0 / step_cfg.microbatches
            loss = loss_sum * inv
            grads = jax.tree.map(lambda g: g * inv, grads)

        params, opt, metrics = apply_updates(opt_cfg, state.params, grads, state.opt)
        metrics = {"loss": loss, **metrics}
        return TrainState(params, opt), metrics

    return train_step
