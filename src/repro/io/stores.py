"""URI-addressed object-store registry: ``open_store`` + ``@register_store``.

The producer-side mirror of the reader-engine registry: call sites name a
store by URI instead of hand-constructing backend objects, so new backends
(a real S3 binding, an HTTP gateway, a sharded meta-store) plug in without
touching loader, checkpoint, serving, or benchmark code::

    from repro.io import open_store

    store = open_store("mem://scratch")                 # in-memory bucket
    store = open_store("local:///data/ckpts")           # real directory
    store = open_store("sims3://bucket?latency_ms=40&bw_mbps=200")

``PrefetchFS`` accepts the same URIs directly:
``PrefetchFS("sims3://bucket?latency_ms=40")``.

Built-in schemes:

  * ``mem://name`` — dict-backed `MemStore` (no simulated link cost);
  * ``local://path`` / ``local:///abs/path`` — `DirStore` over a real
    directory;
  * ``sims3://bucket?...`` — `SimS3Store` behind a `LinkModel`. Query
    params (all optional): ``latency_ms``, ``bw_mbps``, ``jitter``,
    ``seed``, ``fail_prob``, plus ``put_latency_ms``/``put_bw_mbps`` for
    an asymmetric upload link.

Opened stores are cached per canonical URI, so two components that name
the same bucket share one instance (a producer's writes are visible to a
consumer opened from the same URI). Pass ``fresh=True`` to bypass the
cache — benchmarks do this so A/B arms never share simulated link state.

New backends register a factory taking the parsed `StoreURI`::

    @register_store("s3")
    def _open_real_s3(uri: StoreURI) -> ObjectStore:
        return RealS3Store(bucket=uri.netloc, **uri.params)
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Mapping
from urllib.parse import parse_qsl, quote, urlsplit

from repro.store.base import ObjectStore
from repro.store.link import LinkModel
from repro.store.local import DirStore, MemStore
from repro.store.sim_s3 import SimS3Store

StoreFactory = Callable[["StoreURI"], ObjectStore]


@dataclass(frozen=True)
class StoreURI:
    """A parsed store address: ``scheme://netloc/path?params``."""

    scheme: str
    netloc: str
    path: str
    params: Mapping[str, str] = field(default_factory=dict)

    @property
    def location(self) -> str:
        """netloc + path joined — the bucket/directory the URI names
        (``local://rel/dir`` -> ``rel/dir``, ``local:///abs`` -> ``/abs``)."""
        return self.netloc + self.path

    def canonical(self) -> str:
        """Injective normal form used as the instance-cache key: scheme is
        already lowercased by the parser, params are sorted AND
        re-percent-encoded. The re-encoding matters: ``parse_qsl``
        decodes escapes, so joining raw values would collapse e.g.
        ``?a=1&b=2`` and ``?a=1%26b%3D2`` (one param whose VALUE is
        "1&b=2") into the same key — two different stores would silently
        share one cached instance (one LinkModel, one state)."""
        query = "&".join(
            f"{quote(k, safe='')}={quote(v, safe='')}"
            for k, v in sorted(self.params.items())
        )
        return f"{self.scheme}://{self.netloc}{self.path}" + (
            f"?{query}" if query else ""
        )

    def float_param(self, key: str, default: float | None = None) -> float | None:
        raw = self.params.get(key)
        if raw is None:
            return default
        try:
            return float(raw)
        except ValueError:
            raise ValueError(
                f"store URI param {key}={raw!r} is not a number"
            ) from None

    def require_known_params(self, known: set[str]) -> None:
        unknown = set(self.params) - known
        if unknown:
            raise ValueError(
                f"unknown store URI params for {self.scheme!r}: "
                f"{', '.join(sorted(unknown))}; known: {', '.join(sorted(known))}"
            )


def parse_store_uri(uri: str) -> StoreURI:
    if "://" not in uri:
        raise ValueError(
            f"not a store URI: {uri!r} (expected scheme://..., e.g. mem://, "
            f"local:///path, sims3://bucket?latency_ms=40)"
        )
    parts = urlsplit(uri)
    if not parts.scheme:
        raise ValueError(f"store URI has no scheme: {uri!r}")
    params = dict(parse_qsl(parts.query, keep_blank_values=True))
    return StoreURI(
        scheme=parts.scheme, netloc=parts.netloc, path=parts.path, params=params
    )


_REGISTRY: dict[str, StoreFactory] = {}
_CACHE: dict[str, ObjectStore] = {}
# Reentrant: composite factories (hsm://) resolve their backing store
# through open_store while the cache lock is held.
_CACHE_LOCK = threading.RLock()


def register_store(scheme: str):
    """Decorator binding a factory ``(StoreURI) -> ObjectStore`` to a URI
    scheme; existing call sites reach the new backend by URI alone."""

    def deco(factory: StoreFactory) -> StoreFactory:
        if scheme in _REGISTRY:
            raise ValueError(f"store scheme {scheme!r} already registered")
        _REGISTRY[scheme] = factory
        return factory

    return deco


def available_stores() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def open_store(target: ObjectStore | str, *, fresh: bool = False) -> ObjectStore:
    """Resolve `target` to an `ObjectStore`.

    An existing store instance passes through untouched; a URI string
    dispatches through the scheme registry. Same canonical URI -> same
    cached instance, unless ``fresh=True`` (always build a new store, and
    leave the cache alone).
    """
    if isinstance(target, ObjectStore):
        return target
    if not isinstance(target, str):
        raise TypeError(
            f"open_store expects an ObjectStore or URI string, got "
            f"{type(target).__name__}"
        )
    uri = parse_store_uri(target)
    try:
        factory = _REGISTRY[uri.scheme]
    except KeyError:
        raise ValueError(
            f"unknown store scheme {uri.scheme!r}; "
            f"available: {', '.join(available_stores())}"
        ) from None
    if fresh:
        return factory(uri)
    key = uri.canonical()
    with _CACHE_LOCK:
        store = _CACHE.get(key)
        if store is None:
            store = _CACHE[key] = factory(uri)
        return store


def clear_store_cache() -> None:
    """Forget cached per-URI instances (tests and benchmark harnesses)."""
    with _CACHE_LOCK:
        _CACHE.clear()


# --------------------------------------------------------------------------- #
# built-in schemes
# --------------------------------------------------------------------------- #
@register_store("mem")
def _open_mem(uri: StoreURI) -> ObjectStore:
    uri.require_known_params(set())
    return MemStore()


@register_store("local")
def _open_local(uri: StoreURI) -> ObjectStore:
    uri.require_known_params(set())
    if not uri.location:
        raise ValueError("local:// URI needs a directory path")
    return DirStore(uri.location)


@register_store("sims3")
def _open_sims3(uri: StoreURI) -> ObjectStore:
    uri.require_known_params(
        {"latency_ms", "bw_mbps", "jitter", "seed", "fail_prob",
         "rps_limit", "rps_burst", "rps_penalty",
         "put_latency_ms", "put_bw_mbps"}
    )
    name = uri.location or "s3"
    rps_limit = uri.float_param("rps_limit")
    rps_burst = uri.float_param("rps_burst")
    rps_penalty = uri.float_param("rps_penalty", 0.0) or 0.0
    link = LinkModel(
        latency_s=(uri.float_param("latency_ms", 0.0) or 0.0) / 1e3,
        bandwidth_Bps=(
            uri.float_param("bw_mbps") * 1e6
            if uri.float_param("bw_mbps") is not None
            else float("inf")
        ),
        jitter=uri.float_param("jitter", 0.0) or 0.0,
        seed=int(uri.float_param("seed", 0) or 0),
        fail_prob=uri.float_param("fail_prob", 0.0) or 0.0,
        rps_limit=rps_limit if rps_limit is not None else float("inf"),
        rps_burst=rps_burst,
        rps_penalty=rps_penalty,
        name=name,
    )
    put_link = None
    if "put_latency_ms" in uri.params or "put_bw_mbps" in uri.params:
        # Jitter/seed/fault-injection/rate-limits apply to BOTH
        # directions (each direction gets its own token bucket); only
        # the latency/bandwidth shape is asymmetric.
        put_link = LinkModel(
            latency_s=(
                uri.float_param("put_latency_ms", link.latency_s * 1e3) or 0.0
            ) / 1e3,
            bandwidth_Bps=(
                uri.float_param("put_bw_mbps") * 1e6
                if uri.float_param("put_bw_mbps") is not None
                else link.bandwidth_Bps
            ),
            jitter=link.jitter,
            seed=link.seed,
            fail_prob=link.fail_prob,
            rps_limit=link.rps_limit,
            rps_burst=link.rps_burst,
            rps_penalty=link.rps_penalty,
            name=f"{name}.put",
        )
    return SimS3Store(link=link, put_link=put_link)


@register_store("hsm")
def _open_hsm(uri: StoreURI) -> ObjectStore:
    """Composite hierarchical-storage-manager store::

        hsm://?mem=64MB&disk=/scratch/cache:1GB&backing=mem://bucket

    Assembles cache tiers (level order mem, disk, shared) + an `HSMIndex`
    around the ``backing`` store; `PrefetchFS` adopts the hierarchy. A
    backing URI carrying its own query string must be percent-encoded
    (``backing=sims3%3A%2F%2Fb%3Flatency_ms%3D40``), since a bare ``&``
    would be read as the next hsm param. See `repro.store.hsm.build_hsm`.
    """
    from repro.store.hsm import build_hsm

    return build_hsm(uri, open_inner=open_store)


@register_store("peer")
def _open_peer(uri: StoreURI) -> ObjectStore:
    """Composite distributed-prefetch store::

        peer://?self=0&peers=0@127.0.0.1:9100,1@127.0.0.1:9101
              &backing=sims3%3A%2F%2Fbucket%3Flatency_ms%3D40

    Routes block reads to their rendezvous-hashed home host before
    touching the backing store; composes with ``hsm://`` via a
    percent-encoded ``backing=`` (the peer layer adopts that hierarchy).
    See `repro.peer.store.build_peer` for the full parameter grammar and
    README "Distributed prefetch" for the protocol.
    """
    from repro.peer.store import build_peer

    return build_peer(uri, open_inner=open_store)
