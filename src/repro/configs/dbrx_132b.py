"""dbrx-132b — Databricks fine-grained MoE transformer.

40L, d_model 6144, 48 q-heads / 8 kv-heads (head_dim 128), per-expert
d_ff 10752, vocab 100352, MoE 16 experts top-4 on every layer. DBRX
specifics: LayerNorm (no bias), GLU experts, RoPE, no attention biases.
16 experts divide the 16-way tensor axis exactly -> expert-parallel
all-to-all path available (a hillclimb target). [hf:databricks/dbrx-base;
unverified]
"""

from repro.configs.base import BlockDef, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="dbrx-132b",
        family="moe",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=10752,
        vocab_size=100352,
        pattern=(BlockDef("attn", "moe"),),
        norm_type="layernorm",
        norm_bias=False,
        act="silu",
        glu=True,
        rope_theta=500000.0,
        moe_num_experts=16,
        moe_top_k=4,
        source="hf:databricks/dbrx-base",
    )
)
