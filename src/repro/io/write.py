"""Write-behind upload pipeline: the producer-side mirror of Rolling Prefetch.

A `Writer` (returned by ``PrefetchFS.open_write``) buffers application
writes into part-sized chunks (``IOPolicy.blocksize``), stages each sealed
part in a local cache tier (the bounded staging budget doubles as
backpressure), and hands it to a shared `UploadPool` whose
``IOPolicy.write_depth`` background threads upload parts concurrently with
ongoing application writes — ``max(T_compute, T_upload)`` instead of
``T_compute + T_upload``, the paper's read-side pipeline run in reverse
(cf. the successor user-space hierarchical-storage work, arXiv:2404.11556,
and the checkpoint-stall analysis of arXiv:2108.06322).

Durability contract:

  * ``write()`` may return before bytes reach the store;
  * ``flush()`` seals the current buffer as a part and blocks until every
    sealed part is durably uploaded, raising the first upload error;
  * ``close()`` flushes, then atomically publishes the object (multipart
    ``complete()`` — or one background ``put`` when everything fit in a
    single part, matching the legacy sync path request-for-request), so a
    crashed writer never leaves a partially visible object;
  * ``close_async()`` + ``join()`` split close into enqueue-publish and
    barrier, so producers closing many writers (checkpoint save) overlap
    the final round-trips instead of paying one per writer serially;
  * ``abort()`` drops pending work and never publishes.

Transient store faults retry through the unified resilience layer
(`repro.io.retry`): full-jitter exponential backoff via the policy's
`RetryPolicy`, and an optional hedge (``IOPolicy.hedge_timeout_s``,
capped by ``max_hedges``) duplicates a straggling part upload — puts to
the same part index are idempotent, so taking the first copy that lands
is safe. The rolling read engine resolves through the same layer.
"""

from __future__ import annotations

import itertools
import queue
import threading
import time
from contextlib import suppress
from dataclasses import dataclass, field
from typing import Callable, Sequence

from repro.io.policy import IOPolicy
from repro.io.retry import Hedger, Retrier
from repro.store.base import ObjectStore, StoreError
from repro.store.tiers import CacheIndex, CacheTier
from repro.utils import get_logger

log = get_logger("io.write")

_WRITER_IDS = itertools.count()


@dataclass
class WriteStats:
    """Counters mutated from the application thread and the upload pool;
    same bump()/locked-snapshot discipline as the reader `PrefetchStats`."""

    bytes_written: int = 0      # accepted from the application
    bytes_uploaded: int = 0     # durably handed to the store
    parts_uploaded: int = 0
    put_requests: int = 0
    retries: int = 0
    throttles: int = 0          # ThrottleError responses (503 SlowDown)
    hedges: int = 0
    upload_s: float = 0.0       # cumulative time inside store calls
    stage_wait_s: float = 0.0   # application blocked on staging backpressure
    barrier_wait_s: float = 0.0  # flush()/close() waiting on in-flight parts
    unstaged_parts: int = 0     # parts too big for any tier (carried in RAM)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def bump(self, **deltas: int | float) -> None:
        with self._lock:
            for name, delta in deltas.items():
                setattr(self, name, getattr(self, name) + delta)

    def snapshot(self) -> dict:
        with self._lock:
            return {k: v for k, v in self.__dict__.items()
                    if not k.startswith("_")}


class UploadPool:
    """Shared pool of daemon threads draining part-upload jobs from every
    writer of one `PrefetchFS`; grows on demand to the largest
    ``write_depth`` any writer asked for."""

    def __init__(self) -> None:
        self._q: queue.Queue = queue.Queue()
        self._threads: list[threading.Thread] = []
        self._lock = threading.Lock()
        self._closed = False

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._threads)

    def ensure(self, depth: int) -> None:
        with self._lock:
            if self._closed:
                raise ValueError("UploadPool is closed")
            while len(self._threads) < depth:
                t = threading.Thread(
                    target=self._worker,
                    name=f"fs-upload-{len(self._threads)}",
                    daemon=True,
                )
                t.start()
                self._threads.append(t)

    def submit(self, job: Callable[[], None]) -> None:
        # Enqueue UNDER the lock: checking `_closed` and putting outside
        # it raced with close() — a job could land behind the shutdown
        # sentinels and be silently dropped while its writer's barrier
        # waited on a `_done` bump that would never come.
        with self._lock:
            if self._closed:
                raise ValueError("submit on closed UploadPool")
            self._q.put(job)

    def _worker(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                return
            try:
                job()
            except BaseException:   # repro: allow[RP005] — jobs capture their own errors; belt only
                log.exception("upload job leaked an exception")

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            threads = list(self._threads)
            # Sentinels go in while still holding the lock, so every job
            # accepted by submit() is strictly ahead of them in the FIFO —
            # workers drain all remaining jobs before they see a sentinel.
            for _ in threads:
                self._q.put(None)
        for t in threads:
            t.join(timeout=30.0)


class _Part:
    """One sealed part awaiting upload: either staged in a tier (data is
    read back at upload time) or carried inline when no tier can hold it.
    ``digest`` is minted over the sealed bytes at staging time (only with
    ``IOPolicy.verify="full"``) and re-checked after the tier read-back,
    so a part that rotted in staging fails the upload loudly instead of
    persisting corruption to the store."""

    __slots__ = ("index", "size", "tier", "block_id", "data", "digest")

    def __init__(self, index: int, size: int, tier: CacheTier | None,
                 block_id: str | None, data: bytes | None,
                 digest: str | None = None) -> None:
        self.index = index
        self.size = size
        self.tier = tier
        self.block_id = block_id
        self.data = data
        self.digest = digest


class Writer:
    """Write-behind file-like object; construct via ``PrefetchFS.open_write``."""

    def __init__(
        self,
        store: ObjectStore,
        key: str,
        policy: IOPolicy,
        tiers: Sequence[CacheTier],
        pool: UploadPool,
        index: CacheIndex | None = None,
    ) -> None:
        self.store = store
        self.key = key
        self.policy = policy
        self.tiers = list(tiers)
        # Shared cache index over the same tiers (when the fs has one):
        # staging backpressure may pressure-evict unpinned cached blocks
        # instead of spinning forever against a tier filled by
        # keep_cached readers.
        self.index = index
        self.stats = WriteStats()
        self._pool = pool
        self._cond = threading.Condition()
        self._buf = bytearray()
        self._next_index = 0
        self._sealed = 0            # jobs handed to the pool
        self._done = 0              # jobs finished (success, skip, or error)
        self._mp = None             # multipart handle, created at first seal
        self._error: Exception | None = None
        self._closing = False       # close_async() called; no more writes
        self._closed = False
        self._aborted = False
        self._pos = 0
        self._uid = next(_WRITER_IDS)
        # Unified resilience layer: one Retrier (full-jitter backoff,
        # shared across this writer's concurrent part uploads) and one
        # Hedger (max-hedges-in-flight cap) replace the old inline
        # `2 ** attempt` loop and its copy-pasted hedging.
        self._retrier = Retrier(
            policy.retry_policy(),
            on_retry=lambda attempt, exc, pause: self.stats.bump(retries=1),
            on_throttle=lambda: self.stats.bump(throttles=1),
        )
        self._hedger = Hedger(
            policy.hedge_timeout_s,
            max_in_flight=policy.max_hedges,
            on_hedge=lambda: self.stats.bump(hedges=1, put_requests=1),
        )

    # ------------------------------------------------------------------ #
    # file-object surface
    # ------------------------------------------------------------------ #
    @property
    def closed(self) -> bool:
        return self._closed

    def tell(self) -> int:
        return self._pos

    def write(self, data) -> int:
        """Accept bytes; returns immediately once the bytes are staged
        (upload happens behind the write barrier)."""
        if self._closed or self._closing:
            raise ValueError("write on closed Writer")
        self._raise_pending()
        data = bytes(data)
        self._buf += data
        self._pos += len(data)
        self.stats.bump(bytes_written=len(data))
        bs = self.policy.blocksize
        while len(self._buf) >= bs:
            part = bytes(self._buf[:bs])
            del self._buf[:bs]
            self._seal(part)
        return len(data)

    def flush(self) -> None:
        """Barrier: seal the current buffer (forcing multipart mode) and
        block until every sealed part is durably uploaded."""
        if self._closed or self._closing:
            raise ValueError("flush on closed Writer")
        if self._buf:
            part = bytes(self._buf)
            self._buf.clear()
            self._seal(part)
        self._barrier()

    def close_async(self) -> None:
        """Seal the remainder and enqueue the final publish on the upload
        pool; pair with :meth:`join`. Lets a producer closing many writers
        (checkpoint save) overlap their publishes instead of paying one
        store round-trip per writer serially."""
        if self._closed:
            raise ValueError("close_async on closed Writer")
        if self._closing:
            return
        self._closing = True
        if self._mp is None:
            # Everything fits one part: a single background put — the
            # same request shape as the legacy sync path.
            data = bytes(self._buf)
            self._buf.clear()
            with self._cond:
                self._sealed += 1
            self._pool.submit(lambda: self._upload_whole(data))
        else:
            if self._buf:
                part = bytes(self._buf)
                self._buf.clear()
                self._seal(part)
            # The finisher job runs multipart complete() once every part
            # job (all enqueued before it — FIFO) has finished, so it
            # never waits on work queued behind itself: no pool deadlock.
            with self._cond:
                self._sealed += 1
            self._pool.submit(self._finish_multipart)

    def join(self) -> None:
        """Block until the object published by :meth:`close_async` is
        durable; raises `StoreError` (and aborts) on permanent failure."""
        if not self._closing:
            raise ValueError("join() before close_async()")
        if self._closed:
            return
        try:
            self._barrier()
        except BaseException:
            self.abort()
            raise
        self._closed = True

    def close(self) -> None:
        """Flush and atomically publish the object. Raises `StoreError` if
        any part upload failed permanently (the object is then aborted and
        never becomes visible)."""
        if self._closed:
            return
        self.close_async()
        self.join()

    def abort(self) -> None:
        """Drop buffered and in-flight work; the object is never published
        (queued parts drain as no-ops and release their staging budget)."""
        with self._cond:
            self._aborted = True
            self._closed = True
            self._cond.notify_all()
        self._buf.clear()
        if self._mp is not None:
            with suppress(Exception):
                self._mp.abort()

    def __enter__(self) -> "Writer":
        return self

    def __exit__(self, exc_type, *exc) -> None:
        if exc_type is not None:
            self.abort()
        else:
            self.close()

    # ------------------------------------------------------------------ #
    # sealing + staging (application thread)
    # ------------------------------------------------------------------ #
    def _seal(self, data: bytes) -> None:
        # The multipart handshake is a store round-trip; doing it under
        # _cond stalled every upload worker's barrier bump behind the
        # first seal's network latency. Only the application thread
        # seals, so the lazy init cannot race itself, and workers read
        # _mp only from jobs queued after this publish.
        if self._mp is None:
            mp = self.store.start_multipart(self.key)
            with self._cond:
                self._mp = mp
        with self._cond:
            index = self._next_index
            self._next_index += 1
            self._sealed += 1
        part = None
        try:
            part = self._stage(index, data)
            self._pool.submit(lambda: self._upload(part))
        except BaseException:
            # Staging failed (tier I/O error) or the pool refused the job
            # (closed underneath us): no upload will ever bump `_done`
            # for this seal, so `_sealed` must be unwound or every later
            # barrier — flush(), close(), join() — wedges forever. A
            # part that did get staged also gives its tier budget back.
            with self._cond:
                self._sealed -= 1
                self._cond.notify_all()
            if part is not None and part.tier is not None:
                with suppress(Exception):
                    part.tier.delete(part.block_id)
                    part.tier.release(part.size)
            raise

    def _stage(self, index: int, data: bytes) -> _Part:
        """Park the sealed part in the first tier with budget; block (the
        paper's bounded-cache backpressure, pointed at the producer) until
        an upload frees space. Parts no tier could ever hold are carried
        in memory so the pipeline cannot deadlock."""
        block_id = f"wb/{self._uid:04d}/{self.key}/{index:06d}"
        t0 = time.perf_counter()
        try:
            if not self.tiers or all(len(data) > t.capacity for t in self.tiers):
                self.stats.bump(unstaged_parts=1)
                return _Part(index, len(data), None, None, data)
            while True:
                for cand in self.tiers:
                    if len(data) > cand.capacity:
                        continue
                    if cand.available() < len(data):
                        cand.verify_used()
                    reserved = cand.reserve(len(data))
                    if not reserved and self.index is not None:
                        # Tier full of retained cache blocks (keep_cached
                        # readers), not in-flight parts: evict unpinned
                        # ones, or the producer would wait forever on
                        # uploads that free nothing.
                        if self.index.evict_from(cand, len(data)) > 0:
                            reserved = cand.reserve(len(data))
                    if reserved:
                        try:
                            # durable=False: staged parts are transient — a
                            # persistent DirTier must not journal them (a
                            # crashed producer's staging is garbage-collected
                            # at recovery, never resurrected into the cache).
                            cand.write(block_id, data, durable=False)
                        except Exception:
                            # ENOSPC / torn tier write: hand the budget
                            # back or the tier's inflight accounting
                            # shrinks it forever (verify_used treats
                            # inflight bytes as legitimate).
                            cand.cancel(len(data))
                            raise
                        cand.commit(len(data))
                        digest = None
                        if self.policy.verify == "full":
                            from repro.io.integrity import block_digest
                            digest = block_digest(data)
                        return _Part(index, len(data), cand, block_id, None,
                                     digest)
                with self._cond:
                    if self._error is not None or self._aborted:
                        # Pipeline is failing anyway; skip backpressure so
                        # the caller reaches the error at the next barrier.
                        self.stats.bump(unstaged_parts=1)
                        return _Part(index, len(data), None, None, data)
                    self._cond.wait(timeout=0.01)
        finally:
            self.stats.bump(stage_wait_s=time.perf_counter() - t0)

    # ------------------------------------------------------------------ #
    # upload jobs (UploadPool threads)
    # ------------------------------------------------------------------ #
    def _upload(self, part: _Part) -> None:
        try:
            with self._cond:
                skip = self._aborted or self._error is not None
            data = part.data
            if part.tier is not None:
                try:
                    if not skip:   # skipped jobs only free their staging
                        data = part.tier.read(part.block_id, 0, part.size)
                        if part.digest is not None:
                            # verify="full": the sealed bytes' digest must
                            # survive the staging round-trip. A mismatch
                            # is NOT healable — the application's bytes
                            # exist nowhere else — so it fails the writer
                            # loudly at the next barrier rather than
                            # persisting corruption.
                            from repro.io.integrity import check_block
                            check_block(
                                data, part.digest,
                                what=f"staged part {part.block_id}",
                            )
                finally:
                    part.tier.delete(part.block_id)
                    part.tier.release(part.size)
            if not skip:
                t0 = time.perf_counter()
                self._execute_put(lambda: self._mp.put_part(part.index, data))
                self.stats.bump(
                    upload_s=time.perf_counter() - t0,
                    parts_uploaded=1,
                    bytes_uploaded=part.size,
                )
        except Exception as e:   # repro: allow[RP005] — surfaced at the barrier
            self._record_error(e)
        finally:
            with self._cond:
                self._done += 1
                self._cond.notify_all()

    def _finish_multipart(self) -> None:
        """Pool job: wait for every part job (all queued ahead of this
        one), then publish via multipart complete()."""
        try:
            with self._cond:
                self._cond.wait_for(lambda: self._done >= self._sealed - 1)
                skip = self._aborted or self._error is not None
            if not skip:
                t0 = time.perf_counter()
                self._execute_put(self._mp.complete)
                self.stats.bump(upload_s=time.perf_counter() - t0)
        except Exception as e:   # repro: allow[RP005] — surfaced at the barrier
            self._record_error(e)
        finally:
            with self._cond:
                self._done += 1
                self._cond.notify_all()

    def _upload_whole(self, data: bytes) -> None:
        try:
            with self._cond:
                skip = self._aborted
            if not skip:
                t0 = time.perf_counter()
                self._execute_put(lambda: self.store.put(self.key, data))
                self.stats.bump(
                    upload_s=time.perf_counter() - t0,
                    parts_uploaded=1,
                    bytes_uploaded=len(data),
                )
        except Exception as e:   # repro: allow[RP005] — surfaced at the barrier
            self._record_error(e)
        finally:
            with self._cond:
                self._done += 1
                self._cond.notify_all()

    def _execute_put(self, fn: Callable[[], None]) -> None:
        """Retries + optional hedging around one store request, resolved
        through the shared resilience layer (puts to the same key/part
        index are idempotent, so taking the first hedged copy that lands
        is safe)."""

        def attempt():
            self.stats.bump(put_requests=1)
            return self._hedger.call(fn)

        self._retrier.call(attempt, label=f"upload {self.key!r}")

    # ------------------------------------------------------------------ #
    # error + barrier plumbing
    # ------------------------------------------------------------------ #
    def _record_error(self, e: Exception) -> None:
        with self._cond:
            if self._error is None:
                self._error = e
            self._cond.notify_all()
        log.error("writer %s: upload failed: %s", self.key, e)

    def _raise_pending(self) -> None:
        with self._cond:
            err = self._error
        if err is not None:
            raise StoreError(
                f"write-behind upload failed for {self.key!r}"
            ) from err

    def _barrier(self) -> None:
        t0 = time.perf_counter()
        with self._cond:
            self._cond.wait_for(lambda: self._done >= self._sealed)
        self.stats.bump(barrier_wait_s=time.perf_counter() - t0)
        self._raise_pending()
