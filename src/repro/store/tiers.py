"""Bounded local cache tiers for Rolling Prefetch, plus the shared
crash-consistent cache index.

The paper writes prefetched blocks to a priority-ordered list of local
storage devices (tmpfs first, then disk), each with a user-set byte budget.
`used` accounting intentionally mirrors Algorithm 1: the prefetch thread
increments `used` optimistically, and reconciles with reality via
`verify_used()` when it believes a tier is full (evictions may have freed
space since the last check).

Two extensions turn the tiers from per-reader scratch space into a shared
cache subsystem (cf. the successor user-space HSM work, arXiv:2404.11556,
and the shared-cache analysis of arXiv:2108.06322):

  * `CacheIndex` — a refcounted residency map over a list of tiers with
    single-flight fetch registration: N readers of the same key trigger
    ONE store GET per block, a block pinned by any reader is never evicted
    out from under it, and unpinned blocks can stay resident (LRU-evicted
    only under capacity pressure) so a second epoch or a second reader
    starts warm.
  * persistent `DirTier` — every durable block write appends a journal
    record (block id, key, offset, length, checksum) next to the block
    files; a reconstructed tier replays the journal, drops torn/partial
    blocks by checksum, deletes orphans, and starts with its index (and
    `used` accounting) warm — a restarted job pays zero store GETs for
    blocks that survived the crash.
"""

from __future__ import annotations

import abc
import contextlib
import json
import os
import threading
import time
import zlib
from collections import OrderedDict
from dataclasses import dataclass
from urllib.parse import quote, unquote

from repro.store.base import IntegrityError, StoreError
from repro.store.link import LinkModel
from repro.utils import get_logger

try:
    import fcntl
except ImportError:   # non-POSIX: no advisory root locking
    fcntl = None

log = get_logger("store.tiers")


@dataclass(frozen=True)
class BlockMeta:
    """Provenance of a cached block, journaled by persistent tiers so a
    recovered cache can be audited against the store."""

    key: str
    offset: int


class CacheTier(abc.ABC):
    """A bounded block cache with simulated (or real) transfer costs."""

    #: True when full-block reads are verified by the tier itself (the
    #: DirTier's journal-crc check) — engines running ``verify="edges"``
    #: trust such tiers and skip re-hashing what the tier just hashed;
    #: ``verify="full"`` re-checks regardless.
    verifies_reads = False

    def __init__(
        self,
        capacity: int,
        read_link: LinkModel | None = None,
        write_link: LinkModel | None = None,
        name: str = "tier",
    ) -> None:
        self.capacity = capacity
        self.read_link = read_link if read_link is not None else LinkModel(name=f"{name}.r")
        self.write_link = write_link if write_link is not None else LinkModel(name=f"{name}.w")
        self.name = name
        # Position in a storage hierarchy (0 = fastest). A flat tier list
        # leaves it at 0; the HSM assigns levels and persistent tiers
        # journal it with each block (the tier-generation field), so a
        # recovered block is known to have lived at this level.
        self.level = 0
        self._used = 0       # optimistic accounting: committed + in-flight
        self._inflight = 0   # reserved but not yet written
        self._lock = threading.Lock()

    # -- Algorithm-1 accounting -------------------------------------------
    @property
    def used(self) -> int:
        with self._lock:
            return self._used

    def available(self) -> int:
        with self._lock:
            return self.capacity - self._used

    def reserve(self, nbytes: int) -> bool:
        """Optimistically claim space (prefetch thread)."""
        with self._lock:
            if self.capacity - self._used < nbytes:
                return False
            self._used += nbytes
            self._inflight += nbytes
            return True

    def commit(self, nbytes: int) -> None:
        """The reserved bytes are now resident (write completed)."""
        with self._lock:
            self._inflight = max(0, self._inflight - nbytes)

    def cancel(self, nbytes: int) -> None:
        """A reservation was abandoned (fetch failed permanently)."""
        with self._lock:
            self._inflight = max(0, self._inflight - nbytes)
            self._used = max(0, self._used - nbytes)

    def release(self, nbytes: int) -> None:
        """Committed bytes were evicted."""
        with self._lock:
            self._used = max(0, self._used - nbytes)

    def verify_used(self) -> int:
        """Reconcile `used` with the bytes actually resident plus in-flight
        reservations (evictions may have freed space since the last check).
        Returns available space after reconciliation. Mirrors the paper's
        `verify_used()` in Algorithm 1."""
        actual = self._resident_bytes()
        with self._lock:
            self._used = min(self._used, max(actual, 0) + self._inflight)
            return self.capacity - self._used

    # -- storage ops (charged to the tier's links) --------------------------
    def write(self, block_id: str, data: bytes, *,
              meta: BlockMeta | None = None, durable: bool = True) -> None:
        """Store a block. ``meta`` is journaled by persistent tiers;
        ``durable=False`` marks transient staging data (write-behind parts)
        that must NOT survive a restart and is invisible to
        :meth:`resident_blocks`.

        Overwriting an already-resident ``block_id`` credits the replaced
        bytes back to `used` under the accounting lock — a reserve+write of
        a block that was already there must not double-count its size until
        some later `verify_used()` happens to run.
        """
        self.write_link.transfer(len(data))
        prev = self._size_of(block_id)
        self._store_block(block_id, data, meta, durable)
        if prev > 0:
            with self._lock:
                self._used = max(0, self._used - prev)

    def read(self, block_id: str, start: int = 0, end: int | None = None) -> bytes:
        data = self._read(block_id, start, end)
        self.read_link.transfer(len(data))
        return data

    def delete(self, block_id: str) -> int:
        """Remove the block; returns bytes freed. Does NOT adjust `used`
        (that is the prefetcher's job via verify_used / explicit release),
        matching the paper's decoupled eviction."""
        return self._delete(block_id)

    def contains(self, block_id: str) -> bool:
        return self._contains(block_id)

    def resident_blocks(self) -> list[tuple[str, int]]:
        """(block_id, size) of every durable resident block — what a
        `CacheIndex` primes itself with at construction. Transient staging
        blocks (``durable=False`` writes) are excluded."""
        return []

    def close(self) -> None:
        """Release tier-held OS resources (persistent tiers hold an
        advisory root lock). Cached blocks stay on their medium."""

    # -- backend hooks ------------------------------------------------------
    def _store_block(self, block_id: str, data: bytes,
                     meta: BlockMeta | None, durable: bool) -> None:
        """Backend write entry point; the default delegates to the legacy
        `_write` hook so subclasses that only override `_write` keep
        working."""
        self._write(block_id, data)

    def _size_of(self, block_id: str) -> int:
        """Bytes currently resident under `block_id` (0 when absent)."""
        return 0

    @abc.abstractmethod
    def _write(self, block_id: str, data: bytes) -> None: ...

    @abc.abstractmethod
    def _read(self, block_id: str, start: int, end: int | None) -> bytes: ...

    @abc.abstractmethod
    def _delete(self, block_id: str) -> int: ...

    @abc.abstractmethod
    def _contains(self, block_id: str) -> bool: ...

    @abc.abstractmethod
    def _resident_bytes(self) -> int: ...


class MemTier(CacheTier):
    """Dict-backed tier modeling tmpfs (costs from the tier's LinkModel)."""

    def __init__(self, capacity: int, **kw) -> None:
        super().__init__(capacity, **kw)
        self._blocks: dict[str, bytes] = {}
        self._transient: set[str] = set()
        self._blk_lock = threading.Lock()

    def _store_block(self, block_id: str, data: bytes,
                     meta: BlockMeta | None, durable: bool) -> None:
        self._write(block_id, data)   # via the hook so subclasses see it
        with self._blk_lock:
            if durable:
                self._transient.discard(block_id)
            else:
                self._transient.add(block_id)

    def _write(self, block_id: str, data: bytes) -> None:
        with self._blk_lock:
            self._blocks[block_id] = bytes(data)

    def _read(self, block_id: str, start: int, end: int | None) -> bytes:
        with self._blk_lock:
            try:
                data = self._blocks[block_id]
            except KeyError:
                raise StoreError(f"{self.name}: block missing: {block_id}") from None
        return data[start:end if end is not None else len(data)]

    def _delete(self, block_id: str) -> int:
        with self._blk_lock:
            data = self._blocks.pop(block_id, None)
            self._transient.discard(block_id)
            return len(data) if data is not None else 0

    def _contains(self, block_id: str) -> bool:
        with self._blk_lock:
            return block_id in self._blocks

    def _size_of(self, block_id: str) -> int:
        with self._blk_lock:
            data = self._blocks.get(block_id)
            return len(data) if data is not None else 0

    def _resident_bytes(self) -> int:
        with self._blk_lock:
            return sum(len(v) for v in self._blocks.values())

    def resident_blocks(self) -> list[tuple[str, int]]:
        with self._blk_lock:
            return [(bid, len(data)) for bid, data in self._blocks.items()
                    if bid not in self._transient]


class DirTier(CacheTier):
    """Real-directory tier (an actual tmpfs mount or scratch disk), with a
    journaled on-disk index so the cache survives restarts.

    Layout under ``root``::

        _index.jsonl          append-only journal of put/del records
        blk-<quoted-id>       one file per block (atomic tmp+replace)

    Block filenames percent-escape the block id (``quote(id, safe="")``),
    which is injective — the old ``id.replace("/", "__")`` mapped distinct
    ids ``a/b`` and ``a__b`` onto the same file and silently served wrong
    bytes.

    Durable writes append ``{"op": "put", id, key, off, len, crc}`` after
    the block file is atomically in place; deletes append a tombstone.
    Construction replays the journal (a torn trailing record is ignored),
    drops entries whose file is missing or fails the length/crc check
    (torn blocks), deletes orphaned block/tmp files, compacts the journal,
    and seeds `used` with the recovered bytes — so a restarted job's
    `CacheIndex` starts warm and `verify_used()` is already consistent.
    """

    INDEX_NAME = "_index.jsonl"
    LOCK_NAME = ".lock"
    JOURNAL_LOCK_NAME = ".journal.lock"
    BLOCK_PREFIX = "blk-"
    _COMPACT_SLACK = 1024   # journal records beyond live entries before compact

    def __init__(self, capacity: int, root: str, *,
                 verify_reads: bool = True, faults=None, **kw) -> None:
        super().__init__(capacity, **kw)
        self.root = root
        # Steady-state integrity: recovery has always crc-checked blocks,
        # but a block that rots AFTER recovery used to be served as-is
        # for the life of the process. With ``verify_reads`` every
        # full-block read recomputes the journal crc and raises
        # `IntegrityError` on mismatch (partial reads are not coverable
        # by a whole-block crc and pass through). ``faults`` is an
        # optional chaos hook (`FaultSchedule`): a fired ``flip_at_rest``
        # rule mutates the resident block file before the read, so the
        # detection path is exercisable deterministically.
        self.verifies_reads = verify_reads
        self.faults = faults
        self.integrity_failures = 0
        os.makedirs(root, exist_ok=True)
        self._journal_path = os.path.join(root, self.INDEX_NAME)
        self._journal_lock = threading.Lock()
        self._journal_records = 0
        self._live: dict[str, int] = {}        # block_id -> size (durable)
        self._meta: dict[str, dict] = {}       # block_id -> journal record
        self._transient: set[str] = set()
        self.recovered_blocks = 0
        self.discarded_blocks = 0
        # Advisory exclusive lock on the root: only the owner runs the
        # DESTRUCTIVE parts of recovery (orphan sweep, torn-file removal,
        # journal compaction). A second tier over the same directory —
        # another replica sharing a node's cache dir — still recovers the
        # journal read-only and serves/writes blocks, but never deletes a
        # live sibling's files or rewrites its journal records.
        self._lock_file = None
        self._owner_marker: str | None = None
        self.owns_root = True
        if fcntl is not None:
            f = open(os.path.join(root, self.LOCK_NAME), "a+b")  # noqa: SIM115
            try:
                fcntl.flock(f.fileno(), fcntl.LOCK_EX | fcntl.LOCK_NB)
                self._lock_file = f
            except OSError:
                f.close()
                self.owns_root = False
                log.warning(
                    "%s: cache root %s is owned by another live tier; "
                    "recovery cleanup and journal compaction are disabled "
                    "in this instance", self.name, root,
                )
        else:
            # Non-POSIX fallback: no advisory flock, so ownership is an
            # O_EXCL marker file — strictly single-owner (first opener
            # wins; every later opener recovers read-only). Without this,
            # every opener believed it owned the root and two live tiers
            # would delete each other's blocks as "orphans". The marker
            # is removed on close(); a crash leaves it behind, making the
            # NEXT opener conservatively read-only (delete the marker by
            # hand to reclaim ownership) — safe, never destructive.
            marker = os.path.join(root, self.LOCK_NAME + ".owner")
            try:
                fd = os.open(marker, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                with contextlib.suppress(OSError):
                    os.write(fd, str(os.getpid()).encode("ascii"))
                os.close(fd)
                self._owner_marker = marker
            except FileExistsError:
                self.owns_root = False
                log.warning(
                    "%s: cache root %s has an owner marker (another live "
                    "tier, or a stale one from a crash); recovery cleanup "
                    "and journal compaction are disabled in this instance",
                    self.name, root,
                )
        self._recover()
        with self._lock:
            self._used = sum(self._live.values())

    def close(self) -> None:
        """Release the advisory root lock (blocks and journal stay on
        disk — that is the point). A later DirTier over the same root
        becomes the owner."""
        with self._journal_lock:
            if self._lock_file is not None:
                with contextlib.suppress(OSError):
                    fcntl.flock(self._lock_file.fileno(), fcntl.LOCK_UN)
                with contextlib.suppress(OSError):
                    self._lock_file.close()
                self._lock_file = None
            if self._owner_marker is not None:
                with contextlib.suppress(OSError):
                    os.remove(self._owner_marker)
                self._owner_marker = None

    # -- paths --------------------------------------------------------------
    def _path(self, block_id: str) -> str:
        # quote() is collision-free (every reserved byte, including "%"
        # itself, escapes to a unique %XX); the BLOCK_PREFIX keeps block
        # files disjoint from the journal.
        return os.path.join(self.root, self.BLOCK_PREFIX + quote(block_id, safe=""))

    def _id_from_filename(self, fn: str) -> str:
        return unquote(fn[len(self.BLOCK_PREFIX):])

    # -- journal ------------------------------------------------------------
    @contextlib.contextmanager
    def _journal_guard(self):
        """Cross-process serialization of journal appends/compaction for
        siblings sharing one root (a separate flock from the ownership
        lock, which the owner holds for its whole lifetime). In-process
        callers already hold `_journal_lock`; never nest this guard."""
        if fcntl is None:
            yield
            return
        with open(os.path.join(self.root, self.JOURNAL_LOCK_NAME), "a+b") as lf:
            fcntl.flock(lf.fileno(), fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lf.fileno(), fcntl.LOCK_UN)

    def _append_journal(self, rec: dict) -> None:
        """Caller holds `_journal_lock`."""
        with self._journal_guard():
            with open(self._journal_path, "a", encoding="utf-8") as f:
                f.write(json.dumps(rec, separators=(",", ":")) + "\n")
            self._journal_records += 1
            # Both owner AND non-owner compact: the rewrite replays the
            # file under the cross-process flock (sibling records survive
            # by construction), and without this a churning non-owner
            # would grow the journal unboundedly while the owner idles.
            if self._journal_records > len(self._live) + self._COMPACT_SLACK:
                self._compact_journal()

    def _replay_journal(self) -> dict[str, dict]:
        """Fold the journal file into its final per-id state (put records
        minus tombstones). A torn trailing record from a crash is
        ignored."""
        entries: dict[str, dict] = {}
        try:
            with open(self._journal_path, encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except ValueError:
                        continue   # torn trailing record from a crash
                    if rec.get("op") == "put" and "id" in rec:
                        entries[rec["id"]] = rec
                    elif rec.get("op") == "del" and "id" in rec:
                        entries.pop(rec["id"], None)
        except OSError:
            pass
        return entries

    def _compact_journal(self) -> None:
        """Rewrite the journal with only live entries. The rewrite replays
        the FILE (not just this instance's in-memory view, which is a
        subset of it) so records appended by a non-owner sibling sharing
        this root survive the compaction; the caller-held `_journal_guard`
        flock keeps a sibling from appending mid-rewrite. Caller holds
        `_journal_lock` AND `_journal_guard`."""
        entries = self._replay_journal()
        tmp = self._journal_path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            for rec in entries.values():
                f.write(json.dumps(rec, separators=(",", ":")) + "\n")
        os.replace(tmp, self._journal_path)
        self._journal_records = len(entries)

    def _recover(self) -> None:
        entries = self._replay_journal()
        live: dict[str, dict] = {}
        for bid, rec in entries.items():
            path = self._path(bid)
            try:
                with open(path, "rb") as f:
                    data = f.read()
            except OSError:
                self.discarded_blocks += 1
                continue
            if (len(data) != rec.get("len")
                    or (zlib.crc32(data) & 0xFFFFFFFF) != rec.get("crc")):
                # Torn/partial block: the journal promised different
                # bytes. Never trusted; the file itself is removed only
                # by the root owner (a non-owner may be racing a sibling
                # whose write is mid-flight).
                self.discarded_blocks += 1
                if self.owns_root:
                    with contextlib.suppress(OSError):
                        os.remove(path)
                continue
            live[bid] = rec
        self._live = {bid: rec["len"] for bid, rec in live.items()}
        self._meta = live
        self.recovered_blocks = len(live)
        if not self.owns_root:
            return
        # Orphan sweep + compaction (owner only), under the journal flock
        # with a FRESH replay: anything a live sibling journaled since
        # our first read is known, not an orphan, and survives the
        # rewrite. Orphans proper are tmp leftovers and block files no
        # journal record committed (including transient write-behind
        # staging from a crashed producer).
        with self._journal_lock, self._journal_guard():
            known = set(live) | set(self._replay_journal())
            try:
                for fn in os.listdir(self.root):
                    full = os.path.join(self.root, fn)
                    if fn.endswith(".tmp") or (
                            fn.startswith(self.BLOCK_PREFIX)
                            and self._id_from_filename(fn) not in known):
                        with contextlib.suppress(OSError):
                            os.remove(full)
            except OSError:
                pass
            self._compact_journal()

    # -- backend hooks ------------------------------------------------------
    def _store_block(self, block_id: str, data: bytes,
                     meta: BlockMeta | None, durable: bool) -> None:
        path = self._path(block_id)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
        # Journal record AFTER the block is atomically in place: a crash
        # between replace and append leaves an orphan file that recovery
        # deletes — never a journal entry pointing at missing bytes.
        with self._journal_lock:
            if durable:
                rec = {"op": "put", "id": block_id, "len": len(data),
                       "crc": zlib.crc32(data) & 0xFFFFFFFF,
                       "key": meta.key if meta is not None else None,
                       "off": meta.offset if meta is not None else None,
                       "lvl": self.level}
                self._meta[block_id] = rec
                self._live[block_id] = len(data)
                self._transient.discard(block_id)
                self._append_journal(rec)
            else:
                self._transient.add(block_id)
                self._live.pop(block_id, None)
                self._meta.pop(block_id, None)

    def _write(self, block_id: str, data: bytes) -> None:
        self._store_block(block_id, data, None, True)

    def _read(self, block_id: str, start: int, end: int | None) -> bytes:
        if self.faults is not None:
            self._maybe_rot(block_id)
        try:
            with open(self._path(block_id), "rb") as f:
                f.seek(start)
                data = f.read(None if end is None else end - start)
        except OSError:
            raise StoreError(f"{self.name}: block missing: {block_id}") from None
        if self.verifies_reads and start == 0:
            with self._journal_lock:
                rec = self._meta.get(block_id)
            # Only a read that covers the whole journaled block can be
            # checked against the whole-block crc.
            if (rec is not None and len(data) == rec.get("len")
                    and (zlib.crc32(data) & 0xFFFFFFFF) != rec.get("crc")):
                with self._journal_lock:
                    self.integrity_failures += 1
                raise IntegrityError(
                    f"{self.name}: journal crc mismatch for {block_id} "
                    f"(block rotted at rest)"
                )
        return data

    def _maybe_rot(self, block_id: str) -> None:
        """Chaos hook: when the schedule fires a ``flip_at_rest`` rule
        for this block, flip one byte of the resident file in place —
        at-rest bit rot between write and read."""
        rules = self.faults.decide("at_rest", block_id)
        if not any(getattr(r, "kind", None) == "flip_at_rest" for r in rules):
            return
        path = self._path(block_id)
        try:
            with open(path, "r+b") as f:
                f.seek(0, os.SEEK_END)
                size = f.tell()
                if size == 0:
                    return
                pos = size // 2
                f.seek(pos)
                byte = f.read(1)
                f.seek(pos)
                f.write(bytes([byte[0] ^ 0xFF]))
        except OSError:
            pass   # nothing resident to rot

    def _delete(self, block_id: str) -> int:
        path = self._path(block_id)
        try:
            size = os.path.getsize(path)
            os.remove(path)
        except OSError:
            return 0
        with self._journal_lock:
            if block_id in self._live:
                self._live.pop(block_id, None)
                self._meta.pop(block_id, None)
                try:
                    self._append_journal({"op": "del", "id": block_id})
                except OSError as e:
                    # Best-effort tombstone: delete() is called from
                    # eviction threads that must survive a full disk
                    # (ENOSPC is exactly when eviction runs). A lost
                    # tombstone is crash-safe — recovery finds a `put`
                    # whose file is gone and discards it.
                    log.warning("%s: journal tombstone failed for %s: %s",
                                self.name, block_id, e)
            self._transient.discard(block_id)
        return size

    def _contains(self, block_id: str) -> bool:
        return os.path.exists(self._path(block_id))

    def _size_of(self, block_id: str) -> int:
        try:
            return os.path.getsize(self._path(block_id))
        except OSError:
            return 0

    def _resident_bytes(self) -> int:
        total = 0
        try:
            for fn in os.listdir(self.root):
                if fn.startswith(self.BLOCK_PREFIX) and not fn.endswith(".tmp"):
                    total += os.path.getsize(os.path.join(self.root, fn))
        except OSError:
            pass
        return total

    def resident_blocks(self) -> list[tuple[str, int]]:
        with self._journal_lock:
            return list(self._live.items())

    def digest_of(self, block_id: str) -> str | None:
        """Canonical digest of a journaled block (``"crc32:%08x"``, the
        same value `repro.io.integrity.block_digest` mints), so a
        recovered cache primes the `CacheIndex` with verifiable entries
        and the peer server can attest recovered blocks it serves."""
        with self._journal_lock:
            rec = self._meta.get(block_id)
            if rec is None or rec.get("crc") is None:
                return None
            return f"crc32:{rec['crc'] & 0xFFFFFFFF:08x}"

    def journaled_level(self, block_id: str) -> int | None:
        """Tier-generation of a recovered block: the hierarchy level this
        tier occupied when the block was journaled (pre-``lvl`` journals
        return None). The HSM uses it to re-seed heat for blocks that
        lived at a hotter level before the restart."""
        with self._journal_lock:
            rec = self._meta.get(block_id)
            return rec.get("lvl") if rec is not None else None


@dataclass(frozen=True)
class TierPlacement:
    """Where a cached block lives."""

    tier: CacheTier
    block_id: str


# --------------------------------------------------------------------------- #
# shared cache index: refcounts + single-flight fetch registration
# --------------------------------------------------------------------------- #
class CacheFlight:
    """One in-progress fetch of a block, owned by exactly one leader.
    Readers that arrive while it is in flight register as waiters and are
    pinned automatically when the leader publishes.

    ``started_t`` (monotonic creation time) drives the index's stale-flight
    reclamation: a leader that dies without `publish`/`abort_fetch` leaves
    the flight registered forever, and every later reader of the block
    would wedge on it. Past ``CacheIndex.flight_ttl_s`` the index expires
    the flight (``reclaimed``), fails its waiters, and lets the next
    `acquire()` elect a new leader."""

    __slots__ = ("block_id", "done", "tier", "error", "waiters", "io_class",
                 "started_t", "reclaimed")

    def __init__(self, block_id: str, io_class: str = "default") -> None:
        self.block_id = block_id
        self.done = False
        self.tier: CacheTier | None = None
        self.error: Exception | None = None
        self.waiters = 0
        self.io_class = io_class
        self.started_t = time.monotonic()
        self.reclaimed = False


class _IndexEntry:
    __slots__ = ("tier", "size", "refs", "evict_requested", "io_class",
                 "digest")

    def __init__(self, tier: CacheTier, size: int, refs: int,
                 io_class: str = "default",
                 digest: str | None = None) -> None:
        self.tier = tier
        self.size = size
        self.refs = refs
        self.evict_requested = False
        self.io_class = io_class
        # Content digest minted at the block's first store fetch (None
        # for verify="off" producers): the reference every later tier
        # read, HSM move, and peer-served frame is checked against.
        self.digest = digest


class CacheIndex:
    """Shared residency map over a list of cache tiers.

    Three guarantees:

      * **single flight** — `acquire()` returns ``("leader", flight)`` to
        exactly one caller per missing block; everyone else gets
        ``("wait", flight)`` and `join()`s the leader's fetch, so N
        concurrent readers of the same object issue ~1x (not Nx) store
        GETs;
      * **refcounted eviction** — every ``("hit", ...)`` and every
        published block holds a pin; `unpin(want_evict=True)` deletes the
        block from its tier only when the LAST pin drops, so a block one
        reader is using is never evicted out from under another;
      * **warm reuse** — with ``keep_cached=True`` (or for blocks nobody
        asked to evict) unpinned blocks stay resident and are LRU-evicted
        by `evict_from()` only under capacity pressure; construction
        primes the map from each tier's `resident_blocks()`, so a
        persistent `DirTier` makes a restarted job start warm.

    Thread-safe; safe to call while holding an engine lock (the index
    never calls back into an engine).
    """

    #: Default stale-flight TTL (seconds). Generous: it only has to beat
    #: a *dead* leader, and live leaders finish or abort far sooner (the
    #: engines' own per-fetch retry deadlines are single-digit seconds).
    FLIGHT_TTL_S = 30.0

    def __init__(self, tiers: list[CacheTier], *, keep_cached: bool = False,
                 flight_ttl_s: float | None = FLIGHT_TTL_S) -> None:
        self.tiers = list(tiers)
        self.keep_cached = keep_cached
        self.flight_ttl_s = flight_ttl_s
        self._cond = threading.Condition()
        self._entries: dict[str, _IndexEntry] = {}
        self._flights: dict[str, CacheFlight] = {}
        self._evictable: OrderedDict[str, None] = OrderedDict()
        # Blocks whose tier files are being deleted right now (entry
        # already removed, file I/O in progress OUTSIDE the lock).
        # acquire() waits these out so a re-fetch can never be deleted by
        # a stale eviction racing its fresh write.
        self._deleting: set[str] = set()
        self.hits = 0            # acquires served from a resident block
        self.misses = 0          # acquires that became fetch leaders
        self.joins = 0           # acquires that joined another reader's fetch
        self.evictions = 0       # blocks actually deleted from a tier
        self.recovered = 0       # blocks primed from persistent tiers
        self.reclaims = 0        # stale flights expired (leader presumed dead)
        self.quarantined = 0     # blocks evicted+tombstoned on digest mismatch
        for tier in self.tiers:
            tier_digest = getattr(tier, "digest_of", None)
            for block_id, size in tier.resident_blocks():
                if block_id not in self._entries:
                    dg = tier_digest(block_id) if tier_digest else None
                    self._entries[block_id] = _IndexEntry(tier, size, refs=0,
                                                          digest=dg)
                    self._evictable[block_id] = None
                    self.recovered += 1

    def set_keep_cached(self, keep: bool) -> None:
        """Flip the retention policy (an open requesting warm reuse over
        an index created without it upgrades it for everyone sharing the
        tier list)."""
        with self._cond:
            self.keep_cached = keep

    # -- residency / single flight ------------------------------------------
    def acquire(self, block_id: str, io_class: str = "default"):
        """Returns ``("hit", tier)`` with a pin taken, ``("leader",
        flight)`` when the caller must fetch the block (finish with
        `publish` or `abort_fetch`), or ``("wait", flight)`` when another
        reader's fetch is in flight (finish with `join` or `leave`).

        ``io_class`` names the workload class (``IOPolicy.io_class``)
        making the access — ignored here, consumed by the HSM subclass
        for heat tracking and per-class admission."""
        with self._cond:
            while block_id in self._deleting:
                self._cond.wait(timeout=0.5)
            e = self._entries.get(block_id)
            if e is not None:
                e.refs += 1
                self._evictable.pop(block_id, None)
                self.hits += 1
                self._note_hit(block_id, e, io_class)
                return "hit", e.tier
            fl = self._flights.get(block_id)
            if fl is not None and not self._maybe_reclaim(fl):
                fl.waiters += 1
                self.joins += 1
                return "wait", fl
            fl = CacheFlight(block_id, io_class)
            self._flights[block_id] = fl
            self.misses += 1
            return "leader", fl

    def _maybe_reclaim(self, fl: CacheFlight) -> bool:
        """Expire a flight whose leader has been silent past the TTL
        (died without `publish`/`abort_fetch`). Its waiters observe a
        ``("failed", ...)`` join and re-acquire — the next acquire elects
        a new leader — so neither the engines nor the cross-host peer
        path can wedge on a dead leader. Caller holds `_cond`. Returns
        True when the flight was reclaimed (it is no longer registered)."""
        if (self.flight_ttl_s is None or fl.done
                or time.monotonic() - fl.started_t < self.flight_ttl_s):
            return False
        fl.reclaimed = True
        fl.done = True
        fl.error = StoreError(
            f"fetch of {fl.block_id} reclaimed after {self.flight_ttl_s:g}s "
            f"(leader presumed dead)"
        )
        if self._flights.get(fl.block_id) is fl:
            del self._flights[fl.block_id]
        self.reclaims += 1
        self._cond.notify_all()
        return True

    def publish(self, flight: CacheFlight, tier: CacheTier, size: int,
                digest: str | None = None) -> None:
        """Leader: the block is written to `tier`. The entry is pinned once
        for the leader plus once per registered waiter (each waiter's
        `join` returns an already-pinned hit).

        A slow-but-alive leader whose flight was already reclaimed does
        NOT register an entry — a new leader owns the block id now, and
        overwriting its entry would corrupt refcounts. Its bytes are in
        the tier regardless (same content-addressed id, same bytes), so
        the waiters observe the reclamation's "failed" join (never an
        unpinned hit), re-acquire, and find the new leader's entry; at
        worst the duplicate copy is reconciled by the next `verify_used()`
        walk."""
        with self._cond:
            if flight.reclaimed:
                flight.done = True
                self._cond.notify_all()
                return
            e = _IndexEntry(tier, size, refs=1 + flight.waiters,
                            io_class=flight.io_class, digest=digest)
            self._entries[flight.block_id] = e
            self._on_insert(flight.block_id, e)
            flight.done = True
            flight.tier = tier
            if self._flights.get(flight.block_id) is flight:
                del self._flights[flight.block_id]
            self._cond.notify_all()

    def abort_fetch(self, flight: CacheFlight, error: Exception | None = None) -> None:
        """Leader: the fetch failed or was abandoned; waiters observe the
        error (or a bare retry signal) and re-acquire. The identity check
        on the registry pop matters after a reclamation: a zombie leader's
        late abort must not unregister the NEW leader's flight."""
        with self._cond:
            flight.done = True
            flight.error = error
            if self._flights.get(flight.block_id) is flight:
                del self._flights[flight.block_id]
            self._cond.notify_all()

    def join(self, flight: CacheFlight, timeout: float | None = None):
        """Waiter: wait for the leader. ``("hit", tier)`` (pin already
        taken by `publish`), ``("failed", error)``, or ``("timeout",
        None)`` — keep join()ing or `leave()`. A join that times out past
        the flight TTL reclaims the stale flight itself (waiters must not
        depend on some future `acquire()` to notice the dead leader)."""
        with self._cond:
            self._cond.wait_for(lambda: flight.done, timeout)
            if not flight.done:
                if self._maybe_reclaim(flight):
                    return "failed", flight.error
                return "timeout", None
            if flight.tier is not None:
                return "hit", flight.tier
            return "failed", flight.error

    def leave(self, flight: CacheFlight) -> None:
        """Waiter: stop waiting on a flight. If the leader already
        published (pinning on our behalf), the pin is released."""
        release = None
        with self._cond:
            if not flight.done:
                flight.waiters -= 1
            elif flight.tier is not None:
                release = flight.block_id
        if release is not None:
            self.unpin(release)

    def invalidate(self, block_id: str) -> None:
        """Drop a stale entry whose tier file vanished beneath it (a
        sibling process sharing a persistent cache dir evicted it).
        Readers still holding pins unpin harmlessly (no-op); the next
        acquire becomes a leader and re-fetches into the cache instead of
        paying a direct store GET on every read forever."""
        with self._cond:
            e = self._entries.pop(block_id, None)
            self._evictable.pop(block_id, None)
        if e is not None:
            # Converge the tier's byte accounting now rather than waiting
            # for the next verify_used() walk.
            e.tier.release(e.size)

    def digest_of(self, block_id: str) -> str | None:
        """Content digest carried by a resident block's entry (None when
        absent or minted by a verify="off" producer)."""
        with self._cond:
            e = self._entries.get(block_id)
            return e.digest if e is not None else None

    def quarantine(self, block_id: str) -> bool:
        """A reader caught the resident copy lying (digest mismatch):
        evict it NOW and tombstone the entry, regardless of pins — every
        pinned reader would read the same corrupt bytes, and their
        subsequent unpins are harmless no-ops (same contract as
        `invalidate`). Unlike `invalidate` (file already gone) the tier
        file is deleted here, so a persistent tier cannot re-prime the
        corrupt block after a restart. Returns True when an entry was
        actually removed."""
        with self._cond:
            e = self._entries.pop(block_id, None)
            if e is None:
                return False
            self._evictable.pop(block_id, None)
            self._deleting.add(block_id)
            self.quarantined += 1
        try:
            self._delete_from_tier(e.tier, block_id, e.size)
        finally:
            with self._cond:
                self._deleting.discard(block_id)
                self._cond.notify_all()
        return True

    # -- refcounted eviction -------------------------------------------------
    def unpin(self, block_id: str, *, want_evict: bool = False) -> bool:
        """Release one pin. With ``want_evict`` the caller asks for the
        block to be deleted (the rolling engine's consumed-block eviction);
        the delete happens only when the last pin drops, and not at all
        under ``keep_cached`` (capacity pressure evicts instead). Returns
        True when the block was actually deleted."""
        with self._cond:
            e = self._entries.get(block_id)
            if e is None:
                return False
            e.refs = max(0, e.refs - 1)
            if want_evict:
                e.evict_requested = True
            if e.refs > 0:
                return False
            if self.keep_cached or not e.evict_requested:
                # Stays resident, LRU-evictable under pressure.
                self._note_evictable(block_id, e)
                return False
            del self._entries[block_id]
            self._evictable.pop(block_id, None)
            self._deleting.add(block_id)
        # File I/O (delete + a persistent tier's journal tombstone) runs
        # OUTSIDE the global lock; the `_deleting` tombstone makes a
        # concurrent acquire() of the same id wait instead of racing its
        # fresh re-write against this delete.
        try:
            self._delete_from_tier(e.tier, block_id, e.size)
        finally:
            with self._cond:
                self._deleting.discard(block_id)
                self.evictions += 1
                self._cond.notify_all()
        return True

    # -- subclass hooks (no-ops in the flat index) ---------------------------
    def _note_hit(self, block_id: str, e: _IndexEntry, io_class: str) -> None:
        """A resident block was pinned. Caller holds `_cond`."""

    def _on_insert(self, block_id: str, e: _IndexEntry) -> None:
        """A fetched block was published. Caller holds `_cond`."""

    def _note_evictable(self, block_id: str, e: _IndexEntry) -> None:
        """The last pin dropped and the block stays resident: record it as
        an eviction candidate. The flat index is a plain LRU (most
        recently unpinned last); the HSM places scan-resistant classes at
        the FRONT so a bulk sweep evicts its own blocks first. Caller
        holds `_cond`."""
        self._evictable[block_id] = None
        self._evictable.move_to_end(block_id)

    def evict_from(self, tier: CacheTier, nbytes: int,
                   requester: str | None = None) -> int:
        """Capacity pressure: delete least-recently-unpinned blocks from
        `tier` until at least `nbytes` are freed (or nothing unpinned is
        left). Pinned blocks are untouchable. Returns bytes freed.
        ``requester`` names the workload class applying the pressure —
        ignored here, consumed by the HSM subclass (demote-not-evict,
        protected classes)."""
        freed = 0
        with self._cond:
            victims = []
            for bid in list(self._evictable):
                e = self._entries.get(bid)
                if e is None or e.tier is not tier:
                    continue
                victims.append((bid, e))
                freed += e.size
                if freed >= nbytes:
                    break
            for bid, e in victims:
                del self._entries[bid]
                self._evictable.pop(bid, None)
                self._deleting.add(bid)
        if not victims:
            return 0
        try:
            for bid, e in victims:
                self._delete_from_tier(e.tier, bid, e.size)
        finally:
            with self._cond:
                for bid, _ in victims:
                    self._deleting.discard(bid)
                self.evictions += len(victims)
                self._cond.notify_all()
        return freed

    @staticmethod
    def _delete_from_tier(tier: CacheTier, block_id: str, size: int) -> None:
        if tier.contains(block_id):
            tier.delete(block_id)
            tier.release(size)

    # -- placement -------------------------------------------------------------
    def reserve_space(self, nbytes: int,
                      io_class: str = "default") -> CacheTier | None:
        """Priority-ordered tier walk shared by every engine: reconcile
        (`verify_used`) when a tier looks full, reserve, and LRU-evict
        unpinned index blocks under capacity pressure before giving up on
        a tier (Algorithm 1 + shared-cache pressure eviction). Returns the
        tier holding the reservation, or None when every tier is full of
        pinned/in-flight bytes. ``io_class`` is ignored here; the HSM
        subclass applies per-class admission (entry level, cost-ordered
        candidates)."""
        for cand in self.tiers:
            if cand.available() < nbytes:
                cand.verify_used()
            if cand.reserve(nbytes):
                return cand
            if (self.evict_from(cand, nbytes, requester=io_class) > 0
                    and cand.reserve(nbytes)):
                return cand
        return None

    # -- introspection --------------------------------------------------------
    def contains(self, block_id: str) -> bool:
        with self._cond:
            return block_id in self._entries

    def resident_count(self) -> int:
        with self._cond:
            return len(self._entries)

    def resident_bytes(self) -> int:
        with self._cond:
            return sum(e.size for e in self._entries.values())

    def snapshot(self) -> dict:
        with self._cond:
            return dict(
                hits=self.hits,
                misses=self.misses,
                joins=self.joins,
                evictions=self.evictions,
                recovered=self.recovered,
                reclaims=self.reclaims,
                quarantined=self.quarantined,
                resident_blocks=len(self._entries),
                resident_bytes=sum(e.size for e in self._entries.values()),
                inflight=len(self._flights),
                keep_cached=self.keep_cached,
            )
