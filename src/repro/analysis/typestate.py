"""Path-sensitive typestate pass (rules RP009+) over the protocol specs.

A small abstract interpreter walks each function body and forks the
environment at every branch, loop, and exception edge, tracking the
protocol resources created along the way (`repro.analysis.protocols`).
A path that leaves a resource in a non-final state — a leader flight
never published, a reservation never committed, a multipart upload
never completed — is reported at the *creation* site, so the allow
comment (when one is justified) sits on the line that took the
obligation.

What the interpreter models, and how it stays honest:

* tuple-unpack creators (``kind, val = index.acquire(bid)``) bind a
  discriminator; ``if kind == "leader"`` / ``assert kind == "hit"`` /
  ``if tier is None`` refine the per-path state set, and an empty set
  kills the path as infeasible;
* every call can raise: each call-bearing statement forks an exception
  edge that threads through enclosing try/except/finally (checked in
  src only — a test dying mid-protocol already fails loudly);
* escapes under-approximate: a resource that is returned, yielded,
  stored into an attribute/container, captured by a nested function, or
  passed to a call the spec does not recognize transfers its obligation
  and is not reported;
* loops run one abstract iteration; resources created *inside* a loop
  body escape on the back edge (a later iteration may discharge them),
  while resources from before the loop keep their state;
* a per-function path budget bails out silently when branching
  explodes — under-approximate, never guess.

Immediate violations (double ``unpin``, read-after-unpin) are anchored
at the offending call instead.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

from repro.analysis.core import Finding, FuncInfo, Module, Project
from repro.analysis.protocols import PROTOCOLS, Creator, ProtocolSpec
from repro.analysis.registry import register_rule

__all__ = ["run_typestate", "TYPESTATE_RULES"]

#: Per-function cap on concurrently-tracked environments. Past this the
#: function is skipped (no findings) — under-approximation by design.
_PATH_BUDGET = 4096

_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                ast.ClassDef)

# Outcome kinds.
_FALL, _RETURN, _RAISE, _BREAK, _CONTINUE = range(5)


class _Bailout(Exception):
    """Path budget exceeded — abandon the function without findings."""


# ---------------------------------------------------------------------------
# Resources and environments.
# ---------------------------------------------------------------------------

@dataclass
class _Resource:
    rid: int
    spec: ProtocolSpec
    creator: Creator
    node: ast.AST                      # creation call (finding anchor)
    #: Name identifiers inside the key expression (``b.block_id`` ->
    #: {"b"}): passing any of them onward escapes the resource.
    base_names: frozenset[str] = frozenset()
    #: discriminator value -> initial atom; "__true__"/"__some__" style
    #: pseudo-values for bool/None refinement.
    dmap: dict[str, str] = field(default_factory=dict)
    truthy_key: str | None = None
    falsy_key: str | None = None


class _Env:
    """One path's knowledge. Copied on fork; tiny dicts in practice."""

    __slots__ = ("states", "handles", "dvals", "escaped")

    def __init__(self) -> None:
        self.states: dict[int, frozenset[str]] = {}
        self.handles: dict[str, int] = {}      # "v:name" / "t:text" -> rid
        self.dvals: dict[str, tuple[int, frozenset[str]]] = {}
        self.escaped: set[int] = set()

    def copy(self) -> "_Env":
        e = _Env.__new__(_Env)
        e.states = dict(self.states)
        e.handles = dict(self.handles)
        e.dvals = dict(self.dvals)
        e.escaped = set(self.escaped)
        return e

    def key(self):
        return (
            frozenset(self.states.items()),
            frozenset(self.handles.items()),
            frozenset(self.dvals.items()),
            frozenset(self.escaped),
        )

    def unbind_var(self, name: str) -> None:
        self.handles.pop("v:" + name, None)
        self.dvals.pop(name, None)

    def rid_of_expr(self, expr: ast.AST) -> int | None:
        if isinstance(expr, ast.Name):
            rid = self.handles.get("v:" + expr.id)
            if rid is not None:
                return rid
        return self.handles.get("t:" + ast.unparse(expr))


def _dedupe(envs: list[_Env]) -> list[_Env]:
    seen, out = set(), []
    for e in envs:
        k = e.key()
        if k not in seen:
            seen.add(k)
            out.append(e)
    return out


# ---------------------------------------------------------------------------
# Per-function analysis context.
# ---------------------------------------------------------------------------

class _Fn:
    def __init__(self, module: Module, project: Project, fi: FuncInfo) -> None:
        self.module = module
        self.project = project
        self.fi = fi
        self.name = fi.node.name
        self.resources: dict[int, _Resource] = {}
        self._next_rid = 0
        self.budget = _PATH_BUDGET
        #: dedupe key -> Finding
        self.violations: dict[tuple, Finding] = {}

    def new_resource(self, spec: ProtocolSpec, creator: Creator,
                     node: ast.AST, **kw) -> _Resource:
        res = _Resource(rid=self._next_rid, spec=spec, creator=creator,
                        node=node, **kw)
        self._next_rid += 1
        self.resources[res.rid] = res
        return res

    def charge(self, n: int = 1) -> None:
        self.budget -= n
        if self.budget < 0:
            raise _Bailout()

    # -- reporting ----------------------------------------------------------
    def report_exit(self, res: _Resource, atom: str) -> None:
        rule, msg = res.spec.exit_rules.get(atom, (None, None))
        if rule is None:
            return
        key = (rule, getattr(res.node, "lineno", 0), atom)
        if key in self.violations:
            return
        line = getattr(res.node, "lineno", 0)
        self.violations[key] = self.module.finding(
            rule, res.node,
            msg.format(line=line, resource=res.spec.resource,
                       state=atom),
        )

    def report_immediate(self, rule: str, node: ast.AST, msg: str) -> None:
        key = (rule, getattr(node, "lineno", 0), msg)
        if key in self.violations:
            return
        self.violations[key] = self.module.finding(rule, node, msg)


# ---------------------------------------------------------------------------
# Creator matching.
# ---------------------------------------------------------------------------

def _terminal_name(expr: ast.AST) -> str | None:
    if isinstance(expr, ast.Name):
        return expr.id
    if isinstance(expr, ast.Attribute):
        return expr.attr
    return None


def _receiver_ok(fn: _Fn, recv: ast.AST, cr: Creator) -> bool:
    if not (cr.receiver_types or cr.receiver_hints or cr.receiver_suffixes):
        return True
    t = fn.project.receiver_type(fn.fi, recv)
    if t and any(fn.project.is_subclass_of(t, base)
                 for base in cr.receiver_types):
        return True
    term = _terminal_name(recv)
    if term is None:
        return False
    low = term.lower()
    if low in cr.receiver_hints:
        return True
    return any(low.endswith(suf) for suf in cr.receiver_suffixes)


def _creator_match(fn: _Fn,
                   call: ast.Call) -> tuple[ProtocolSpec, Creator] | None:
    func = call.func
    for spec in PROTOCOLS:
        for cr in spec.creators:
            if cr.kind == "method":
                if not isinstance(func, ast.Attribute) \
                        or func.attr != cr.method:
                    continue
                if any(s in fn.name for s in cr.skip_in_functions):
                    continue
                recv = func.value
                if isinstance(recv, ast.Name) and recv.id == "self" \
                        and not cr.allow_self_receiver:
                    continue
                if _receiver_ok(fn, recv, cr):
                    return spec, cr
            else:
                name = None
                if isinstance(func, ast.Name):
                    name = func.id
                elif isinstance(func, ast.Attribute):
                    name = func.attr
                if name in cr.class_names:
                    return spec, cr
    return None


# ---------------------------------------------------------------------------
# Call scanning: events, immediate violations, escapes.
# ---------------------------------------------------------------------------

#: Calls that cannot realistically raise and so do not fork an
#: exception edge (keeps raise-path findings anchored to real risks).
_NO_RAISE_BUILTINS = frozenset({
    "len", "min", "max", "isinstance", "id", "abs", "bool", "range",
    "enumerate", "zip", "repr", "hasattr",
})
_NO_RAISE_MODULES = frozenset({"time", "math"})


def _call_may_raise(call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Name) and f.id in _NO_RAISE_BUILTINS:
        return False
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
            and f.value.id in _NO_RAISE_MODULES:
        return False
    return True


def _shallow_calls(node: ast.AST):
    if isinstance(node, ast.Call):
        yield node
    for child in ast.iter_child_nodes(node):
        if isinstance(child, _SCOPE_NODES):
            continue
        yield from _shallow_calls(child)


def _names_in(node: ast.AST):
    """Every Name load in `node`, INCLUDING nested scopes (closure
    capture escapes the resource)."""
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            yield n.id


def _apply_event(fn: _Fn, env: _Env, spec: ProtocolSpec, rid: int,
                 method: str, call: ast.Call) -> None:
    res = fn.resources[rid]
    atoms = env.states.get(rid)
    if atoms is None:
        return
    ev_map = spec.events.get(method, {})
    imm = spec.immediate.get(method, {})
    new: set[str] = set()
    for atom in atoms:
        if atom in imm:
            from repro.analysis.protocols import _immediate_rule_id
            fn.report_immediate(_immediate_rule_id(spec), call, imm[atom])
            new.add(atom)
        elif atom in ev_map:
            new.add(ev_map[atom])
        else:
            new.add(atom)
    env.states[rid] = frozenset(new)
    # A leader publish pins the block on the publisher's behalf: spawn
    # the pin so a following double-unpin is caught.
    if spec.name == "cache-acquire" and method == "publish" \
            and "done" in new:
        _spawn_publish_pin(fn, env, res, call)


def _spawn_publish_pin(fn: _Fn, env: _Env, flight: _Resource,
                       call: ast.Call) -> None:
    key = None
    for hkey, hrid in list(env.handles.items()):
        if hrid == flight.rid and hkey.startswith("t:"):
            key = hkey
            break
    if key is None:
        return
    pin = fn.new_resource(flight.spec, flight.creator, call,
                          base_names=flight.base_names)
    env.states[pin.rid] = frozenset({"pinned"})
    env.handles[key] = pin.rid


def _refinement_names(env: _Env, test: ast.AST) -> set[int]:
    """id()s of bare discriminator/handle Name mentions inside a branch
    test — ``if tier is None``, ``if kind == "leader"``, ``assert ok`` —
    which refine the path rather than consume the resource, so they must
    not count as escapes. A Name inside a Call subtree still escapes:
    passing the handle onward transfers the obligation."""
    in_calls: set[int] = set()
    for n in ast.walk(test):
        if isinstance(n, ast.Call):
            for sub in ast.walk(n):
                in_calls.add(id(sub))
    out: set[int] = set()
    for n in ast.walk(test):
        if isinstance(n, ast.Name) and id(n) not in in_calls \
                and n.id in env.dvals:
            out.add(id(n))
    return out


def _scan_calls(fn: _Fn, env: _Env, node: ast.AST,
                skip: ast.Call | None = None,
                extra_excluded: set[int] | None = None) -> bool:
    """Apply events/uses and escape resource references for every call
    lexically inside `node`. Returns True if any call may raise."""
    may_raise = False
    consumed: set[int] = set()      # id() of arg nodes consumed by events
    func_nodes: list[ast.AST] = []
    for call in _shallow_calls(node):
        if call is skip:
            func_nodes.append(call.func)
            continue
        if _call_may_raise(call):
            may_raise = True
        func_nodes.append(call.func)
        method = call.func.attr if isinstance(call.func, ast.Attribute) \
            else None
        if method is None:
            continue
        for spec in PROTOCOLS:
            is_event = method in spec.events or method in spec.immediate
            is_use = method in spec.uses
            if not (is_event or is_use):
                continue
            if is_event:
                if spec.event_match == "arg0":
                    if not call.args:
                        continue
                    target: ast.AST = call.args[0]
                else:
                    target = call.func.value
                rid = env.rid_of_expr(target)
                if rid is None or fn.resources[rid].spec is not spec:
                    continue
                _apply_event(fn, env, spec, rid, method, call)
                consumed.add(id(target))
            if is_use:
                rid = env.rid_of_expr(call.func.value)
                if rid is not None and fn.resources[rid].spec is spec:
                    atoms = env.states.get(rid, frozenset())
                    for atom in atoms:
                        if atom in spec.immediate_use:
                            from repro.analysis.protocols import \
                                _immediate_rule_id
                            fn.report_immediate(
                                _immediate_rule_id(spec), call,
                                spec.immediate_use[atom])
    # Escapes: resource names appearing anywhere in `node` other than as
    # a call target (func chain), an event-consumed argument, or a
    # caller-supplied refinement mention.
    excluded: set[int] = consumed
    if extra_excluded:
        excluded |= extra_excluded
    for f in func_nodes:
        for n in ast.walk(f):
            excluded.add(id(n))
    _escape_names(fn, env, node, excluded)
    return may_raise


def _escape_names(fn: _Fn, env: _Env, node: ast.AST,
                  excluded: set[int] | None = None) -> None:
    excluded = excluded or set()
    skip_subtrees: set[int] = set()
    for n in ast.walk(node):
        if id(n) in excluded:
            for sub in ast.walk(n):
                skip_subtrees.add(id(sub))
    names: set[str] = set()
    for n in ast.walk(node):
        if id(n) in skip_subtrees:
            continue
        if isinstance(n, ast.Name):
            names.add(n.id)
    for name in names:
        rid = env.handles.get("v:" + name)
        if rid is not None:
            env.escaped.add(rid)
    for rid, res in fn.resources.items():
        if rid in env.escaped or rid not in env.states:
            continue
        if res.base_names & names:
            env.escaped.add(rid)


# ---------------------------------------------------------------------------
# Refinement.
# ---------------------------------------------------------------------------

def _restrict(env: _Env, res: _Resource, allowed: frozenset[str]) -> bool:
    """Narrow a discriminated resource to `allowed` discriminator
    values. Returns False if the path becomes infeasible."""
    rid = res.rid
    initial_atoms = set(res.dmap.values())
    allowed_atoms = {res.dmap[v] for v in allowed if v in res.dmap}
    atoms = env.states.get(rid)
    if atoms is None:
        return True
    new = frozenset(a for a in atoms
                    if a not in initial_atoms or a in allowed_atoms)
    if not new:
        return False
    env.states[rid] = new
    return True


def _refine(fn: _Fn, env: _Env, test: ast.AST, branch: bool) -> bool:
    """Refine `env` assuming `test` evaluated to `branch`. Returns False
    when the path is infeasible."""
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _refine(fn, env, test.operand, not branch)
    if isinstance(test, ast.BoolOp):
        if isinstance(test.op, ast.And) and branch:
            return all(_refine(fn, env, v, True) for v in test.values)
        if isinstance(test.op, ast.Or) and not branch:
            return all(_refine(fn, env, v, False) for v in test.values)
        return True
    if isinstance(test, ast.Name):
        entry = env.dvals.get(test.id)
        if entry is None:
            return True
        rid, vals = entry
        res = fn.resources[rid]
        key = res.truthy_key if branch else res.falsy_key
        if key is None:
            return True
        new_vals = vals & {key}
        if not new_vals:
            return False
        env.dvals[test.id] = (rid, new_vals)
        return _restrict(env, res, new_vals)
    if isinstance(test, ast.Compare) and len(test.ops) == 1:
        op = test.ops[0]
        left, right = test.left, test.comparators[0]
        # `x is None` / `x is not None` on a value-bound handle.
        if isinstance(op, (ast.Is, ast.IsNot)):
            none_side = None
            var_side = None
            for a, b in ((left, right), (right, left)):
                if isinstance(a, ast.Constant) and a.value is None \
                        and isinstance(b, ast.Name):
                    none_side, var_side = a, b
            if var_side is not None:
                entry = env.dvals.get(var_side.id)
                if entry is None:
                    return True
                rid, vals = entry
                res = fn.resources[rid]
                if res.falsy_key is None:
                    return True
                is_none = isinstance(op, ast.Is) == branch
                key = res.falsy_key if is_none else res.truthy_key
                new_vals = vals & {key}
                if not new_vals:
                    return False
                env.dvals[var_side.id] = (rid, new_vals)
                return _restrict(env, res, new_vals)
            return True
        # `kind == "leader"` / `kind != "hit"` on a discriminator.
        if isinstance(op, (ast.Eq, ast.NotEq)):
            var = None
            const = None
            for a, b in ((left, right), (right, left)):
                if isinstance(a, ast.Name) and isinstance(b, ast.Constant) \
                        and isinstance(b.value, str):
                    var, const = a, b.value
            if var is None:
                return True
            entry = env.dvals.get(var.id)
            if entry is None:
                return True
            rid, vals = entry
            res = fn.resources[rid]
            if const not in res.dmap:
                return True
            equal = isinstance(op, ast.Eq) == branch
            new_vals = (vals & {const}) if equal else (vals - {const})
            if not new_vals:
                return False
            env.dvals[var.id] = (rid, new_vals)
            return _restrict(env, res, new_vals)
    return True


# ---------------------------------------------------------------------------
# Creation binding.
# ---------------------------------------------------------------------------

def _bind_creator(fn: _Fn, env: _Env, spec: ProtocolSpec, cr: Creator,
                  call: ast.Call, targets: list[ast.expr]) -> bool:
    """Bind a creator call's result. Returns True if a resource was
    actually created (unsupported target shapes create nothing)."""
    if cr.binds == "tuple2":
        if len(targets) != 1 or not isinstance(targets[0], ast.Tuple) \
                or len(targets[0].elts) != 2:
            return False
        kt, vt = targets[0].elts
        if not (isinstance(kt, ast.Name) and isinstance(vt, ast.Name)):
            return False
        arg_text = ast.unparse(call.args[0]) if call.args else None
        base = frozenset(n for n in _names_in(call.args[0])) \
            if call.args else frozenset()
        res = fn.new_resource(
            spec, cr, call, base_names=base,
            dmap=dict(spec.discriminants))
        env.unbind_var(kt.id)
        env.unbind_var(vt.id)
        env.states[res.rid] = frozenset(spec.discriminants.values())
        env.handles["v:" + vt.id] = res.rid
        if arg_text is not None:
            env.handles["t:" + arg_text] = res.rid
        env.dvals[kt.id] = (res.rid, frozenset(spec.discriminants))
        return True
    if cr.binds == "value":
        if len(targets) != 1 or not isinstance(targets[0], ast.Name):
            return False
        name = targets[0].id
        nullable = bool(spec.initial_none)
        dmap = {"__some__": spec.initial}
        if nullable:
            dmap["__none__"] = spec.initial_none
        res = fn.new_resource(spec, cr, call, dmap=dmap,
                              truthy_key="__some__",
                              falsy_key="__none__" if nullable else None)
        env.unbind_var(name)
        env.states[res.rid] = frozenset(dmap.values())
        env.handles["v:" + name] = res.rid
        env.dvals[name] = (res.rid, frozenset(dmap))
        return True
    if cr.binds == "bool":
        recv_text = ast.unparse(call.func.value)
        base = frozenset(_names_in(call.func.value))
        dmap = {"__true__": spec.initial, "__false__": spec.initial_none}
        res = fn.new_resource(spec, cr, call, base_names=base, dmap=dmap,
                              truthy_key="__true__", falsy_key="__false__")
        env.states[res.rid] = frozenset(dmap.values())
        env.handles["t:" + recv_text] = res.rid
        if len(targets) == 1 and isinstance(targets[0], ast.Name):
            name = targets[0].id
            env.unbind_var(name)
            env.dvals[name] = (res.rid, frozenset(dmap))
        return True
    return False


def _bool_creator_in_test(fn: _Fn, test: ast.AST) \
        -> tuple[ProtocolSpec, Creator, ast.Call] | None:
    """`if cand.reserve(n):` — a bool-binding creator used directly as
    the branch condition."""
    if not isinstance(test, ast.Call):
        return None
    m = _creator_match(fn, test)
    if m is None or m[1].binds != "bool":
        return None
    return m[0], m[1], test


# ---------------------------------------------------------------------------
# The interpreter.
# ---------------------------------------------------------------------------

def _exec_block(fn: _Fn, stmts: list[ast.stmt],
                env: _Env) -> list[tuple[int, _Env]]:
    outs: list[tuple[int, _Env]] = []
    cur = [env]
    for stmt in stmts:
        nxt: list[_Env] = []
        for e in cur:
            fn.charge()
            for kind, e2 in _exec_stmt(fn, stmt, e):
                if kind == _FALL:
                    nxt.append(e2)
                else:
                    outs.append((kind, e2))
        cur = _dedupe(nxt)
        if not cur:
            break
    outs.extend((_FALL, e) for e in cur)
    return outs


def _exec_stmt(fn: _Fn, stmt: ast.stmt,
               env: _Env) -> list[tuple[int, _Env]]:
    if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
        return _exec_assign(fn, stmt, env)
    if isinstance(stmt, ast.If):
        return _exec_if(fn, stmt, env)
    if isinstance(stmt, (ast.While, ast.For, ast.AsyncFor)):
        return _exec_loop(fn, stmt, env)
    if isinstance(stmt, (ast.Try, getattr(ast, "TryStar", ast.Try))):
        return _exec_try(fn, stmt, env)
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return _exec_with(fn, stmt, env)
    if isinstance(stmt, ast.Return):
        out: list[tuple[int, _Env]] = []
        if stmt.value is not None:
            if _scan_calls(fn, env, stmt.value):
                out.append((_RAISE, env.copy()))
            _escape_names(fn, env, stmt.value, _func_chains(stmt.value))
        out.append((_RETURN, env))
        return out
    if isinstance(stmt, ast.Raise):
        out = []
        for part in (stmt.exc, stmt.cause):
            if part is not None:
                _scan_calls(fn, env, part)
                _escape_names(fn, env, part, _func_chains(part))
        out.append((_RAISE, env))
        return out
    if isinstance(stmt, ast.Expr):
        may_raise = _scan_calls(fn, env, stmt.value)
        out = []
        if may_raise:
            out.append((_RAISE, env.copy()))
        out.append((_FALL, env))
        return out
    if isinstance(stmt, ast.Assert):
        _scan_calls(fn, env, stmt.test,
                    extra_excluded=_refinement_names(env, stmt.test))
        if not _refine(fn, env, stmt.test, True):
            return []          # assert proves this path impossible
        return [(_FALL, env)]
    if isinstance(stmt, ast.Break):
        return [(_BREAK, env)]
    if isinstance(stmt, ast.Continue):
        return [(_CONTINUE, env)]
    if isinstance(stmt, ast.Delete):
        for t in stmt.targets:
            if isinstance(t, ast.Name):
                env.unbind_var(t.id)
        return [(_FALL, env)]
    if isinstance(stmt, _SCOPE_NODES):
        # Nested def/class: anything it captures escapes.
        _escape_names(fn, env, stmt)
        return [(_FALL, env)]
    if isinstance(stmt, (ast.Import, ast.ImportFrom, ast.Global,
                         ast.Nonlocal, ast.Pass)):
        return [(_FALL, env)]
    # Fallback: scan for calls, keep going.
    may_raise = _scan_calls(fn, env, stmt)
    out = []
    if may_raise:
        out.append((_RAISE, env.copy()))
    out.append((_FALL, env))
    return out


def _func_chains(node: ast.AST) -> set[int]:
    """id()s of call-func subtrees (receiver chains don't escape)."""
    out: set[int] = set()
    for call in _shallow_calls(node):
        out.add(id(call.func))
    return out


def _exec_assign(fn: _Fn, stmt: ast.stmt,
                 env: _Env) -> list[tuple[int, _Env]]:
    if isinstance(stmt, ast.Assign):
        targets, value = stmt.targets, stmt.value
    elif isinstance(stmt, ast.AnnAssign):
        targets, value = [stmt.target], stmt.value
    else:  # AugAssign
        targets, value = [], stmt.value

    creator = None
    if isinstance(value, ast.Call):
        creator = _creator_match(fn, value)

    may_raise = False
    if value is not None:
        may_raise = _scan_calls(fn, env, value,
                                skip=value if creator else None)
        if creator:
            # Arguments of the creator call can still raise / escape.
            for arg in list(value.args) + [kw.value for kw in value.keywords]:
                if _scan_calls(fn, env, arg):
                    may_raise = True
            may_raise = True
    raise_env = env.copy() if may_raise else None

    bound = False
    if creator is not None and targets:
        bound = _bind_creator(fn, env, creator[0], creator[1], value,
                              targets)

    if not bound and targets:
        # Alias propagation and rebinding.
        simple_alias = (
            len(targets) == 1 and isinstance(targets[0], ast.Name)
            and isinstance(value, ast.Name)
        )
        attr_target = any(not isinstance(t, (ast.Name, ast.Tuple))
                          for t in targets)
        if attr_target and value is not None:
            # Stored into an attribute / subscript: escapes.
            _escape_names(fn, env, value, _func_chains(value))
        for t in targets:
            names = [t] if isinstance(t, ast.Name) else [
                e for e in getattr(t, "elts", []) if isinstance(e, ast.Name)]
            for n in names:
                env.unbind_var(n.id)
        if simple_alias:
            rid = env.handles.get("v:" + value.id)
            if rid is not None:
                env.handles["v:" + targets[0].id] = rid
        elif value is not None and not attr_target:
            # Value flows into a tuple/list/other expression bound to a
            # plain name — treat embedded resources as escaped.
            if not isinstance(value, (ast.Call, ast.Name, ast.Constant)):
                _escape_names(fn, env, value, _func_chains(value))

    out: list[tuple[int, _Env]] = []
    if raise_env is not None:
        out.append((_RAISE, raise_env))
    out.append((_FALL, env))
    return out


def _exec_if(fn: _Fn, stmt: ast.If, env: _Env) -> list[tuple[int, _Env]]:
    outs: list[tuple[int, _Env]] = []
    bool_creator = _bool_creator_in_test(fn, stmt.test)
    may_raise = _scan_calls(
        fn, env, stmt.test,
        skip=bool_creator[2] if bool_creator else None,
        extra_excluded=_refinement_names(env, stmt.test))
    if may_raise or bool_creator:
        outs.append((_RAISE, env.copy()))

    tenv = env.copy()
    fenv = env
    if bool_creator is not None:
        spec, cr, call = bool_creator
        for e, atom in ((tenv, spec.initial), (fenv, spec.initial_none)):
            recv_text = ast.unparse(call.func.value)
            res = fn.new_resource(
                spec, cr, call,
                base_names=frozenset(_names_in(call.func.value)),
                dmap={"__true__": spec.initial,
                      "__false__": spec.initial_none},
                truthy_key="__true__", falsy_key="__false__")
            e.states[res.rid] = frozenset({atom})
            e.handles["t:" + recv_text] = res.rid
        t_ok = f_ok = True
    else:
        t_ok = _refine(fn, tenv, stmt.test, True)
        f_ok = _refine(fn, fenv, stmt.test, False)
    if t_ok:
        outs.extend(_exec_block(fn, stmt.body, tenv))
    if f_ok:
        outs.extend(_exec_block(fn, stmt.orelse, fenv))
    return outs


def _exec_loop(fn: _Fn, stmt: ast.stmt,
               env: _Env) -> list[tuple[int, _Env]]:
    outs: list[tuple[int, _Env]] = []
    is_while = isinstance(stmt, ast.While)
    infinite = (is_while and isinstance(stmt.test, ast.Constant)
                and bool(stmt.test.value))
    if is_while:
        if _scan_calls(fn, env, stmt.test,
                       extra_excluded=_refinement_names(env, stmt.test)):
            outs.append((_RAISE, env.copy()))
    else:
        if _scan_calls(fn, env, stmt.iter):
            outs.append((_RAISE, env.copy()))
        for t in ast.walk(stmt.target):
            if isinstance(t, ast.Name):
                env.unbind_var(t.id)

    loop_marker = fn._next_rid
    body_env = env.copy()
    feasible = True
    if is_while:
        feasible = _refine(fn, body_env, stmt.test, True)

    exit_envs: list[_Env] = []
    if not infinite:
        zero = env.copy()
        if not is_while or _refine(fn, zero, stmt.test, False):
            exit_envs.append(zero)

    if feasible:
        for kind, e in _exec_block(fn, stmt.body, body_env):
            if kind in (_FALL, _CONTINUE):
                # Back edge: a later iteration may discharge anything
                # created inside the body — escape those, keep earlier
                # resources at their current state.
                for rid in list(e.states):
                    if rid >= loop_marker:
                        e.escaped.add(rid)
                if not infinite:
                    exit_envs.append(e)
            elif kind == _BREAK:
                exit_envs.append(e)
            else:
                outs.append((kind, e))

    for e in _dedupe(exit_envs):
        if stmt.orelse:
            outs.extend(_exec_block(fn, stmt.orelse, e.copy()))
        else:
            outs.append((_FALL, e))
    return outs


def _is_catch_all(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    names = []
    if isinstance(t, (ast.Name, ast.Attribute)):
        names = [_terminal_name(t)]
    elif isinstance(t, ast.Tuple):
        names = [_terminal_name(e) for e in t.elts]
    return any(n in ("Exception", "BaseException") for n in names)


def _exec_try(fn: _Fn, stmt, env: _Env) -> list[tuple[int, _Env]]:
    body_outs = _exec_block(fn, stmt.body, env)
    routed: list[tuple[int, _Env]] = []
    has_catch_all = any(_is_catch_all(h) for h in stmt.handlers)

    for kind, e in body_outs:
        if kind == _RAISE and stmt.handlers:
            for h in stmt.handlers:
                he = e.copy()
                if h.name:
                    he.unbind_var(h.name)
                routed.extend(_exec_block(fn, h.body, he))
            if not has_catch_all:
                routed.append((_RAISE, e))
        elif kind == _FALL and stmt.orelse:
            routed.extend(_exec_block(fn, stmt.orelse, e))
        else:
            routed.append((kind, e))

    if not stmt.finalbody:
        return routed
    outs: list[tuple[int, _Env]] = []
    for kind, e in routed:
        fn.charge()
        for fkind, fe in _exec_block(fn, stmt.finalbody, e):
            outs.append((kind, fe) if fkind == _FALL else (fkind, fe))
    return outs


def _exec_with(fn: _Fn, stmt, env: _Env) -> list[tuple[int, _Env]]:
    outs: list[tuple[int, _Env]] = []
    may_raise = False
    for item in stmt.items:
        ce = item.context_expr
        managed_creator = isinstance(ce, ast.Call) \
            and _creator_match(fn, ce) is not None
        if managed_creator:
            # `with fs.open_write(k) as w:` — __exit__ discharges the
            # obligation structurally; nothing to track.
            for arg in list(ce.args) + [kw.value for kw in ce.keywords]:
                if _scan_calls(fn, env, arg):
                    may_raise = True
            may_raise = True
        else:
            if _scan_calls(fn, env, ce):
                may_raise = True
            rid = env.rid_of_expr(ce)
            if rid is not None:
                # `with w:` on a tracked lifecycle resource: __exit__
                # closes it on every path out of the block.
                spec = fn.resources[rid].spec
                env.states[rid] = frozenset(
                    a if a in spec.final else next(iter(spec.final))
                    for a in env.states[rid])
        if item.optional_vars is not None:
            for n in ast.walk(item.optional_vars):
                if isinstance(n, ast.Name):
                    env.unbind_var(n.id)
    if may_raise:
        outs.append((_RAISE, env.copy()))
    outs.extend(_exec_block(fn, stmt.body, env))
    return outs


# ---------------------------------------------------------------------------
# Function / module driver.
# ---------------------------------------------------------------------------

_CREATOR_METHODS = frozenset(
    cr.method for spec in PROTOCOLS for cr in spec.creators if cr.method)
_CREATOR_CLASSES = frozenset(
    name for spec in PROTOCOLS for cr in spec.creators
    for name in cr.class_names)


def _mentions_creator(node: ast.AST) -> bool:
    for call in ast.walk(node):
        if not isinstance(call, ast.Call):
            continue
        f = call.func
        if isinstance(f, ast.Attribute) and (f.attr in _CREATOR_METHODS
                                             or f.attr in _CREATOR_CLASSES):
            return True
        if isinstance(f, ast.Name) and f.id in _CREATOR_CLASSES:
            return True
    return False


def _check_function(module: Module, project: Project,
                    fi: FuncInfo) -> list[Finding]:
    if not _mentions_creator(fi.node):
        return []
    fn = _Fn(module, project, fi)
    try:
        outcomes = _exec_block(fn, fi.node.body, _Env())
    except _Bailout:
        return []
    for kind, e in outcomes:
        exceptional = kind == _RAISE
        for rid, atoms in e.states.items():
            if rid in e.escaped:
                continue
            res = fn.resources[rid]
            spec = res.spec
            if exceptional:
                if spec.exception_paths == "none":
                    continue
                if spec.exception_paths == "src" and module.is_test:
                    continue
            for atom in atoms:
                if atom in spec.final:
                    continue
                fn.report_exit(res, atom)
    return list(fn.violations.values())


def run_typestate(module: Module, project: Project) -> list[Finding]:
    """All typestate findings for one module, across every protocol."""
    findings: list[Finding] = []
    seen_funcs: set[int] = set()
    fi_by_node = {id(fi.node): fi for fi in project.funcs.values()
                  if fi.module is module}
    for node in ast.walk(module.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if id(node) in seen_funcs:
            continue
        seen_funcs.add(id(node))
        fi = fi_by_node.get(id(node))
        if fi is None:
            fi = FuncInfo(module=module, node=node, qualname=node.name)
        findings.extend(_check_function(module, project, fi))
    return findings


# ---------------------------------------------------------------------------
# Rule registration: one rule id per protocol bug class, all served from
# a single cached interpreter run per module.
# ---------------------------------------------------------------------------

_RESULT_CACHE: dict[int, tuple[Module, dict[str, list[Finding]]]] = {}


def _bucketed(module: Module, project: Project) -> dict[str, list[Finding]]:
    cached = _RESULT_CACHE.get(id(module))
    if cached is not None and cached[0] is module:
        return cached[1]
    buckets: dict[str, list[Finding]] = {}
    for f in run_typestate(module, project):
        buckets.setdefault(f.rule, []).append(f)
    if len(_RESULT_CACHE) > 4096:
        _RESULT_CACHE.clear()
    _RESULT_CACHE[id(module)] = (module, buckets)
    return buckets


def _typestate_rule(rid: str):
    def rule(module: Module, project: Project) -> list[Finding]:
        return _bucketed(module, project).get(rid, [])
    rule.__name__ = f"rule_{rid.lower()}"
    return rule


TYPESTATE_RULES: dict[str, tuple[str, str]] = {
    "RP009": (
        "acquire() leader/waiter handles reach publish/abort or "
        "join/leave on every path",
        "a leaked leader flight wedges every waiter until the reclaim "
        "TTL — the bug class PR 4's engine-shutdown fixes were full of",
    ),
    "RP010": (
        "unpin() balances pins: no double release, no read after release",
        "an extra unpin frees a block another reader still trusts; a "
        "read after unpin races eviction",
    ),
    "RP011": (
        "reserve_space()/reserve() commit or cancel on every path",
        "a leaked reservation permanently shrinks the tier: inflight "
        "bytes count as legitimate forever",
    ),
    "RP012": (
        "start_multipart() completes or aborts on every path",
        "an orphaned multipart upload is a stranded partial object — "
        "storage cost and recovery confusion",
    ),
    "RP013": (
        "Writer/UploadPool/DeviceFeeder close on every normal path",
        "unclosed writers strand staged tier blocks; unclosed "
        "pools/feeders strand threads",
    ),
}

for _rid, (_summary, _rationale) in TYPESTATE_RULES.items():
    register_rule(_rid, _summary, rationale=_rationale)(
        _typestate_rule(_rid))
