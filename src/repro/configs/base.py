"""Config system: model architecture + input-shape configs.

Every assigned architecture is a `ModelConfig` in its own module under
``repro.configs``; shapes are the four assigned (seq_len, global_batch)
cells. Block layout is expressed as a repeating *period* of blocks so the
layer stack lowers to one `lax.scan` over periods (HLO size independent of
depth — critical for 40-cell × 2-mesh dry-run compile times).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace


# --------------------------------------------------------------------------- #
# Block pattern
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class BlockDef:
    mixer: str          # "attn" | "mamba"
    ffn: str | None     # "dense" | "moe" | None (mamba2 blocks carry no FFN)
    cross_attn: bool = False  # decoder blocks of enc-dec models


# --------------------------------------------------------------------------- #
# Model config
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads

    # block layout: `pattern` repeated `periods` times == num_layers blocks
    pattern: tuple[BlockDef, ...] = (BlockDef("attn", "dense"),)

    # normalization / misc structure
    norm_type: str = "rmsnorm"    # rmsnorm | layernorm
    norm_eps: float = 1e-5
    parametric_norm: bool = True  # False: OLMo-style non-parametric LN
    norm_bias: bool = False
    qkv_bias: bool = False
    out_bias: bool = False        # bias on attn-out / MLP projections
    parallel_block: bool = False  # Cohere: attn + FFN share the input norm
    qk_norm: bool = False
    act: str = "silu"             # silu (SwiGLU) | gelu (plain / GeGLU)
    glu: bool = True              # gated FFN
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    pos_embedding: str | None = None  # "sinusoidal" | "learned" | None
    logit_scale: float = 1.0      # Cohere logit_scale / granite logits_scaling
    embedding_multiplier: float = 1.0  # granite
    residual_multiplier: float = 1.0   # granite
    embed_inputs: bool = False    # VLM/audio: inputs are embeddings, not ids
    max_seq_len: int = 524288

    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_aux_loss_weight: float = 0.01
    # Pad the expert dim to this multiple so it shards over the tensor axis
    # (granite's 40 -> 48 on a 16-way axis); dummy experts are masked out of
    # routing and receive no tokens. 1 disables padding.
    moe_pad_multiple: int = 16

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 128
    ssm_conv_kernel: int = 4

    # encoder-decoder
    is_encdec: bool = False
    enc_layers: int = 0           # encoder depth (decoder depth = num_layers)
    dec_prefill_len: int = 256    # decoder prompt length for prefill shapes

    # provenance
    source: str = ""

    # ---- derived ----------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern length {len(self.pattern)}"
            )

    @property
    def periods(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def is_moe(self) -> bool:
        return self.moe_num_experts > 0

    @property
    def moe_padded_experts(self) -> int:
        m = max(1, self.moe_pad_multiple)
        return int(math.ceil(self.moe_num_experts / m) * m)

    @property
    def has_attention(self) -> bool:
        return any(b.mixer == "attn" for b in self.pattern)

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: no full-attention over the whole sequence
        dominates (SSM or hybrid-with-few-attn archs)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    def padded_vocab(self, multiple: int = 256) -> int:
        return int(math.ceil(self.vocab_size / multiple) * multiple)

    # ---- reduced config for CPU smoke tests --------------------------------
    def reduced(self) -> "ModelConfig":
        """Same family/block-structure, tiny dims: one pattern period (or two
        for depth), small width, few experts — runnable on CPU."""
        num_layers = len(self.pattern)
        d_model = 64
        n_heads = max(1, min(4, self.num_heads)) if self.num_heads else 0
        if n_heads and self.num_kv_heads:
            if self.num_kv_heads == self.num_heads:
                n_kv = n_heads  # MHA stays MHA
            else:
                group = max(2, self.num_heads // self.num_kv_heads)
                n_kv = max(1, n_heads // group)
                n_heads = n_kv * min(group, n_heads)  # keep divisibility
        else:
            n_kv = 0
        kw = dict(
            name=self.name + "-reduced",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=16 if n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=512,
            max_seq_len=2048,
        )
        if self.is_moe:
            kw.update(moe_num_experts=4, moe_top_k=min(2, self.moe_top_k),
                      moe_pad_multiple=1)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
        if self.is_encdec:
            kw.update(enc_layers=len(self.pattern), dec_prefill_len=8)
        return replace(self, **kw)


# --------------------------------------------------------------------------- #
# Shape configs (assigned per-arch shape set)
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str           # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """The assigned shape cells applicable to `cfg`. long_500k needs
    sub-quadratic sequence mixing; full-attention archs skip it (recorded in
    DESIGN.md §Arch-applicability)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.subquadratic:
        out.append(SHAPES["long_500k"])
    return out


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #
_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch config: {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _load_all()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def all_configs() -> dict[str, ModelConfig]:
    _load_all()
    return dict(_REGISTRY)


_ARCH_MODULES = [
    "command_r_plus_104b",
    "codeqwen1_5_7b",
    "smollm_135m",
    "olmo_1b",
    "llava_next_mistral_7b",
    "jamba_1_5_large_398b",
    "whisper_large_v3",
    "granite_moe_3b_a800m",
    "dbrx_132b",
    "mamba2_1_3b",
]

_LOADED = False


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    import importlib

    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True
