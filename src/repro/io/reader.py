"""The uniform Reader protocol plus the direct (uncached) engine.

Every engine returned by `PrefetchFS.open`/`open_many` satisfies `Reader`:
sequential ``read``/``seek``/``tell``/``close`` over one logical byte
stream (the concatenation of the opened objects), a ``size`` property, and
a ``stats`` object with a ``snapshot()`` dict — the subset of the
S3Fs/fsspec file API the paper's applications use.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Protocol, runtime_checkable

from repro.core.plan import BlockPlan
from repro.store.base import ObjectMeta, ObjectStore


@runtime_checkable
class Reader(Protocol):
    """File-object protocol shared by all engines."""

    @property
    def size(self) -> int: ...

    @property
    def closed(self) -> bool: ...

    def read(self, n: int = -1) -> bytes: ...

    def seek(self, offset: int, whence: int = 0) -> int: ...

    def tell(self) -> int: ...

    def close(self) -> None: ...


@dataclass
class DirectStats:
    requests: int = 0
    bytes_read: int = 0
    fetch_s: float = 0.0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


class DirectReader:
    """Pass-through engine: every ``read`` becomes store range requests,
    no caching, no background threads. This is the random-access fallback
    (each request pays full store latency) and the control arm for
    benchmarks that want raw link behaviour."""

    def __init__(self, store: ObjectStore, files: list[ObjectMeta]) -> None:
        self.store = store
        # One "block" per file: the plan is used only for stream->file
        # offset math; requests are cut to exactly the bytes asked for.
        blocksize = max((m.size for m in files), default=1)
        self.plan = BlockPlan(files, max(1, blocksize))
        self.stats = DirectStats()
        self._pos = 0
        self._closed = False

    @property
    def size(self) -> int:
        return self.plan.total_bytes

    @property
    def closed(self) -> bool:
        return self._closed

    def read(self, n: int = -1) -> bytes:
        if self._closed:
            raise ValueError("read on closed file")
        if n < 0:
            n = self.size - self._pos
        end = min(self._pos + n, self.size)
        out = bytearray()
        while self._pos < end:
            block = self.plan.block_at(self._pos)
            lo = self._pos - block.global_start
            hi = min(end, block.global_end) - block.global_start
            t0 = time.perf_counter()
            data = self.store.get_range(block.key, block.start + lo,
                                        block.start + hi)
            self.stats.fetch_s += time.perf_counter() - t0
            self.stats.requests += 1
            out.extend(data)
            self._pos += len(data)
        self.stats.bytes_read += len(out)
        return bytes(out)

    def seek(self, offset: int, whence: int = 0) -> int:
        if whence == 1:
            offset += self._pos
        elif whence == 2:
            offset += self.size
        if not 0 <= offset <= self.size:
            raise ValueError(f"seek out of range: {offset}")
        self._pos = offset
        return self._pos

    def tell(self) -> int:
        return self._pos

    def close(self) -> None:
        self._closed = True

    def __enter__(self) -> "DirectReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
