from repro.utils.hashing import rendezvous_owner
from repro.utils.logging import get_logger
from repro.utils.timing import Timer, Stopwatch

__all__ = ["get_logger", "Timer", "Stopwatch", "rendezvous_owner"]
