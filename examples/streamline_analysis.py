"""Paper use-cases end-to-end: streamline-length histogram and bundle
recognition over a prefetched multi-shard dataset, with the analysis
compute in JAX (paper §II-D.4, Fig. 5).

  PYTHONPATH=src python examples/streamline_analysis.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.trk import iter_streamlines_multi, synth_trk
from repro.io import IOPolicy, PrefetchFS, open_store
from repro.store import MemTier

rng = np.random.default_rng(1)
objects = {f"hydi/shard{i}.trk": synth_trk(rng, 3000, mean_points=15)
           for i in range(4)}


def open_stream(engine: str):
    store = open_store("sims3://hydi?latency_ms=20&bw_mbps=45", fresh=True)
    for k, v in objects.items():
        store.backing.put(k, v)
    fs = PrefetchFS(
        store,
        policy=IOPolicy(engine=engine, blocksize=256 << 10,
                        eviction_interval_s=0.05),
        tiers=[MemTier(4 << 20)],
    )
    return fs.open_many(store.backing.list_objects())


# --- use-case 1: histogram of streamline lengths (lazy, data-intensive) ------
@jax.jit
def lengths_of(padded_points, n_points):
    deltas = jnp.diff(padded_points, axis=0)
    seg = jnp.linalg.norm(deltas, axis=1)
    mask = jnp.arange(seg.shape[0]) < (n_points - 1)
    return jnp.sum(seg * mask)


def histogram(mode: str):
    f = open_stream(mode)
    t0 = time.perf_counter()
    lengths = []
    for sl in iter_streamlines_multi(f, f.size):
        pts = np.zeros((64, 3), np.float32)
        n = min(len(sl.points), 64)
        pts[:n] = sl.points[:n]
        lengths.append(float(lengths_of(jnp.asarray(pts), n)))
    hist = np.histogram(lengths, bins=20)[0]
    dt = time.perf_counter() - t0
    f.close()
    return hist, dt


# --- use-case 2: bundle recognition (load-all-then-compute) --------------------
@jax.jit
def classify(batch_points, ref_cst, ref_arc):
    d_cst = jnp.mean(jnp.linalg.norm(batch_points - ref_cst, axis=-1), axis=-1)
    d_arc = jnp.mean(jnp.linalg.norm(batch_points - ref_arc, axis=-1), axis=-1)
    best = jnp.minimum(d_cst, d_arc)
    return jnp.where(best > 8.0, 0, jnp.where(d_cst < d_arc, 1, 2))


def resample(points: np.ndarray, n: int = 20) -> np.ndarray:
    t = np.linspace(0, 1, len(points))
    ti = np.linspace(0, 1, n)
    return np.stack([np.interp(ti, t, points[:, i]) for i in range(3)], axis=1)


def bundle_recognition(mode: str):
    f = open_stream(mode)
    t0 = time.perf_counter()
    # Paper: the pipeline loads all data first (no lazy loading)...
    streamlines = [sl.points for sl in iter_streamlines_multi(f, f.size)]
    f.close()
    # ...then computes.
    batch = jnp.asarray(np.stack([resample(p) for p in streamlines]))
    k = jax.random.key(0)
    ref_cst = jax.random.normal(k, (20, 3)).cumsum(axis=0)
    ref_arc = ref_cst + 5.0
    labels = np.asarray(classify(batch, ref_cst, ref_arc))
    return labels, time.perf_counter() - t0


for usecase, fn in [("histogram", histogram), ("bundle", bundle_recognition)]:
    fn("rolling")  # warm-up: JIT compilation must not land in a timed run
    out_seq, t_seq = fn("sequential")
    out_pf, t_pf = fn("rolling")
    match = np.array_equal(np.asarray(out_seq), np.asarray(out_pf))
    print(f"{usecase:>10s}: sequential {t_seq:.2f}s | rolling {t_pf:.2f}s | "
          f"speed-up {t_seq / t_pf:.2f}x | results identical: {match}")
print("(paper Fig. 5: histogram ~1.5x, bundle ~1.14x; both < 2x)")
