"""Serving driver: batched prefill + decode with KV caches; weights
restored from the object store through Rolling Prefetch (cold-start
latency is a first-order cost at serving scale, and checkpoint restore is
exactly the sequential multi-object stream the paper optimizes).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.io import IOPolicy, open_store
from repro.models import make_model
from repro.utils import get_logger

log = get_logger("launch.serve")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--restore-mode", default="rolling",
                    choices=["rolling", "sequential"])
    ap.add_argument("--autotune", action="store_true",
                    help="adaptive restore: coalesced range GETs + AIMD "
                         "stream depth + closed-loop blocksize tuning")
    ap.add_argument("--store", default="sims3://weights?latency_ms=10&bw_mbps=80",
                    help="weight store URI (any registered scheme)")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent weight-block cache directory: restores "
                         "cache into a journaled DirTier there, so a "
                         "restarted replica cold-starts warm (zero store "
                         "GETs for blocks that survived on local disk)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quant", choices=["int8"], default=None,
                    help="weight-only int8 serving (TP-only layout)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = make_model(cfg)

    # --- publish + cold-start restore through the object store ----------------
    store = open_store(args.store)
    params = model.init(jax.random.key(0))
    save_checkpoint(store, "weights", 0, params,
                    policy=IOPolicy(write_depth=4))
    t0 = time.time()
    params, _ = restore_checkpoint(
        store, "weights", params,
        policy=IOPolicy(engine=args.restore_mode, depth=2,
                        max_depth=8 if args.autotune else None,
                        autotune=args.autotune,
                        eviction_interval_s=0.2),
        cache_dir=args.cache_dir,
    )
    print(f"weight restore ({args.restore_mode}): {time.time() - t0:.2f}s"
          + (f" [cache: {args.cache_dir}]" if args.cache_dir else ""))
    if args.quant == "int8":
        from repro.models.quant import quantize_params

        params, n_q = quantize_params(params)
        print(f"quantized {n_q} weight tensors to int8 (weight-only)")

    # --- batched prefill -------------------------------------------------------
    b, s = args.batch, args.prompt_len
    rng = np.random.default_rng(0)
    if cfg.embed_inputs and not cfg.is_encdec:
        batch = {"inputs": jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)).astype(np.float32),
            jnp.bfloat16)}
    elif cfg.is_encdec:
        batch = {
            "enc_inputs": jnp.asarray(
                rng.normal(size=(b, s, cfg.d_model)).astype(np.float32),
                jnp.bfloat16),
            "dec_prompt": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, 8)), jnp.int32),
        }
    else:
        batch = {"inputs": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}

    # Decode needs cache headroom for generated tokens.
    prompt_tokens = 8 if cfg.is_encdec else s
    max_len = prompt_tokens + args.gen
    if cfg.is_encdec:
        from repro.models import encdec as ED

        enc_h = ED.encode(params, cfg, batch["enc_inputs"], q_chunk=min(512, s))
        cross = ED.build_cross_caches(params, cfg, enc_h)
        caches = ED.make_decode_caches(cfg, b, max_len, cross_len=s, length=0)
        caches = ED._merge_cross(caches, cross)
        from repro.models import layers as L, lm as LM

        x = L.embed_tokens(params["embed"], cfg, batch["dec_prompt"])
        x = ED._add_sinusoid(x)
        positions = jnp.arange(prompt_tokens, dtype=jnp.int32)
        x, caches, _ = LM.stack_fwd(
            params["layers"], cfg, x, positions=positions, caches=caches,
            update_cache=True, causal=True, q_chunk=min(512, prompt_tokens),
        )
        h = L.apply_norm(params["final_norm"], cfg, x)
        logits = LM.logits_from_hidden(params, cfg, h[:, -1:, :])[:, 0]
    else:
        from repro.models import lm as LM

        caches = LM.make_stack_cache(cfg, b, max_len)
        t0 = time.time()
        h, caches, _ = LM.lm_hidden(
            params, cfg, batch["inputs"], caches=caches, update_cache=True,
            q_chunk=min(512, s),
        )
        logits = LM.logits_from_hidden(params, cfg, h[:, -1:, :])[:, 0]
        print(f"prefill: {time.time() - t0:.2f}s "
              f"({b * s / (time.time() - t0):.0f} tok/s)")

    # --- decode loop -----------------------------------------------------------
    decode = jax.jit(
        lambda p, ids, c, pos: model.decode_step(p, ids, c, pos)
    )
    key = jax.random.key(1)
    tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = prompt_tokens + i
        if cfg.embed_inputs and not cfg.is_encdec:
            # VLM decode consumes token embeddings from the text table.
            emb = jnp.take(params["embed"]["table"], tok[:, 0], axis=0)
            step_in = emb[:, None, :].astype(jnp.bfloat16)
        else:
            step_in = tok
        logits, caches = decode(params, step_in, caches, pos)
        logits = logits[:, : cfg.vocab_size]
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None]
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decoded {args.gen} tokens x {b} seqs in {dt:.2f}s "
          f"({b * args.gen / dt:.1f} tok/s)")
    print("sample token ids:", np.asarray(out[0, :16]))


if __name__ == "__main__":
    main()
