"""Bounded local cache tiers for Rolling Prefetch.

The paper writes prefetched blocks to a priority-ordered list of local
storage devices (tmpfs first, then disk), each with a user-set byte budget.
`used` accounting intentionally mirrors Algorithm 1: the prefetch thread
increments `used` optimistically, and reconciles with reality via
`verify_used()` when it believes a tier is full (evictions may have freed
space since the last check).
"""

from __future__ import annotations

import abc
import os
import threading
from dataclasses import dataclass

from repro.store.base import StoreError
from repro.store.link import LinkModel


class CacheTier(abc.ABC):
    """A bounded block cache with simulated (or real) transfer costs."""

    def __init__(
        self,
        capacity: int,
        read_link: LinkModel | None = None,
        write_link: LinkModel | None = None,
        name: str = "tier",
    ) -> None:
        self.capacity = capacity
        self.read_link = read_link if read_link is not None else LinkModel(name=f"{name}.r")
        self.write_link = write_link if write_link is not None else LinkModel(name=f"{name}.w")
        self.name = name
        self._used = 0       # optimistic accounting: committed + in-flight
        self._inflight = 0   # reserved but not yet written
        self._lock = threading.Lock()

    # -- Algorithm-1 accounting -------------------------------------------
    @property
    def used(self) -> int:
        with self._lock:
            return self._used

    def available(self) -> int:
        with self._lock:
            return self.capacity - self._used

    def reserve(self, nbytes: int) -> bool:
        """Optimistically claim space (prefetch thread)."""
        with self._lock:
            if self.capacity - self._used < nbytes:
                return False
            self._used += nbytes
            self._inflight += nbytes
            return True

    def commit(self, nbytes: int) -> None:
        """The reserved bytes are now resident (write completed)."""
        with self._lock:
            self._inflight = max(0, self._inflight - nbytes)

    def cancel(self, nbytes: int) -> None:
        """A reservation was abandoned (fetch failed permanently)."""
        with self._lock:
            self._inflight = max(0, self._inflight - nbytes)
            self._used = max(0, self._used - nbytes)

    def release(self, nbytes: int) -> None:
        """Committed bytes were evicted."""
        with self._lock:
            self._used = max(0, self._used - nbytes)

    def verify_used(self) -> int:
        """Reconcile `used` with the bytes actually resident plus in-flight
        reservations (evictions may have freed space since the last check).
        Returns available space after reconciliation. Mirrors the paper's
        `verify_used()` in Algorithm 1."""
        actual = self._resident_bytes()
        with self._lock:
            self._used = min(self._used, max(actual, 0) + self._inflight)
            return self.capacity - self._used

    # -- storage ops (charged to the tier's links) --------------------------
    def write(self, block_id: str, data: bytes) -> None:
        self.write_link.transfer(len(data))
        self._write(block_id, data)

    def read(self, block_id: str, start: int = 0, end: int | None = None) -> bytes:
        data = self._read(block_id, start, end)
        self.read_link.transfer(len(data))
        return data

    def delete(self, block_id: str) -> int:
        """Remove the block; returns bytes freed. Does NOT adjust `used`
        (that is the prefetcher's job via verify_used / explicit release),
        matching the paper's decoupled eviction."""
        return self._delete(block_id)

    def contains(self, block_id: str) -> bool:
        return self._contains(block_id)

    # -- backend hooks ------------------------------------------------------
    @abc.abstractmethod
    def _write(self, block_id: str, data: bytes) -> None: ...

    @abc.abstractmethod
    def _read(self, block_id: str, start: int, end: int | None) -> bytes: ...

    @abc.abstractmethod
    def _delete(self, block_id: str) -> int: ...

    @abc.abstractmethod
    def _contains(self, block_id: str) -> bool: ...

    @abc.abstractmethod
    def _resident_bytes(self) -> int: ...


class MemTier(CacheTier):
    """Dict-backed tier modeling tmpfs (costs from the tier's LinkModel)."""

    def __init__(self, capacity: int, **kw) -> None:
        super().__init__(capacity, **kw)
        self._blocks: dict[str, bytes] = {}
        self._blk_lock = threading.Lock()

    def _write(self, block_id: str, data: bytes) -> None:
        with self._blk_lock:
            self._blocks[block_id] = bytes(data)

    def _read(self, block_id: str, start: int, end: int | None) -> bytes:
        with self._blk_lock:
            try:
                data = self._blocks[block_id]
            except KeyError:
                raise StoreError(f"{self.name}: block missing: {block_id}") from None
        return data[start:end if end is not None else len(data)]

    def _delete(self, block_id: str) -> int:
        with self._blk_lock:
            data = self._blocks.pop(block_id, None)
            return len(data) if data is not None else 0

    def _contains(self, block_id: str) -> bool:
        with self._blk_lock:
            return block_id in self._blocks

    def _resident_bytes(self) -> int:
        with self._blk_lock:
            return sum(len(v) for v in self._blocks.values())


class DirTier(CacheTier):
    """Real-directory tier (an actual tmpfs mount or scratch disk)."""

    def __init__(self, capacity: int, root: str, **kw) -> None:
        super().__init__(capacity, **kw)
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, block_id: str) -> str:
        return os.path.join(self.root, block_id.replace("/", "__"))

    def _write(self, block_id: str, data: bytes) -> None:
        tmp = self._path(block_id) + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, self._path(block_id))

    def _read(self, block_id: str, start: int, end: int | None) -> bytes:
        try:
            with open(self._path(block_id), "rb") as f:
                f.seek(start)
                return f.read(None if end is None else end - start)
        except OSError:
            raise StoreError(f"{self.name}: block missing: {block_id}") from None

    def _delete(self, block_id: str) -> int:
        path = self._path(block_id)
        try:
            size = os.path.getsize(path)
            os.remove(path)
            return size
        except OSError:
            return 0

    def _contains(self, block_id: str) -> bool:
        return os.path.exists(self._path(block_id))

    def _resident_bytes(self) -> int:
        total = 0
        try:
            for fn in os.listdir(self.root):
                if not fn.endswith(".tmp"):
                    total += os.path.getsize(os.path.join(self.root, fn))
        except OSError:
            pass
        return total


@dataclass(frozen=True)
class TierPlacement:
    """Where a cached block lives."""

    tier: CacheTier
    block_id: str
