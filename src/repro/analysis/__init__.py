"""repro.analysis: project-specific static analysis for the prefetch stack.

Every rule in this package encodes a bug class this codebase has already
paid for (see CHANGES.md): unjittered retry storms, locks leaked on
early-exit paths, blocking store I/O under an index lock, fire-and-forget
threads, and un-length-checked range responses cached as corruption.
Generic linters cannot see these (the ruff config is deliberately
Pyflakes-only); this analyzer walks the AST with a lightweight
intra-project call graph and checks the invariants directly.

Usage::

    python -m repro.analysis src tests              # text report
    python -m repro.analysis src --format json      # machine-readable
    python -m repro.analysis src --locks-md LOCKS.md

Suppression convention (one per line, reason required)::

    except Exception:   # repro: allow[RP005] — mover must survive

Rules register through `@register_rule`, mirroring the reader/store
registries in `repro.io.registry` — adding a rule is writing a function.
On top of rule findings the analyzer emits a lock-order graph (which
locks are held at each acquisition site, interprocedurally) and fails on
any cycle; `LOCKS.md` is its rendered form.
"""

from __future__ import annotations

from repro.analysis.core import (
    Finding,
    Module,
    Project,
    analyze,
    load_project,
)
from repro.analysis.lockgraph import LockGraph, build_lock_graph
from repro.analysis.registry import (
    RuleSpec,
    all_rules,
    get_rule,
    register_rule,
)
from repro.analysis.report import (
    Baseline,
    Report,
    render_json,
    render_text,
)

__all__ = [
    "Baseline",
    "Finding",
    "LockGraph",
    "Module",
    "Project",
    "Report",
    "RuleSpec",
    "all_rules",
    "analyze",
    "build_lock_graph",
    "get_rule",
    "load_project",
    "register_rule",
    "render_json",
    "render_text",
]
