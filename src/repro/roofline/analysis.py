"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh):

  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = collective_bytes_per_chip / link_bw

`compiled.cost_analysis()` on the CPU backend reports per-partition (i.e.
per-chip) FLOPs and bytes for SPMD executables (verified empirically:
a 512-way sharded matmul reports total/512). Collective bytes are parsed
from the post-partitioning optimized HLO: shapes there are per-partition,
and we count output bytes per op with an all-reduce x2 multiplier
(ring AR moves ~2x payload); (n-1)/n ring factors are folded to 1.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e per-chip hardware constants (per assignment).
HW_V5E = dict(
    name="tpu_v5e",
    peak_flops=197e12,     # bf16 FLOP/s
    hbm_bw=819e9,          # B/s
    link_bw=50e9,          # B/s per ICI link
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*(\w+)\[([0-9,]*)\][^=]*?"
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)


@dataclass
class CollectiveStats:
    total_bytes: float = 0.0
    by_kind_bytes: dict = field(default_factory=dict)
    by_kind_count: dict = field(default_factory=dict)

    def add(self, kind: str, nbytes: float) -> None:
        self.total_bytes += nbytes
        self.by_kind_bytes[kind] = self.by_kind_bytes.get(kind, 0.0) + nbytes
        self.by_kind_count[kind] = self.by_kind_count.get(kind, 0) + 1


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum collective payload bytes (per partition) from optimized HLO."""
    stats = CollectiveStats()
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        bpe = _DTYPE_BYTES.get(dtype)
        if bpe is None:
            continue  # tuple-typed wrapper line; elements counted separately
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes = n * bpe
        if kind == "all-reduce":
            nbytes *= 2  # ring AR = reduce-scatter + all-gather
        stats.add(kind, float(nbytes))
    return stats


def model_flops(kind: str, n_active_params: int, tokens: int) -> float:
    """Standard accounting: 6·N per train token (fwd+bwd), 2·N per
    forward-only token (prefill/decode)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens


@dataclass
class RooflineReport:
    arch: str
    shape: str
    kind: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: dict
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops_total: float
    useful_flops_ratio: float
    memory_stats: dict
    hw: dict

    def to_dict(self) -> dict:
        return dict(self.__dict__)

    def summary_line(self) -> str:
        return (
            f"{self.arch:28s} {self.shape:12s} {self.mesh:10s} "
            f"tc={self.t_compute:.3e}s tm={self.t_memory:.3e}s "
            f"tcoll={self.t_collective:.3e}s dom={self.dominant:10s} "
            f"useful={self.useful_flops_ratio:.2f}"
        )


def analyze_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    kind: str,
    mesh_name: str,
    chips: int,
    n_active_params: int,
    tokens: int,
    hw: dict = HW_V5E,
) -> RooflineReport:
    """Primary cost source: the loop-aware HLO parser (hlo_parse), because
    XLA's cost_analysis visits while bodies once and our stacks are scans.
    XLA's numbers are kept in the report as `xla_cost_analysis` for
    cross-checking the non-loop part."""
    from repro.roofline.hlo_parse import analyze_hlo

    text = compiled.as_text()
    parsed = analyze_hlo(text)
    cost = compiled.cost_analysis() or {}
    flops_per_chip = max(parsed.flops, float(cost.get("flops", 0.0)))
    bytes_per_chip = max(parsed.traffic_bytes, float(cost.get("bytes accessed", 0.0)))
    stats = CollectiveStats(
        total_bytes=parsed.collective_bytes,
        by_kind_bytes=parsed.collective_by_kind,
        by_kind_count=parsed.collective_count,
    )

    t_compute = flops_per_chip / hw["peak_flops"]
    t_memory = bytes_per_chip / hw["hbm_bw"]
    t_collective = stats.total_bytes / hw["link_bw"]
    terms = {
        "compute": t_compute,
        "memory": t_memory,
        "collective": t_collective,
    }
    dominant = max(terms, key=terms.get)  # type: ignore[arg-type]

    mf = model_flops(kind, n_active_params, tokens)
    total_hlo_flops = flops_per_chip * chips
    useful = mf / total_hlo_flops if total_hlo_flops else 0.0

    try:
        ms = compiled.memory_analysis()
        memory_stats = dict(
            argument_bytes=int(ms.argument_size_in_bytes),
            output_bytes=int(ms.output_size_in_bytes),
            temp_bytes=int(ms.temp_size_in_bytes),
            alias_bytes=int(ms.alias_size_in_bytes),
            code_bytes=int(ms.generated_code_size_in_bytes),
        )
    except Exception as e:  # repro: allow[RP005] — optional XLA API; error reported in-band
        memory_stats = {"error": str(e)}
    memory_stats["xla_cost_analysis"] = {
        k: float(v) for k, v in cost.items()
        if k in ("flops", "bytes accessed", "transcendentals")
    }
    memory_stats["while_trip_counts"] = parsed.while_trip_counts

    return RooflineReport(
        arch=arch,
        shape=shape,
        kind=kind,
        mesh=mesh_name,
        chips=chips,
        flops_per_chip=flops_per_chip,
        bytes_per_chip=bytes_per_chip,
        coll_bytes_per_chip=stats.total_bytes,
        coll_breakdown={
            "bytes": stats.by_kind_bytes,
            "count": stats.by_kind_count,
        },
        t_compute=t_compute,
        t_memory=t_memory,
        t_collective=t_collective,
        dominant=dominant,
        model_flops_total=mf,
        useful_flops_ratio=useful,
        memory_stats=memory_stats,
        hw=dict(hw),
    )
