"""Seeded, deterministic chaos injection for any `ObjectStore`.

The resilience layer (`repro.io.retry`) is only trustworthy if the
failure modes it claims to survive can be *simulated*: throttling (503
SlowDown), stalls, truncated range responses, corrupt payloads, and
mid-transfer connection cuts. `FaultyStore` wraps any store and injects
those faults according to a `FaultSchedule` — a small scripting DSL whose
decisions are a pure function of (seed, request order), so a chaos test
that fails replays identically.

    sched = (FaultSchedule(seed=7)
             .throttle(ops=READ_OPS, prob=0.2)      # 503 on ~20% of GETs
             .stall(0.05, every=10)                 # every 10th op lags 50 ms
             .truncate(nbytes=128, times=2)         # two short responses
             .cut(after_bytes=4096, every=13)       # mid-object drops
             .transient(key="shard_0003", times=1)) # one targeted fault
    store = FaultyStore(SimS3Store(...), sched)

Cost honesty: a ``cut`` fetches the first ``after_bytes`` from the inner
store *for real* before raising — on a simulated S3 that pays one request
latency plus partial bandwidth, exactly what a dropped connection costs.
``throttle``/``transient`` raise without touching the inner store; pair
`FaultyStore` with a `LinkModel` rps limit when the raising request
itself should pay a round trip.

Corruption is detected AND healed since the integrity layer
(`repro.io.integrity`) landed: the verified-read path
(:meth:`FaultyStore.get_range_verified` and friends) takes the
store-attested digest from the INNER store while payload shaping mangles
only the returned bytes — so a fired ``corrupt``/``truncate`` is exactly
the detectable wire-mangling S3's GetObject checksum mode catches, and
engines running ``IOPolicy(verify="edges")`` re-fetch through the shared
`Retrier` instead of delivering flipped bytes to the application. Only
the unverified legacy path (``verify="off"``) still delivers corruption
silently. ``flip_at_rest`` extends chaos to resident cache blocks: a
`DirTier` constructed with ``faults=schedule`` mutates the on-disk block
file between write and read, exercising the journal-crc steady-state
check.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field

from repro.store.base import (
    MultipartUpload,
    ObjectMeta,
    ObjectStore,
    StoreError,
    ThrottleError,
    TransientStoreError,
)

READ_OPS = ("get_range", "get_ranges", "get")
WRITE_OPS = ("put", "put_part", "complete")
META_OPS = ("size", "list_objects", "delete")
ALL_OPS = READ_OPS + WRITE_OPS + META_OPS

# Faults that replace the normal raise/serve flow of a request.
# ``flip_at_rest`` is special: it fires on the pseudo-op "at_rest" that
# a `DirTier` consults on reads, mutating a RESIDENT block file rather
# than a wire payload.
_KINDS = ("throttle", "transient", "stall", "truncate", "corrupt", "cut",
          "flip_at_rest")


@dataclass
class FaultRule:
    """One line of a `FaultSchedule` script. Matching is by operation
    name and (optional) key substring; firing is either probabilistic
    (``prob``, drawn from the schedule's seeded rng) or deterministic
    (``every`` Nth matching request). ``after`` skips the first N
    matches, ``times`` caps total firings."""

    kind: str
    ops: tuple[str, ...]
    prob: float = 1.0
    key: str | None = None
    times: int | None = None
    after: int = 0
    every: int | None = None
    stall_s: float = 0.0
    nbytes: int = 1
    # Mutable bookkeeping (under the schedule's lock).
    seen: int = field(default=0, repr=False)
    fired: int = field(default=0, repr=False)

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"prob must be in [0, 1], got {self.prob}")
        if self.every is not None and self.every < 1:
            raise ValueError(f"every must be >= 1, got {self.every}")
        if self.nbytes < 0:
            raise ValueError(f"nbytes must be >= 0, got {self.nbytes}")


class FaultSchedule:
    """Ordered fault rules plus the seeded rng that arbitrates them.

    Builder methods append a rule and return ``self`` for chaining; each
    takes the common matching knobs (``ops``, ``key``, ``prob``,
    ``times``, ``after``, ``every``). When ``every`` is given the rule is
    fully deterministic and ``prob`` is ignored.
    """

    def __init__(self, seed: int = 0) -> None:
        self.rules: list[FaultRule] = []
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    # -- builder ----------------------------------------------------------
    def _add(self, kind: str, ops, key, prob, times, after, every,
             **extra) -> "FaultSchedule":
        if isinstance(ops, str):
            ops = (ops,)
        self.rules.append(FaultRule(
            kind=kind, ops=tuple(ops), key=key, prob=prob, times=times,
            after=after, every=every, **extra,
        ))
        return self

    def throttle(self, *, ops=ALL_OPS, key=None, prob=1.0, times=None,
                 after=0, every=None) -> "FaultSchedule":
        """Raise `ThrottleError` (503 SlowDown) for matching requests."""
        return self._add("throttle", ops, key, prob, times, after, every)

    def transient(self, *, ops=ALL_OPS, key=None, prob=1.0, times=None,
                  after=0, every=None) -> "FaultSchedule":
        """Raise `TransientStoreError` (dropped connection, 5xx)."""
        return self._add("transient", ops, key, prob, times, after, every)

    def stall(self, duration_s: float, *, ops=ALL_OPS, key=None, prob=1.0,
              times=None, after=0, every=None) -> "FaultSchedule":
        """Delay matching requests by ``duration_s`` then serve normally
        (the straggler the hedging machinery exists for)."""
        return self._add("stall", ops, key, prob, times, after, every,
                         stall_s=duration_s)

    def truncate(self, *, nbytes: int = 1, ops=READ_OPS, key=None, prob=1.0,
                 times=None, after=0, every=None) -> "FaultSchedule":
        """Chop ``nbytes`` off the tail of the response payload (a short
        read the server reported as complete)."""
        return self._add("truncate", ops, key, prob, times, after, every,
                         nbytes=nbytes)

    def corrupt(self, *, ops=READ_OPS, key=None, prob=1.0, times=None,
                after=0, every=None) -> "FaultSchedule":
        """Flip one (seeded-position) byte of the response payload."""
        return self._add("corrupt", ops, key, prob, times, after, every)

    def flip_at_rest(self, *, key=None, prob=1.0, times=None,
                     after=0, every=None) -> "FaultSchedule":
        """Flip one byte of a RESIDENT `DirTier` block file between its
        write and a later read (at-rest bit rot). Fires on the tier's
        ``"at_rest"`` pseudo-op — pass this schedule as the tier's
        ``faults=`` argument; wire-level ops never match it."""
        return self._add("flip_at_rest", ("at_rest",), key, prob, times,
                         after, every)

    def cut(self, *, after_bytes: int, ops=READ_OPS, key=None, prob=1.0,
            times=None, after=0, every=None) -> "FaultSchedule":
        """Drop the connection mid-transfer: the first ``after_bytes``
        are fetched from the inner store for real (paying latency and
        partial bandwidth), then the request raises."""
        return self._add("cut", ops, key, prob, times, after, every,
                         nbytes=after_bytes)

    # -- arbitration -------------------------------------------------------
    def decide(self, op: str, key: str) -> list[FaultRule]:
        """The rules firing for this request, in script order.
        Deterministic in (seed, sequence of matching requests)."""
        out: list[FaultRule] = []
        with self._lock:
            for r in self.rules:
                if op not in r.ops:
                    continue
                if r.key is not None and r.key not in key:
                    continue
                r.seen += 1
                if r.seen <= r.after:
                    continue
                if r.times is not None and r.fired >= r.times:
                    continue
                if r.every is not None:
                    if (r.seen - r.after) % r.every != 0:
                        continue
                elif r.prob < 1.0 and self._rng.random() >= r.prob:
                    continue
                r.fired += 1
                out.append(r)
        return out

    def rand_index(self, n: int) -> int:
        """A seeded index in [0, n) (corruption byte position)."""
        with self._lock:
            return self._rng.randrange(n)

    def total_fired(self) -> int:
        with self._lock:
            return sum(r.fired for r in self.rules)


class _FaultyMultipartUpload:
    """Proxy multipart handle: part puts and the final complete() pass
    through the schedule as ``put_part`` / ``complete`` operations."""

    def __init__(self, outer: "FaultyStore", inner: MultipartUpload,
                 key: str) -> None:
        self._outer = outer
        self._inner = inner
        self._key = key

    def put_part(self, index: int, data: bytes) -> None:
        self._outer._inject("put_part", self._key)
        self._inner.put_part(index, data)

    def complete(self) -> None:
        self._outer._inject("complete", self._key)
        self._inner.complete()

    def abort(self) -> None:
        self._inner.abort()


class FaultyStore(ObjectStore):
    """Chaos wrapper delegating every operation to ``inner`` with the
    faults a `FaultSchedule` scripts. Per-kind injection counts are kept
    in :attr:`injected` (read via :meth:`snapshot`)."""

    def __init__(self, inner: ObjectStore, schedule: FaultSchedule) -> None:
        self.inner = inner
        self.schedule = schedule
        self._lock = threading.Lock()
        self.injected: dict[str, int] = {k: 0 for k in _KINDS}

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self.injected)

    # -- injection ---------------------------------------------------------
    def _inject(self, op: str, key: str) -> list[FaultRule]:
        """Apply the raising/stalling faults for this request; return the
        payload-shaping rules (truncate/corrupt/cut) for the caller."""
        rules = self.schedule.decide(op, key)
        payload_rules: list[FaultRule] = []
        for r in rules:
            with self._lock:
                self.injected[r.kind] += 1
            if r.kind == "stall":
                time.sleep(r.stall_s)
            elif r.kind == "throttle":
                raise ThrottleError(
                    f"injected throttle: {op} {key!r} (SlowDown)"
                )
            elif r.kind == "transient":
                raise TransientStoreError(
                    f"injected transient fault: {op} {key!r}"
                )
            else:
                payload_rules.append(r)
        return payload_rules

    def _mangle(self, rules: list[FaultRule], data: bytes) -> bytes:
        for r in rules:
            if r.kind == "truncate" and data:
                data = data[: max(0, len(data) - r.nbytes)]
            elif r.kind == "corrupt" and data:
                buf = bytearray(data)
                buf[self.schedule.rand_index(len(buf))] ^= 0xFF
                data = bytes(buf)
        return data

    @staticmethod
    def _cut_rule(rules: list[FaultRule]) -> FaultRule | None:
        return next((r for r in rules if r.kind == "cut"), None)

    # -- reads -------------------------------------------------------------
    def get_range(self, key: str, start: int, end: int) -> bytes:
        rules = self._inject("get_range", key)
        cut = self._cut_rule(rules)
        if cut is not None:
            stop = min(end, start + cut.nbytes)
            if stop > start:
                # The partial payload crosses the (inner) wire for real.
                self.inner.get_range(key, start, stop)
            raise TransientStoreError(
                f"injected cut: {key!r} dropped after {stop - start} "
                f"of {end - start} bytes"
            )
        return self._mangle(rules, self.inner.get_range(key, start, end))

    def get_ranges(self, key: str, spans: list[tuple[int, int]]) -> list[bytes]:
        rules = self._inject("get_ranges", key)
        cut = self._cut_rule(rules)
        if cut is not None:
            start = spans[0][0] if spans else 0
            stop = min(spans[-1][1] if spans else 0, start + cut.nbytes)
            if stop > start:
                self.inner.get_range(key, start, stop)
            raise TransientStoreError(
                f"injected cut: {key!r} dropped after {stop - start} bytes "
                f"of a {len(spans)}-span request"
            )
        out = self.inner.get_ranges(key, spans)
        if out and rules:
            # Payload shaping lands on the final span — the tail of the
            # wire transfer, where a short response actually bites.
            out = list(out)
            out[-1] = self._mangle(rules, out[-1])
        return out

    def get(self, key: str) -> bytes:
        rules = self._inject("get", key)
        cut = self._cut_rule(rules)
        if cut is not None:
            if cut.nbytes > 0:
                self.inner.get_range(key, 0, cut.nbytes)
            raise TransientStoreError(
                f"injected cut: {key!r} dropped after {cut.nbytes} bytes"
            )
        return self._mangle(rules, self.inner.get(key))

    # -- verified reads ----------------------------------------------------
    # The store-attested digest comes from the INNER store (the
    # authority) while payload shaping mangles only the returned bytes —
    # so a fired ``corrupt``/``truncate`` is *detectable* by the caller,
    # exactly like S3's GetObject checksum mode detects a mangled wire
    # transfer. ``cut`` still raises before any payload exists.
    def get_range_verified(self, key: str, start: int,
                           end: int) -> tuple[bytes, str]:
        rules = self._inject("get_range", key)
        cut = self._cut_rule(rules)
        if cut is not None:
            stop = min(end, start + cut.nbytes)
            if stop > start:
                self.inner.get_range(key, start, stop)
            raise TransientStoreError(
                f"injected cut: {key!r} dropped after {stop - start} "
                f"of {end - start} bytes"
            )
        data, digest = self.inner.get_range_verified(key, start, end)
        return self._mangle(rules, data), digest

    def get_ranges_verified(
        self, key: str, spans: list[tuple[int, int]]
    ) -> list[tuple[bytes, str]]:
        rules = self._inject("get_ranges", key)
        cut = self._cut_rule(rules)
        if cut is not None:
            start = spans[0][0] if spans else 0
            stop = min(spans[-1][1] if spans else 0, start + cut.nbytes)
            if stop > start:
                self.inner.get_range(key, start, stop)
            raise TransientStoreError(
                f"injected cut: {key!r} dropped after {stop - start} bytes "
                f"of a {len(spans)}-span request"
            )
        out = self.inner.get_ranges_verified(key, spans)
        if out and rules:
            out = list(out)
            data, digest = out[-1]
            out[-1] = (self._mangle(rules, data), digest)
        return out

    def digest_range(self, key: str, start: int, end: int) -> str:
        # A checksum RPC carries no payload to mangle; pass through to
        # the authority.
        return self.inner.digest_range(key, start, end)

    # -- writes ------------------------------------------------------------
    def put(self, key: str, data: bytes) -> None:
        rules = self._inject("put", key)
        if self._cut_rule(rules) is not None:
            # A cut upload never lands (whole-object puts are atomic).
            raise TransientStoreError(f"injected cut: put {key!r} dropped")
        self.inner.put(key, data)

    def start_multipart(self, key: str) -> MultipartUpload:
        return _FaultyMultipartUpload(self, self.inner.start_multipart(key),
                                      key)  # type: ignore[return-value]

    def delete(self, key: str) -> None:
        self._inject("delete", key)
        self.inner.delete(key)

    # -- metadata ----------------------------------------------------------
    def list_objects(self, prefix: str = "") -> list[ObjectMeta]:
        self._inject("list_objects", prefix)
        return self.inner.list_objects(prefix)

    def size(self, key: str) -> int:
        self._inject("size", key)
        return self.inner.size(key)

    def exists(self, key: str) -> bool:
        try:
            self.size(key)
            return True
        except TransientStoreError:
            raise
        except StoreError:
            return False
